"""Engine vs pre-PR loop: rounds/sec for the device-resident superstep.

Times the paper's per-round-accuracy workload (eval every round — Fig. 4-7
plot accuracy-per-round curves) for fedavg / fedmmd / fedfusion, each with
the identity codec and with topk+error-feedback uplink:

* baseline — ``run_federated_reference`` with ``eval_fn=_evaluate_eager``:
  the exact pre-engine loop (per-round jit dispatch, blocking ``float()``
  metrics, NumPy EF round-trip, uncompiled evaluation);
* engine — ``run_federated`` (jitted superstep chunks with eval folded
  into the scan, donated buffers, on-device EF scatter, prefetch thread,
  async metrics).

Methodology: after one warmup run (process-global op caches), each loop is
run at R1 and R2 rounds from identical fresh state; rounds/sec =
(R2 - R1) / (t2 - t1).  Both timed runs compile the same programs from
scratch (R1 and R2 are multiples of the chunk length), so compile time
cancels and the quotient is steady-state round throughput.

Quick mode deliberately uses a small, loop-overhead-bound configuration —
the paper's CNN shrunk until per-round device compute no longer masks the
loop machinery this PR replaces (per-round dispatch, blocking metrics,
NumPy EF round-trip, uncompiled eval).  Full mode times the paper-scale
CNN, where the device-compute floor (shared by both loops) bounds the
achievable ratio on CPU.

Writes ``benchmarks/artifacts/BENCH_engine.json``.  ``--check BASELINE``
compares the *speedup ratio* (engine / baseline on the same host, same
run) against a committed baseline and exits non-zero on a >20% regression
— the ratio is host-speed-independent, unlike absolute rounds/sec, so the
check is meaningful on heterogeneous CI machines.  Absolute rounds/sec are
recorded in the JSON for human eyes.

Also asserts the acceptance equivalence: the K=1 engine's final model is
bitwise-equal to the reference loop on the same seed/config.

``--mesh data=N`` times the sharded engine (client axis over a forced
N-device host mesh — the flag is translated to
``xla_force_host_platform_device_count`` BEFORE jax initializes, which is
why the env fixup below precedes every jax import) on a client-bound
config; ``--mesh-sweep data=1,2,4`` spawns one subprocess per point and
aggregates rounds/sec scaling into the report's ``mesh_scaling`` section.
Each multi-device point additionally records the fused-vs-unfused
collective ratio (the one-psum round vs the three-collective oracle) and
the sharded-eval eval-every-round ratio; the ``--check`` gate arms on
those once the committed baseline records them.

``--n-sweep [N1,N2,...]`` is the cohort-paged EF store's headline run:
rounds/sec at a FIXED cohort while the federation size N sweeps (default
10^3 -> 10^5, CI-sized; the store design extends to 10^6 — the per-chunk
page is K*C rows whatever N is).  The sweep runs ``ef_store="host"`` on a
:class:`repro.data.federated.TemplateClients` lazy federation (O(C) host
data too) and exits non-zero unless (a) the staged EF page bytes are
IDENTICAL at every N — the O(C·n) device-memory pin — and (b) rounds/sec
at the largest N stays >= 0.9x the smallest N.
"""
from __future__ import annotations

import os
import sys

_mesh_arg = next((a.split("=", 1)[1] if a.startswith("--mesh=")
                  else sys.argv[i + 1]
                  for i, a in enumerate(sys.argv)
                  if a == "--mesh" or a.startswith("--mesh=")), None)
if _mesh_arg is not None:   # must precede any jax import (see docstring)
    _n = int(_mesh_arg.rsplit("=", 1)[1])
    # damp intra-op threading at EVERY point (data=1 included) so the
    # curve reflects device-level sharding, not core oversubscription.
    # Best-effort: XLA CPU still runs some ops multi-threaded, so on an
    # M-core host the measurable ceiling is < M / (threads the 1-device
    # baseline already uses) — the committed baseline records cpu_count
    # and the regression gate self-disarms across host classes.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_cpu_multi_thread_eigen=false"
        + (f" --xla_force_host_platform_device_count={_n}"
           if _n > 1 else ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import platform      # noqa: E402
import subprocess    # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import iid_partition
from repro.fl.server import (_evaluate_eager, run_federated,
                             run_federated_reference)
from repro.models.registry import make_bundle

from benchmarks.common import ART_DIR, mnist_like, print_table

SUPERSTEP = 25  # R1/R2 are multiples, so both runs compile one chunk length
REPEATS = 3     # median-of-N rounds/sec per loop: the box's run-to-run
                # noise would otherwise dominate single measurements


def _bundle(quick: bool):
    cfg = CNN_CONFIGS["cnn_mnist"]
    if quick:
        cfg = dataclasses.replace(cfg, input_shape=(8, 8, 1),
                                  conv_channels=(2,), fc_units=(4,),
                                  dropout=0.0)
    else:
        cfg = dataclasses.replace(cfg, dropout=0.0)
    return cfg


def _data(cfg, quick: bool, seed=0):
    if quick:
        from repro.data.synth import class_images
        x, y = class_images(12, n_classes=10, shape=cfg.input_shape,
                            seed=seed, noise=0.2, template_seed=0)
        xt, yt = class_images(8, n_classes=10, shape=cfg.input_shape,
                             seed=seed + 1, noise=0.2, template_seed=0)
    else:
        x, y = mnist_like(60, seed=seed)
        xt, yt = mnist_like(10, seed=seed + 1)
    return FederatedDataset(iid_partition(x, y, 8), {"x": xt, "y": yt},
                            seed=seed)


def _configs(quick: bool):
    if quick:
        base = dict(clients_per_round=4, local_steps=1, local_batch=4,
                    lr=0.05)
    else:
        base = dict(clients_per_round=4, local_steps=4, local_batch=16,
                    lr=0.05)
    for algo, extra in (("fedavg", {}), ("fedmmd", {"mmd_lambda": 0.1}),
                        ("fedfusion", {"fusion_op": "multi"})):
        for uplink in ("identity", "topk"):
            fl = FLConfig(algorithm=algo, uplink_codec=uplink,
                          topk_frac=0.05, **extra, **base)
            yield f"{algo}x{uplink}", fl


def _timed(run, rounds):
    t0 = time.perf_counter()
    res = run(rounds)
    jax.block_until_ready(res.global_state)
    return time.perf_counter() - t0, res


def _rps(run, r1, r2, repeats=None):
    """Steady-state rounds/sec via the two-length compile-cancel trick."""
    _timed(run, r1)                      # warmup: process-global op caches
    want = repeats or REPEATS
    samples = []
    for attempt in range(3 * want):
        t1, _ = _timed(run, r1)
        t2, res = _timed(run, r2)
        # a non-positive delta means compile/scheduling jitter swallowed
        # the steady-state signal entirely — that sample carries no
        # information, so resample instead of clamping it to nonsense
        if t2 - t1 > 0:
            samples.append((r2 - r1) / (t2 - t1))
            if len(samples) >= want:
                break
    if not samples:                       # pathologically noisy host
        samples.append((r2 - r1) / max(t2 - t1, 1e-9))
    return float(np.median(samples)), res


def _mesh_config():
    """Client-bound sharding workload: ``client_sequential`` scans the
    round's clients one after another on a device, so the client axis is
    ALGORITHMICALLY serial per shard — sharding it divides the serial
    chain, which is what the sweep measures (the vmapped
    ``client_parallel`` mode already parallelizes clients inside one XLA
    program, so on CPU its scaling only reflects core oversubscription).
    No eval, identity codec: the collective under test is the FedAvg
    aggregation psum, not the wire path."""
    cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                              input_shape=(24, 24, 1),
                              conv_channels=(8, 16), fc_units=(64,),
                              dropout=0.0)
    fl = FLConfig(algorithm="fedavg", clients_per_round=8, local_steps=2,
                  local_batch=8, lr=0.05)
    return cfg, fl


def _mesh_data(cfg, seed=0):
    from repro.data.synth import class_images
    x, y = class_images(24, n_classes=10, shape=cfg.input_shape, seed=seed,
                        noise=0.2, template_seed=0)
    xt, yt = class_images(8, n_classes=10, shape=cfg.input_shape,
                          seed=seed + 1, noise=0.2, template_seed=0)
    return FederatedDataset(iid_partition(x, y, 8), {"x": xt, "y": yt},
                            seed=seed)


def run_mesh_point(n_devices: int, r1: int = 10, r2: int = 40) -> dict:
    """Rounds/sec of the (sharded) engine on an ``n_devices``-wide client
    mesh — run in a process whose host was forced to that device count.

    On a real mesh (n > 1) the point also measures the two per-round
    collective knobs this engine exposes:

    * ``collective_fused_ratio`` — the fused one-psum round vs the
      three-collective oracle (``fused_collective=False``), compressed
      workload (topk uplink: the EF exchange is what gets fused away);
    * ``sharded_eval_ratio`` — eval-every-round (the paper's workload)
      with the eval batch split over the shards vs replicated eval.

    Both are bitwise/allclose-pinned equivalences (tests/test_engine.py),
    so the ratios are pure latency measurements.  On a shared-memory CPU
    host collective latency is tiny — the ratios mostly certify "no
    regression" there; the spread shows up on real interconnects.
    """
    from repro.launch.mesh import make_engine_mesh
    assert jax.device_count() >= n_devices, \
        (f"need {n_devices} devices, have {jax.device_count()} — launch "
         f"via --mesh-sweep or set xla_force_host_platform_device_count")
    cfg, fl = _mesh_config()
    bundle = make_bundle(cfg)
    mesh = make_engine_mesh(n_devices) if n_devices > 1 else None

    def run(rounds):
        return run_federated(bundle, fl, _mesh_data(cfg), rounds=rounds,
                             seed=0, eval_every=0, superstep_rounds=10,
                             mode="client_sequential", mesh=mesh)

    rps, res = _rps(run, r1, r2)
    point = {"devices": n_devices, "rps": round(rps, 2),
             "host_wait_s": res.stats["host_wait_s"],
             "clients_per_round": fl.clients_per_round,
             "mode": "client_sequential"}
    if mesh is None:
        return point

    fl_comp = dataclasses.replace(fl, uplink_codec="topk", topk_frac=0.05)

    def run_collective(rounds, fused):
        return run_federated(bundle, fl_comp, _mesh_data(cfg),
                             rounds=rounds, seed=0, eval_every=0,
                             superstep_rounds=10, mode="client_sequential",
                             mesh=mesh, fused_collective=fused)

    fused_rps, _ = _rps(lambda r: run_collective(r, True), r1, r2)
    unfused_rps, _ = _rps(lambda r: run_collective(r, False), r1, r2)
    point["rps_fused"] = round(fused_rps, 2)
    point["rps_unfused"] = round(unfused_rps, 2)
    point["collective_fused_ratio"] = round(
        fused_rps / max(unfused_rps, 1e-9), 3)

    def run_eval(rounds, sharded):
        return run_federated(bundle, fl, _mesh_data(cfg), rounds=rounds,
                             seed=0, eval_every=1, eval_examples=64,
                             superstep_rounds=10, mode="client_sequential",
                             mesh=mesh, sharded_eval=sharded)

    ev_shd, _ = _rps(lambda r: run_eval(r, True), r1, r2)
    ev_repl, _ = _rps(lambda r: run_eval(r, False), r1, r2)
    point["rps_eval_sharded"] = round(ev_shd, 2)
    point["rps_eval_replicated"] = round(ev_repl, 2)
    point["sharded_eval_ratio"] = round(ev_shd / max(ev_repl, 1e-9), 3)
    return point


def run_mesh_sweep(devices, out_dir: str) -> dict:
    """Spawn one subprocess per device count (the forced-device flag must
    be set before jax initializes) and aggregate the scaling curve."""
    points = []
    for n in devices:
        path = os.path.join(out_dir, f"_mesh_{n}.json")
        cmd = [sys.executable, "-m", "benchmarks.bench_engine",
               "--mesh", f"data={n}", "--out", path]
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1800)
        if r.returncode:
            raise RuntimeError(f"mesh point {n} failed:\n{r.stdout}\n"
                               f"{r.stderr}")
        with open(path) as f:
            points.append(json.load(f)["mesh_point"])
        os.remove(path)
        p = points[-1]
        extra = ""
        if "collective_fused_ratio" in p:
            extra = (f"  fused/unfused={p['collective_fused_ratio']}x"
                     f"  sharded-eval={p['sharded_eval_ratio']}x")
        print(f"mesh data={n}: {p['rps']:7.2f} r/s{extra}")
    one = [p for p in points if p["devices"] == 1]
    assert one, "mesh sweep needs a devices=1 point (speedup_vs_1 base)"
    base = one[0]["rps"]
    for p in points:
        p["speedup_vs_1"] = round(p["rps"] / base, 2)
    out = {"points": points,
           "max_speedup": max(p["speedup_vs_1"] for p in points)}
    fused = [p["collective_fused_ratio"] for p in points
             if "collective_fused_ratio" in p]
    if fused:
        out["collective_fused_ratio_max"] = max(fused)
    ev = [p["sharded_eval_ratio"] for p in points
          if "sharded_eval_ratio" in p]
    if ev:
        out["sharded_eval_ratio_max"] = max(ev)
    return out


def run_n_sweep(ns, r1: int = 50, r2: int = 450) -> dict:
    """Rounds/sec + EF device memory as N sweeps at a fixed cohort.

    With the dense table, every point would stage (and checkpoint-sync)
    an ``[N, n]`` device buffer — throughput and memory both scale with
    N.  With the paged store the device only ever sees the chunk's
    ``[K*C, n]`` page, so both curves must be FLAT.  ``dense_table_bytes``
    records what the dense backing would have allocated at each N (the
    page bytes / dense bytes gap is the tentpole's memory headline).
    """
    import tempfile

    from repro.data.federated import TemplateClients
    from repro.data.synth import class_images

    # Every point compiles the SAME programs (page shapes are cohort-
    # sized, independent of N — that is the tentpole), but each engine
    # run jits fresh function objects, so without a persistent cache
    # every timed run would recompile ~1s of XLA whose run-to-run jitter
    # swamps the ~ms-scale steady-state signal the flatness gate needs.
    # Scoped to the n-sweep: the full bench path has tripped allocator
    # crashes with the cache enabled on this jax build, and its gates
    # are ratio-based (noise cancels) rather than flatness-based.
    cache_dir = tempfile.mkdtemp(prefix="nsweep_xla_cache_")
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)

    K = 10
    cfg = _bundle(True)
    bundle = make_bundle(cfg)
    fl = FLConfig(algorithm="fedavg", uplink_codec="topk", topk_frac=0.05,
                  clients_per_round=8, local_steps=1, local_batch=4, lr=0.05)
    x, y = class_images(12, n_classes=10, shape=cfg.input_shape, seed=0,
                        noise=0.2, template_seed=0)
    xt, yt = class_images(8, n_classes=10, shape=cfg.input_shape, seed=1,
                          noise=0.2, template_seed=0)
    template = {"x": x, "y": y}

    def data(n):
        return FederatedDataset(TemplateClients(template, n),
                                {"x": xt, "y": yt}, seed=0)

    points = []
    for n in ns:
        def run_point(rounds, n=n):
            return run_federated(bundle, fl, data(n), rounds=rounds, seed=0,
                                 eval_every=0, superstep_rounds=K,
                                 ef_store="host")

        rps, res = _rps(run_point, r1, r2, repeats=5)
        page = res.stats["ef_page_bytes"]
        row = page // (K * fl.clients_per_round)   # page rows = K*C
        points.append({"n_clients": int(n), "rps": round(rps, 2),
                       "ef_page_bytes": int(page),
                       "dense_table_bytes": int(n) * row,
                       "ef_store_rows": res.stats["ef_store_rows"]})
        print(f"N={n:>9,d}: {rps:7.2f} r/s  page={page / 1024:.1f} KiB  "
              f"dense table would be {n * row / (1 << 20):.1f} MiB")
    rps_lo, rps_hi = points[0]["rps"], points[-1]["rps"]
    pages = {p["ef_page_bytes"] for p in points}
    out = {"points": points, "cohort": fl.clients_per_round,
           "chunk_rounds": K, "ef_store": "host",
           "rps_flatness": round(rps_hi / max(rps_lo, 1e-9), 3),
           "flat": bool(rps_hi >= 0.9 * rps_lo),
           "page_bytes_constant": len(pages) == 1}
    print(f"n-sweep flatness: rps@maxN / rps@minN = {out['rps_flatness']} "
          f"(gate >= 0.9)   page bytes constant: "
          f"{out['page_bytes_constant']}")
    return out


def run_eval_overlap(quick: bool, cfg, bundle) -> dict:
    """Chunk-boundary stall check: eval_every=2 with the snapshot-overlap
    dispatch vs the blocking (pre-overlap) dispatch, same workload."""
    fl = FLConfig(algorithm="fedavg", clients_per_round=4,
                  local_steps=1 if quick else 4,
                  local_batch=4 if quick else 16, lr=0.05)
    ev = 32 if quick else 2048
    out = {}
    for tag, overlap in (("overlap", True), ("blocking", False)):
        rps, res = _rps(
            lambda rounds: run_federated(
                bundle, fl, _data(cfg, quick), rounds=rounds, seed=0,
                eval_every=2, eval_examples=ev, superstep_rounds=SUPERSTEP,
                overlap_eval=overlap), 24, 120 if quick else 64)
        out[f"rps_{tag}"] = round(rps, 2)
        out[f"host_wait_s_{tag}"] = res.stats["host_wait_s"]
    out["overlap_ratio"] = round(out["rps_overlap"]
                                 / max(out["rps_blocking"], 1e-9), 3)
    return out


def run_observability(quick, cfg, bundle, out_dir,
                      profile_dir=None) -> dict:
    """Instrumented engine run (repro.obs): telemetry taps + runlog span
    tracing on the topk workload, emitting the run's JSONL artifacts
    (``runlog.jsonl`` / ``comm.jsonl``) next to the report and embedding
    the round-time breakdown in it.  Also measures the telemetry on/off
    throughput ratio — informational, since the bitwise contract
    (tests/test_obs.py) already pins that "on" only adds tap arithmetic
    to the existing round program.
    """
    from repro.obs import RunLog, build_report
    fl = next(f for name, f in _configs(quick) if name == "fedavgxtopk")
    rounds = 50 if quick else 24
    os.makedirs(out_dir, exist_ok=True)
    runlog_path = os.path.join(out_dir, "runlog.jsonl")
    comm_path = os.path.join(out_dir, "comm.jsonl")

    res = run_federated(bundle, fl, _data(cfg, quick), rounds=rounds,
                        seed=0, eval_every=1,
                        eval_examples=32 if quick else 2048,
                        superstep_rounds=10, telemetry=True,
                        runlog=runlog_path, profile_dir=profile_dir)
    res.comm.save(comm_path)
    report = build_report(RunLog.load(runlog_path), res.comm.to_records())

    def run_tele(rounds, on):
        return run_federated(bundle, fl, _data(cfg, quick), rounds=rounds,
                             seed=0, eval_every=1,
                             eval_examples=32 if quick else 2048,
                             superstep_rounds=SUPERSTEP, telemetry=on)

    rps_off, _ = _rps(lambda r: run_tele(r, False), 25, 100 if quick else 50)
    rps_on, _ = _rps(lambda r: run_tele(r, True), 25, 100 if quick else 50)
    return {"round_time": report["round_time"],
            "telemetry": {"rps_off": round(rps_off, 2),
                          "rps_on": round(rps_on, 2),
                          "on_off_ratio": round(rps_on / max(rps_off, 1e-9),
                                                3)},
            "artifacts": {"runlog": runlog_path, "comm": comm_path}}


def check_bitwise(bundle, fl, cfg, quick) -> bool:
    """Acceptance: K=1 engine model bitwise-equals the reference loop."""
    ref = run_federated_reference(bundle, fl, _data(cfg, quick), rounds=6,
                                  seed=0, eval_every=1)
    eng = run_federated(bundle, fl, _data(cfg, quick), rounds=6, seed=0,
                        eval_every=1, superstep_rounds=1)
    return all(np.array_equal(a, b) for a, b in zip(
        jax.tree.leaves(ref.global_state), jax.tree.leaves(eng.global_state)))


def run(quick: bool = True, r1: int = None, r2: int = None,
        out_dir: str = None, profile_dir: str = None):
    cfg = _bundle(quick)
    bundle = make_bundle(cfg)
    r1 = r1 or SUPERSTEP
    r2 = r2 or (r1 + (125 if quick else 40))
    eval_examples = 32 if quick else 2048
    rows = []
    for name, fl in _configs(quick):
        base_rps, _ = _rps(
            lambda rounds: run_federated_reference(
                bundle, fl, _data(cfg, quick), rounds=rounds, seed=0,
                eval_every=1, eval_examples=eval_examples,
                eval_fn=_evaluate_eager), r1, r2)
        eng_rps, _ = _rps(
            lambda rounds: run_federated(
                bundle, fl, _data(cfg, quick), rounds=rounds, seed=0,
                eval_every=1, eval_examples=eval_examples,
                superstep_rounds=SUPERSTEP), r1, r2)
        rows.append({"config": name, "algorithm": fl.algorithm,
                     "uplink": fl.uplink_codec,
                     "baseline_rps": round(base_rps, 2),
                     "engine_rps": round(eng_rps, 2),
                     "speedup": round(eng_rps / base_rps, 2)})
        print(f"{name:22s} baseline={base_rps:7.2f} r/s  "
              f"engine={eng_rps:7.2f} r/s  speedup={eng_rps/base_rps:5.2f}x")
    speedups = [r["speedup"] for r in rows]
    geomean = float(np.exp(np.mean(np.log(speedups))))
    bitwise = check_bitwise(bundle, next(_configs(quick))[1], cfg, quick)
    # adaptive chunk sizing: what K the dispatch-overhead calibration picks
    # on this host for the quick workload (logged, not gated — it is a
    # throughput knob with results pinned chunk-size-invariant by tests)
    auto = run_federated(bundle, next(_configs(quick))[1],
                         _data(cfg, quick), rounds=8, seed=0,
                         eval_every=0, superstep_rounds="auto")
    overlap = run_eval_overlap(quick, cfg, bundle)
    obs = run_observability(quick, cfg, bundle, out_dir or ART_DIR,
                            profile_dir=profile_dir)
    report = {
        "host": {"platform": platform.platform(),
                 "device": jax.devices()[0].platform,
                 "cpu_count": os.cpu_count(),
                 "jax": jax.__version__},
        "workload": {"quick": quick, "eval_every": 1,
                     "measured_rounds": r2 - r1,
                     "superstep_rounds": SUPERSTEP},
        "results": rows,
        "geomean_speedup": round(geomean, 3),
        "k1_bitwise_equal": bool(bitwise),
        "adaptive_chunk_rounds": auto.stats["chunk_rounds"],
        "eval_overlap": overlap,
        "observability": obs,
    }
    print_table("engine vs pre-PR loop (rounds/sec)", rows)
    print(f"geomean speedup: {geomean:.2f}x   "
          f"K=1 bitwise-equal: {bitwise}")
    print(f"adaptive chunk size: {auto.stats['chunk_rounds']} rounds   "
          f"eval-overlap ratio: {overlap['overlap_ratio']}x "
          f"(host wait {overlap['host_wait_s_overlap']}s vs "
          f"{overlap['host_wait_s_blocking']}s blocking)")
    rt = obs["round_time"]
    print(f"round-time breakdown: dispatch={rt['dispatch_s']}s "
          f"metrics={rt['metrics_drain_s']}s "
          f"prefetch-stall={rt['prefetch_stall_s']}s "
          f"eval={rt['eval_s']}s of wall={rt['wall_s']}s   "
          f"telemetry on/off: {obs['telemetry']['on_off_ratio']}x")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=os.path.join(ART_DIR,
                                                  "BENCH_engine.json"))
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail if geomean speedup (or mesh scaling, when "
                         "both runs measured it) regresses >20%% vs the "
                         "committed baseline")
    ap.add_argument("--mesh", default=None, metavar="data=N",
                    help="time ONE sharded-engine point on an N-device "
                         "forced host mesh (writes {'mesh_point': ...})")
    ap.add_argument("--n-sweep", nargs="?", const="1000,10000,100000",
                    default=None, metavar="N1,N2,...",
                    help="sweep federation size at fixed cohort with the "
                         "cohort-paged EF store; exits non-zero unless "
                         "rounds/sec and EF page bytes stay flat in N")
    ap.add_argument("--mesh-sweep", default=None, metavar="data=1,2,4",
                    help="run the mesh point per device count in "
                         "subprocesses and add 'mesh_scaling' to the "
                         "report")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="write a jax.profiler trace of the instrumented "
                         "observability run into DIR")
    args = ap.parse_args()

    if args.mesh:
        n = int(args.mesh.split("=", 1)[1])
        report = {"mesh_point": run_mesh_point(n)}
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")
        return

    if args.n_sweep:
        ns = [int(s) for s in args.n_sweep.split(",")]
        report = {"n_sweep": run_n_sweep(ns)}
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")
        sweep = report["n_sweep"]
        if not sweep["page_bytes_constant"]:
            raise SystemExit("FAIL: EF page bytes vary with N — the paged "
                             "store is not O(C*n)")
        if not sweep["flat"]:
            raise SystemExit("FAIL: rounds/sec not flat across the N sweep "
                             f"(ratio {sweep['rps_flatness']} < 0.9)")
        return

    report = run(quick=args.quick,
                 out_dir=os.path.dirname(args.out) or ".",
                 profile_dir=args.profile)
    if args.mesh_sweep:
        devices = [int(d) for d in
                   args.mesh_sweep.split("=", 1)[1].split(",")]
        report["mesh_scaling"] = run_mesh_sweep(devices,
                                                os.path.dirname(args.out)
                                                or ".")
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    if not report["k1_bitwise_equal"]:
        raise SystemExit("FAIL: K=1 engine is not bitwise-equal to the "
                         "reference loop")
    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        same_host_class = (baseline.get("host", {}).get("cpu_count")
                           == os.cpu_count())

        def gate(name, got, floor):
            if got >= floor:
                print(f"regression check OK: {name} {got:.2f} >= "
                      f"{floor:.2f}")
                return
            msg = f"{name} {got:.2f} < floor {floor:.2f}"
            if same_host_class:
                raise SystemExit("FAIL: " + msg)
            # ratios still shift with the host's compute floor; a baseline
            # recorded on a different machine class cannot gate reliably —
            # warn, and refresh the baseline from this host class.
            print(f"WARN (not gating): {msg}; baseline host has cpu_count="
                  f"{baseline.get('host', {}).get('cpu_count')}, this host "
                  f"{os.cpu_count()} — refresh "
                  f"benchmarks/baselines/BENCH_engine.json on this host "
                  f"class to arm the gate")

        gate("geomean speedup", report["geomean_speedup"],
             0.8 * baseline["geomean_speedup"])
        if "mesh_scaling" in report and "mesh_scaling" in baseline:
            gate("mesh max speedup", report["mesh_scaling"]["max_speedup"],
                 0.8 * baseline["mesh_scaling"]["max_speedup"])
            # collective-layout gates: self-arm once the committed
            # baseline records the ratios (same-host-class rule applies)
            for key in ("collective_fused_ratio_max",
                        "sharded_eval_ratio_max"):
                if key in report["mesh_scaling"] \
                        and key in baseline["mesh_scaling"]:
                    gate(key, report["mesh_scaling"][key],
                         0.8 * baseline["mesh_scaling"][key])


if __name__ == "__main__":
    main()
