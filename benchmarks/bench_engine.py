"""Engine vs pre-PR loop: rounds/sec for the device-resident superstep.

Times the paper's per-round-accuracy workload (eval every round — Fig. 4-7
plot accuracy-per-round curves) for fedavg / fedmmd / fedfusion, each with
the identity codec and with topk+error-feedback uplink:

* baseline — ``run_federated_reference`` with ``eval_fn=_evaluate_eager``:
  the exact pre-engine loop (per-round jit dispatch, blocking ``float()``
  metrics, NumPy EF round-trip, uncompiled evaluation);
* engine — ``run_federated`` (jitted superstep chunks with eval folded
  into the scan, donated buffers, on-device EF scatter, prefetch thread,
  async metrics).

Methodology: after one warmup run (process-global op caches), each loop is
run at R1 and R2 rounds from identical fresh state; rounds/sec =
(R2 - R1) / (t2 - t1).  Both timed runs compile the same programs from
scratch (R1 and R2 are multiples of the chunk length), so compile time
cancels and the quotient is steady-state round throughput.

Quick mode deliberately uses a small, loop-overhead-bound configuration —
the paper's CNN shrunk until per-round device compute no longer masks the
loop machinery this PR replaces (per-round dispatch, blocking metrics,
NumPy EF round-trip, uncompiled eval).  Full mode times the paper-scale
CNN, where the device-compute floor (shared by both loops) bounds the
achievable ratio on CPU.

Writes ``benchmarks/artifacts/BENCH_engine.json``.  ``--check BASELINE``
compares the *speedup ratio* (engine / baseline on the same host, same
run) against a committed baseline and exits non-zero on a >20% regression
— the ratio is host-speed-independent, unlike absolute rounds/sec, so the
check is meaningful on heterogeneous CI machines.  Absolute rounds/sec are
recorded in the JSON for human eyes.

Also asserts the acceptance equivalence: the K=1 engine's final model is
bitwise-equal to the reference loop on the same seed/config.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import platform
import time

import jax
import numpy as np

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import iid_partition
from repro.fl.server import (_evaluate_eager, run_federated,
                             run_federated_reference)
from repro.models.registry import make_bundle

from benchmarks.common import ART_DIR, mnist_like, print_table

SUPERSTEP = 25  # R1/R2 are multiples, so both runs compile one chunk length
REPEATS = 3     # median-of-N rounds/sec per loop: the box's run-to-run
                # noise would otherwise dominate single measurements


def _bundle(quick: bool):
    cfg = CNN_CONFIGS["cnn_mnist"]
    if quick:
        cfg = dataclasses.replace(cfg, input_shape=(8, 8, 1),
                                  conv_channels=(2,), fc_units=(4,),
                                  dropout=0.0)
    else:
        cfg = dataclasses.replace(cfg, dropout=0.0)
    return cfg


def _data(cfg, quick: bool, seed=0):
    if quick:
        from repro.data.synth import class_images
        x, y = class_images(12, n_classes=10, shape=cfg.input_shape,
                            seed=seed, noise=0.2, template_seed=0)
        xt, yt = class_images(8, n_classes=10, shape=cfg.input_shape,
                             seed=seed + 1, noise=0.2, template_seed=0)
    else:
        x, y = mnist_like(60, seed=seed)
        xt, yt = mnist_like(10, seed=seed + 1)
    return FederatedDataset(iid_partition(x, y, 8), {"x": xt, "y": yt},
                            seed=seed)


def _configs(quick: bool):
    if quick:
        base = dict(clients_per_round=4, local_steps=1, local_batch=4,
                    lr=0.05)
    else:
        base = dict(clients_per_round=4, local_steps=4, local_batch=16,
                    lr=0.05)
    for algo, extra in (("fedavg", {}), ("fedmmd", {"mmd_lambda": 0.1}),
                        ("fedfusion", {"fusion_op": "multi"})):
        for uplink in ("identity", "topk"):
            fl = FLConfig(algorithm=algo, uplink_codec=uplink,
                          topk_frac=0.05, **extra, **base)
            yield f"{algo}x{uplink}", fl


def _timed(run, rounds):
    t0 = time.perf_counter()
    res = run(rounds)
    jax.block_until_ready(res.global_state)
    return time.perf_counter() - t0, res


def _rps(run, r1, r2):
    """Steady-state rounds/sec via the two-length compile-cancel trick."""
    _timed(run, r1)                      # warmup: process-global op caches
    samples = []
    for _ in range(REPEATS):
        t1, _ = _timed(run, r1)
        t2, res = _timed(run, r2)
        samples.append((r2 - r1) / max(t2 - t1, 1e-9))
    return float(np.median(samples)), res


def check_bitwise(bundle, fl, cfg, quick) -> bool:
    """Acceptance: K=1 engine model bitwise-equals the reference loop."""
    ref = run_federated_reference(bundle, fl, _data(cfg, quick), rounds=6,
                                  seed=0, eval_every=1)
    eng = run_federated(bundle, fl, _data(cfg, quick), rounds=6, seed=0,
                        eval_every=1, superstep_rounds=1)
    return all(np.array_equal(a, b) for a, b in zip(
        jax.tree.leaves(ref.global_state), jax.tree.leaves(eng.global_state)))


def run(quick: bool = True, r1: int = None, r2: int = None):
    cfg = _bundle(quick)
    bundle = make_bundle(cfg)
    r1 = r1 or SUPERSTEP
    r2 = r2 or (r1 + (125 if quick else 40))
    eval_examples = 32 if quick else 2048
    rows = []
    for name, fl in _configs(quick):
        base_rps, _ = _rps(
            lambda rounds: run_federated_reference(
                bundle, fl, _data(cfg, quick), rounds=rounds, seed=0,
                eval_every=1, eval_examples=eval_examples,
                eval_fn=_evaluate_eager), r1, r2)
        eng_rps, _ = _rps(
            lambda rounds: run_federated(
                bundle, fl, _data(cfg, quick), rounds=rounds, seed=0,
                eval_every=1, eval_examples=eval_examples,
                superstep_rounds=SUPERSTEP), r1, r2)
        rows.append({"config": name, "algorithm": fl.algorithm,
                     "uplink": fl.uplink_codec,
                     "baseline_rps": round(base_rps, 2),
                     "engine_rps": round(eng_rps, 2),
                     "speedup": round(eng_rps / base_rps, 2)})
        print(f"{name:22s} baseline={base_rps:7.2f} r/s  "
              f"engine={eng_rps:7.2f} r/s  speedup={eng_rps/base_rps:5.2f}x")
    speedups = [r["speedup"] for r in rows]
    geomean = float(np.exp(np.mean(np.log(speedups))))
    bitwise = check_bitwise(bundle, next(_configs(quick))[1], cfg, quick)
    report = {
        "host": {"platform": platform.platform(),
                 "device": jax.devices()[0].platform,
                 "cpu_count": os.cpu_count(),
                 "jax": jax.__version__},
        "workload": {"quick": quick, "eval_every": 1,
                     "measured_rounds": r2 - r1,
                     "superstep_rounds": SUPERSTEP},
        "results": rows,
        "geomean_speedup": round(geomean, 3),
        "k1_bitwise_equal": bool(bitwise),
    }
    print_table("engine vs pre-PR loop (rounds/sec)", rows)
    print(f"geomean speedup: {geomean:.2f}x   "
          f"K=1 bitwise-equal: {bitwise}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=os.path.join(ART_DIR,
                                                  "BENCH_engine.json"))
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail if geomean speedup regresses >20%% vs the "
                         "committed baseline")
    args = ap.parse_args()
    report = run(quick=args.quick)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    if not report["k1_bitwise_equal"]:
        raise SystemExit("FAIL: K=1 engine is not bitwise-equal to the "
                         "reference loop")
    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        floor = 0.8 * baseline["geomean_speedup"]
        same_host_class = (baseline.get("host", {}).get("cpu_count")
                           == os.cpu_count())
        if report["geomean_speedup"] < floor:
            msg = (f"geomean speedup {report['geomean_speedup']:.2f}x "
                   f"< 80% of committed baseline "
                   f"{baseline['geomean_speedup']:.2f}x")
            if same_host_class:
                raise SystemExit("FAIL: " + msg)
            # the speedup ratio still shifts with the host's compute
            # floor; a baseline recorded on a different machine class
            # cannot gate reliably — warn, and refresh the baseline from
            # this host class to arm the gate.
            print(f"WARN (not gating): {msg}; baseline host has "
                  f"cpu_count={baseline.get('host', {}).get('cpu_count')}, "
                  f"this host {os.cpu_count()} — refresh "
                  f"benchmarks/baselines/BENCH_engine.json on this host "
                  f"class to arm the regression gate")
        else:
            print(f"regression check OK "
                  f"({report['geomean_speedup']:.2f}x >= {floor:.2f}x)")


if __name__ == "__main__":
    main()
