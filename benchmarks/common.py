"""Shared harness for the paper-reproduction benchmarks.

Synthetic stand-ins for MNIST / CIFAR (offline container): class-structured
Gaussian-blob images of identical shapes.  Every benchmark returns rows of
dicts and writes a CSV under benchmarks/artifacts/.
"""
from __future__ import annotations

import csv
import dataclasses
import os
from typing import Dict, List

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.synth import class_images
from repro.fl.server import run_federated
from repro.models.registry import make_bundle

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def mnist_like(n_per_class=60, seed=0, noise=0.2):
    """28x28x1, 10 classes — the paper's MNIST stand-in.

    Class templates are pinned (template_seed=0) so any (seed, noise) split
    samples the same class-conditional distribution — train/test match.
    """
    return class_images(n_per_class, n_classes=10, shape=(28, 28, 1),
                        seed=seed, noise=noise, template_seed=0)


def cifar_like(n_per_class=60, seed=0, noise=0.25):
    """32x32x3, 10 classes — the paper's CIFAR-10 stand-in."""
    return class_images(n_per_class, n_classes=10, shape=(32, 32, 3),
                        seed=seed, noise=noise, template_seed=7)


def permuted_union_test(xt, yt, parts):
    """Test set for the user-specific (permuted) partition: the union of the
    per-client permutations applied to the held-out images.  Evaluating the
    global model on UN-permuted data would probe a distribution no client
    generates (paper Fig. 5c measures accuracy on the federation's task)."""
    import numpy as np
    xs, ys = [], []
    for p in parts:
        perm = p["perm"]
        xf = xt.reshape(len(xt), -1)[:, perm].reshape(xt.shape)
        xs.append(xf)
        ys.append(yt)
    return {"x": np.concatenate(xs), "y": np.concatenate(ys)}


def bench_cnn(kind: str, quick: bool):
    """Paper CNN, width-reduced in quick mode to keep CPU time sane."""
    cfg = CNN_CONFIGS[f"cnn_{kind}"]
    if quick:
        cfg = dataclasses.replace(
            cfg, conv_channels=tuple(c // 4 for c in cfg.conv_channels),
            fc_units=tuple(u // 8 for u in cfg.fc_units), dropout=0.0)
    else:
        cfg = dataclasses.replace(cfg, dropout=0.0)
    return make_bundle(cfg)


def run_fl(bundle, data: FederatedDataset, fl: FLConfig, rounds: int,
           seed=0, eval_every=1):
    return run_federated(bundle, fl, data, rounds=rounds, seed=seed,
                         eval_every=eval_every)


def round_records(comm, save_as: str = None) -> List[Dict]:
    """A run's per-round history as plain-JSON records
    (``CommLog.to_records`` — the repro.obs serializer, so numpy scalars
    are already host types).  ``save_as`` additionally streams the full
    record set (rounds + summary) as JSONL under the artifacts dir, the
    same file format ``repro.obs.report``/``benchmarks.obs_report``
    consume."""
    if save_as:
        os.makedirs(ART_DIR, exist_ok=True)
        comm.save(os.path.join(ART_DIR, save_as))
    return [r for r in comm.to_records() if r["kind"] == "round"]


def rounds_to_acc(history: List[Dict], target: float) -> int:
    for h in history:
        if h.get("acc", -1) >= target:
            return h["round"]
    return -1


def best_acc(history: List[Dict]) -> float:
    return max(h.get("acc", 0.0) for h in history)


def _all_cols(rows: List[Dict]) -> List[str]:
    cols: List[str] = []
    for r in rows:
        for k in r:
            if k not in cols:
                cols.append(k)
    return cols


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name)
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=_all_cols(rows), restval="")
            w.writeheader()
            w.writerows(rows)
    return path


def print_table(title: str, rows: List[Dict]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    cols = _all_cols(rows)
    print(" | ".join(f"{c:>18s}" for c in cols))
    for r in rows:
        print(" | ".join(f"{str(r.get(c, '')):>18s}" for c in cols))
