"""Paper Figure 4: FedMMD (two-stream + MMD) vs FedAvg vs two-stream-L2.

Four panels: (a) CIFAR-like non-IID 2-client class split, (b) CIFAR-like
IID, (c) MNIST-like non-IID binary split, (d) MNIST-like 100-client shard
split (C=0.1, B=10, E=2).  The paper's claims:
  * non-IID: FedMMD reaches target accuracy in ~20% fewer rounds
  * IID: FedMMD ~= FedAvg (MMD's role is weakened)
  * L2 two-stream underperforms (constraint choice matters)
"""
from __future__ import annotations

from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import (artificial_noniid_partition,
                                  class_split_partition, iid_partition)

from benchmarks.common import (bench_cnn, best_acc, cifar_like, mnist_like,
                               print_table, round_records, rounds_to_acc,
                               run_fl, write_csv)

ALGOS = ("fedavg", "fedmmd", "fedl2")


def _panel(name, bundle, data, fl_base, rounds, target, seed=0):
    rows = []
    for algo in ALGOS:
        import dataclasses
        fl = dataclasses.replace(fl_base, algorithm=algo)
        res = run_fl(bundle, data, fl, rounds, seed=seed)
        hist = round_records(res.comm, save_as=f"fig4_{name}_{algo}.jsonl")
        rows.append({
            "panel": name, "algorithm": algo,
            "rounds_to_target": rounds_to_acc(hist, target),
            "target": target,
            "best_acc": round(best_acc(hist), 4),
            "final_acc": round(hist[-1].get("acc", 0.0), 4),
        })
    base = next(r for r in rows if r["algorithm"] == "fedavg")
    for r in rows:
        bt, rt = base["rounds_to_target"], r["rounds_to_target"]
        r["round_reduction_vs_fedavg"] = (
            round(1 - rt / bt, 3) if bt > 0 and rt > 0 else "n/a")
    return rows


def run(quick: bool = True):
    rounds = 20 if quick else 60
    n_per = 40 if quick else 80
    rows = []

    # (a) CIFAR-like, 2-client 5+5 class split (paper §4.2.1 non-IID)
    x, y = cifar_like(n_per)
    xt, yt = cifar_like(20, seed=1)
    data = FederatedDataset(class_split_partition(x, y, 2),
                            {"x": xt, "y": yt})
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=4,
                  local_batch=32, lr=0.08, mmd_lambda=0.1, l2_lambda=0.01)
    rows += _panel("a_cifar_noniid", bench_cnn("cifar", quick), data, fl,
                   rounds, target=0.55)

    # (b) CIFAR-like, IID
    data = FederatedDataset(iid_partition(x, y, 2), {"x": xt, "y": yt})
    rows += _panel("b_cifar_iid", bench_cnn("cifar", quick), data, fl,
                   rounds, target=0.55)

    # (c) MNIST-like, 2-client binary class split
    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    data = FederatedDataset(class_split_partition(x, y, 2),
                            {"x": xt, "y": yt})
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=4,
                  local_batch=32, lr=0.08, mmd_lambda=0.1, l2_lambda=0.001)
    rows += _panel("c_mnist_noniid", bench_cnn("mnist", quick), data, fl,
                   rounds, target=0.6)

    # (d) MNIST-like, 100-client 2-shard split, C = 0.1 (paper §4.2.2)
    n_clients = 20 if quick else 100
    data = FederatedDataset(
        artificial_noniid_partition(x, y, n_clients, shards_per_client=2),
        {"x": xt, "y": yt})
    fl = FLConfig(algorithm="fedavg", clients_per_round=max(2, n_clients // 10),
                  local_steps=4, local_batch=10, lr=0.08, mmd_lambda=0.1,
                  l2_lambda=0.001)
    rows += _panel("d_mnist_shards", bench_cnn("mnist", quick), data, fl,
                   rounds, target=0.6)

    write_csv("fig4_fedmmd.csv", rows)
    print_table("Fig 4 — FedMMD vs FedAvg vs L2 (rounds to target acc)", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
