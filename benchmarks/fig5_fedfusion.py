"""Paper Figure 5 + Table 1: FedFusion (conv/multi/single) vs FedAvg.

Panels: (a,b) artificial non-IID CIFAR-like splits, (c) user-specific
non-IID (permuted MNIST-like — see table2_milestones for the milestone
table), (d) IID CIFAR-like.  Claims: `multi` leads on artificial non-IID;
`multi`/`conv` beat FedAvg on IID; convergence accuracy (Table 1) is
matched or improved.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import (artificial_noniid_partition, iid_partition,
                                  permuted_partition)

from benchmarks.common import (bench_cnn, best_acc, cifar_like, mnist_like,
                               permuted_union_test, print_table,
                               round_records, rounds_to_acc, run_fl,
                               write_csv)

VARIANTS = (("fedavg", "none"), ("fedfusion", "single"),
            ("fedfusion", "multi"), ("fedfusion", "conv"))


def _panel(name, bundle, data, fl_base, rounds, target, seed=0):
    rows = []
    for algo, op in VARIANTS:
        fl = dataclasses.replace(fl_base, algorithm=algo,
                                 fusion_op=op if op != "none" else "multi")
        variant = op if algo == "fedfusion" else "fedavg"
        res = run_fl(bundle, data, fl, rounds, seed=seed)
        hist = round_records(res.comm, save_as=f"fig5_{name}_{variant}.jsonl")
        rows.append({
            "panel": name,
            "variant": variant,
            "rounds_to_target": rounds_to_acc(hist, target),
            "target": target,
            "best_acc": round(best_acc(hist), 4),      # Table 1 analogue
            "final_acc": round(hist[-1].get("acc", 0.0), 4),
            "bytes_up_per_round": hist[-1]["bytes_up"],
        })
    base = next(r for r in rows if r["variant"] == "fedavg")
    for r in rows:
        bt, rt = base["rounds_to_target"], r["rounds_to_target"]
        r["round_reduction_vs_fedavg"] = (
            round(1 - rt / bt, 3) if bt > 0 and rt > 0 else "n/a")
    return rows


def run(quick: bool = True):
    rounds = 20 if quick else 60
    n_per = 40 if quick else 80
    rows = []

    # (a) artificial non-IID CIFAR-like: 8 clients x 2 shards
    x, y = cifar_like(n_per)
    xt, yt = cifar_like(20, seed=1)
    data = FederatedDataset(
        artificial_noniid_partition(x, y, 8, shards_per_client=2),
        {"x": xt, "y": yt})
    fl = FLConfig(algorithm="fedavg", clients_per_round=4, local_steps=4,
                  local_batch=32, lr=0.08, lr_decay=0.985, ema_beta=0.5)
    rows += _panel("a_artificial_noniid", bench_cnn("cifar", quick), data,
                   fl, rounds, target=0.5)

    # (b) artificial non-IID, fewer shards (harder split)
    data = FederatedDataset(
        artificial_noniid_partition(x, y, 8, shards_per_client=1),
        {"x": xt, "y": yt})
    rows += _panel("b_artificial_noniid_1shard", bench_cnn("cifar", quick),
                   data, fl, rounds, target=0.45)

    # (c) user-specific non-IID: permuted MNIST-like.  The test set is the
    # union of the client permutations applied to held-out images.
    xm, ym = mnist_like(n_per)
    xmt, ymt = mnist_like(20, seed=1)
    parts = permuted_partition(xm, ym, 8)
    data = FederatedDataset(parts, permuted_union_test(xmt, ymt, parts))
    flm = dataclasses.replace(fl, lr=0.06, lr_decay=0.99)
    rows += _panel("c_user_specific", bench_cnn("mnist", quick), data, flm,
                   rounds, target=0.5)

    # (d) IID CIFAR-like
    data = FederatedDataset(iid_partition(x, y, 8), {"x": xt, "y": yt})
    rows += _panel("d_iid", bench_cnn("cifar", quick), data, fl, rounds,
                   target=0.55)

    write_csv("fig5_fedfusion.csv", rows)
    print_table("Fig 5 / Table 1 — FedFusion operators vs FedAvg", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
