"""Paper Figure 6: generalization to newly incoming clients.

After federated training, a fresh client (unseen permutation of the
user-specific partition) adapts locally; we count local epochs to reach a
convergence threshold.  The paper claims FedFusion+conv initializes the
newcomer best (fewest local epochs).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import permuted_partition
from repro.fl.newclient import newclient_convergence

from benchmarks.common import (bench_cnn, mnist_like, permuted_union_test,
                               print_table, run_fl, write_csv)

VARIANTS = (("fedavg", "none"), ("fedfusion", "single"),
            ("fedfusion", "multi"), ("fedfusion", "conv"))


def run(quick: bool = True):
    rounds = 15 if quick else 50
    epochs = 6 if quick else 15
    n_per = 40 if quick else 80

    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    bundle = bench_cnn("mnist", quick)

    # the newcomer: same class structure, fresh permutation (seed 1234)
    new_parts = permuted_partition(x, y, 1, seed=1234)
    newcomer = {"x": new_parts[0]["x"], "y": new_parts[0]["y"]}

    rows = []
    for algo, op in VARIANTS:
        parts = permuted_partition(x, y, 8)
        data = FederatedDataset(parts, permuted_union_test(xt, yt, parts))
        fl = FLConfig(algorithm=algo,
                      fusion_op=op if op != "none" else "multi",
                      clients_per_round=4, local_steps=4, local_batch=32,
                      lr=0.06, lr_decay=0.99)
        res = run_fl(bundle, data, fl, rounds)
        accs = newclient_convergence(bundle, fl, res.global_state, newcomer,
                                     epochs=epochs, batch=32, lr=0.06)
        conv_target = 0.8 * max(accs) if max(accs) > 0 else 1.0
        ep = next((i + 1 for i, a in enumerate(accs) if a >= conv_target),
                  -1)
        rows.append({
            "variant": op if algo == "fedfusion" else "fedavg",
            "epochs_to_converge": ep,
            "first_epoch_acc": round(accs[0], 4),
            "final_epoch_acc": round(accs[-1], 4),
        })

    write_csv("fig6_newclient.csv", rows)
    print_table("Fig 6 — local epochs to convergence for a new client", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
