"""Fig. 7 (extension): accuracy vs cumulative uplink bytes with wire codecs.

The paper counts communication rounds; with ``repro.compress`` the y-axis
becomes real wire MB.  Sweep {fedavg, fedmmd, fedfusion} x {identity,
int8, topk+EF} on the artificial non-IID partition and report, per
algorithm, the cumulative uplink bytes to the accuracy milestone and the
reduction vs the identity codec.  CFedAvg/RingFed-style result: top-k with
client error feedback reaches the milestone with a fraction of the bytes
and no accuracy loss.

``--adaptive`` runs the in-superstep controller comparison instead
(``repro.control``): every rung of a 3-level top-k ladder as a STATIC
run, then the ``ef_ratio`` controller scheduling over the same ladder —
and gates ``adaptive_bytes_to_milestone <= best static`` (non-zero exit
on regression; ``benchmarks/artifacts/fig7_result.json`` embeds the
verdict, ``fig7_adaptive_schedule.jsonl`` the per-round schedule).
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import artificial_noniid_partition

from benchmarks.common import (ART_DIR, bench_cnn, best_acc, mnist_like,
                               print_table, round_records, run_fl,
                               write_csv)

ALGOS = ("fedavg", "fedmmd", "fedfusion")
CODECS = ("identity", "int8", "topk")
TOPK_FRAC = 1.0 / 16.0
# --adaptive: the ladder the controller schedules over (ascending; top =
# TOPK_FRAC so the capacity level IS the static sweep's topk codec)
LADDER = (TOPK_FRAC / 4.0, TOPK_FRAC / 2.0, TOPK_FRAC)


def bytes_to_acc(hist: List[Dict], target: float) -> int:
    """Cumulative uplink bytes when the milestone is first reached (-1 if
    never)."""
    for h in hist:
        if h.get("acc", -1.0) >= target:
            return h["cum_bytes_up"]
    return -1


def run(quick: bool = True):
    rounds = 14 if quick else 60
    n_per = 32 if quick else 100
    milestone = 0.55 if quick else 0.6

    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    bundle = bench_cnn("mnist", quick)

    rows = []
    for algo in ALGOS:
        base_bytes = None
        for codec in CODECS:
            parts = artificial_noniid_partition(x, y, 8)
            data = FederatedDataset(parts, {"x": xt, "y": yt})
            fl = FLConfig(algorithm=algo, fusion_op="conv",
                          clients_per_round=4, local_steps=4,
                          local_batch=32, lr=0.06, lr_decay=0.99,
                          uplink_codec=codec, topk_frac=TOPK_FRAC)
            res = run_fl(bundle, data, fl, rounds)
            hist = round_records(res.comm,
                                 save_as=f"fig7_{algo}_{codec}.jsonl")
            b = bytes_to_acc(hist, milestone)
            row = {"algo": algo, "uplink": codec,
                   "best_acc": round(best_acc(hist), 4),
                   "mb_up_total": round(res.comm.bytes_up / 1e6, 3),
                   "mb_to_milestone": round(b / 1e6, 3) if b > 0 else "n/a"}
            if codec == "identity":
                base_bytes = b
            row["bytes_reduction"] = (
                f"{base_bytes / b:.1f}x" if b > 0 and base_bytes
                and base_bytes > 0 else "n/a")
            rows.append(row)

    write_csv("fig7_compression.csv", rows)
    print_table(f"Fig 7 — uplink bytes to acc>={milestone}, "
                "artificial non-IID", rows)
    return rows


def run_adaptive(quick: bool = True) -> Dict:
    """Bytes-to-milestone: best static ladder rung vs the adaptive
    controller on the same ladder (the CI-gated extension)."""
    import json
    import os

    from repro.obs.report import schedule_summary

    rounds = 14 if quick else 60
    n_per = 32 if quick else 100
    milestone = 0.55 if quick else 0.6

    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    bundle = bench_cnn("mnist", quick)

    def one(frac: float, controller: str = "static"):
        parts = artificial_noniid_partition(x, y, 8)
        data = FederatedDataset(parts, {"x": xt, "y": yt})
        fl = FLConfig(algorithm="fedavg", fusion_op="conv",
                      clients_per_round=4, local_steps=4, local_batch=32,
                      lr=0.06, lr_decay=0.99, uplink_codec="topk",
                      topk_frac=frac, controller=controller,
                      ladder=LADDER if controller != "static" else ())
        return run_fl(bundle, data, fl, rounds)

    rows = []
    static_bytes: Dict[str, int] = {}
    for frac in LADDER:
        res = one(frac)
        hist = round_records(
            res.comm, save_as=f"fig7_static_f{round(1 / frac)}.jsonl")
        b = bytes_to_acc(hist, milestone)
        static_bytes[f"{frac:.6f}"] = b
        rows.append({"run": f"static topk 1/{round(1 / frac)}",
                     "best_acc": round(best_acc(hist), 4),
                     "mb_up_total": round(res.comm.bytes_up / 1e6, 3),
                     "mb_to_milestone": round(b / 1e6, 3) if b > 0
                     else "n/a"})

    res = one(TOPK_FRAC, controller="ef_ratio")
    hist = round_records(res.comm, save_as="fig7_adaptive_schedule.jsonl")
    b_ad = bytes_to_acc(hist, milestone)
    sched = schedule_summary(hist)
    rows.append({"run": "adaptive ef_ratio",
                 "best_acc": round(best_acc(hist), 4),
                 "mb_up_total": round(res.comm.bytes_up / 1e6, 3),
                 "mb_to_milestone": round(b_ad / 1e6, 3) if b_ad > 0
                 else "n/a"})

    reached = [b for b in static_bytes.values() if b > 0]
    best_static = min(reached) if reached else -1
    beats = b_ad > 0 and (best_static < 0 or b_ad <= best_static)
    result = {"milestone": milestone, "rounds": rounds,
              "ladder": list(LADDER),
              "static_bytes_to_milestone": static_bytes,
              "best_static_bytes_to_milestone": best_static,
              "adaptive_bytes_to_milestone": b_ad,
              "adaptive_beats_static": beats,
              "schedule": sched}
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "fig7_result.json"), "w") as f:
        json.dump(result, f, indent=2)

    write_csv("fig7_adaptive.csv", rows)
    print_table(f"Fig 7 (adaptive) — uplink bytes to acc>={milestone}, "
                "static ladder rungs vs ef_ratio controller", rows)
    print(f"adaptive_beats_static={beats} "
          f"(adaptive={b_ad}, best_static={best_static})")
    return result


if __name__ == "__main__":
    import sys
    if "--adaptive" in sys.argv:
        result = run_adaptive(quick="--full" not in sys.argv)
        sys.exit(0 if result["adaptive_beats_static"] else 1)
    run(quick="--full" not in sys.argv)
