"""Fig. 7 (extension): accuracy vs cumulative uplink bytes with wire codecs.

The paper counts communication rounds; with ``repro.compress`` the y-axis
becomes real wire MB.  Sweep {fedavg, fedmmd, fedfusion} x {identity,
int8, topk+EF} on the artificial non-IID partition and report, per
algorithm, the cumulative uplink bytes to the accuracy milestone and the
reduction vs the identity codec.  CFedAvg/RingFed-style result: top-k with
client error feedback reaches the milestone with a fraction of the bytes
and no accuracy loss.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import artificial_noniid_partition

from benchmarks.common import (bench_cnn, best_acc, mnist_like, print_table,
                               round_records, run_fl, write_csv)

ALGOS = ("fedavg", "fedmmd", "fedfusion")
CODECS = ("identity", "int8", "topk")
TOPK_FRAC = 1.0 / 16.0


def bytes_to_acc(hist: List[Dict], target: float) -> int:
    """Cumulative uplink bytes when the milestone is first reached (-1 if
    never)."""
    for h in hist:
        if h.get("acc", -1.0) >= target:
            return h["cum_bytes_up"]
    return -1


def run(quick: bool = True):
    rounds = 14 if quick else 60
    n_per = 32 if quick else 100
    milestone = 0.55 if quick else 0.6

    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    bundle = bench_cnn("mnist", quick)

    rows = []
    for algo in ALGOS:
        base_bytes = None
        for codec in CODECS:
            parts = artificial_noniid_partition(x, y, 8)
            data = FederatedDataset(parts, {"x": xt, "y": yt})
            fl = FLConfig(algorithm=algo, fusion_op="conv",
                          clients_per_round=4, local_steps=4,
                          local_batch=32, lr=0.06, lr_decay=0.99,
                          uplink_codec=codec, topk_frac=TOPK_FRAC)
            res = run_fl(bundle, data, fl, rounds)
            hist = round_records(res.comm,
                                 save_as=f"fig7_{algo}_{codec}.jsonl")
            b = bytes_to_acc(hist, milestone)
            row = {"algo": algo, "uplink": codec,
                   "best_acc": round(best_acc(hist), 4),
                   "mb_up_total": round(res.comm.bytes_up / 1e6, 3),
                   "mb_to_milestone": round(b / 1e6, 3) if b > 0 else "n/a"}
            if codec == "identity":
                base_bytes = b
            row["bytes_reduction"] = (
                f"{base_bytes / b:.1f}x" if b > 0 and base_bytes
                and base_bytes > 0 else "n/a")
            rows.append(row)

    write_csv("fig7_compression.csv", rows)
    print_table(f"Fig 7 — uplink bytes to acc>={milestone}, "
                "artificial non-IID", rows)
    return rows


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
