"""Fig. 8 (extension): time-to-accuracy under straggling clients.

The paper's round-count metric silently assumes every sampled client
reports every round.  Under heavy-tailed client speeds (the regime every
cross-device FL deployment measures) a synchronous round is as slow as
its slowest participant, so *rounds* and *wall-clock* decouple.  This
benchmark injects a deterministic straggler/fault schedule
(``repro.data.federated.ChaosConfig``: lognormal per-client speeds,
per-round jitter, dropouts) into the engine and compares the built-in
participation policies (``repro.fl.participation``) on the artificial
non-IID partition:

* ``full_sync``  — wait for every surviving client (the paper's model);
* ``deadline``   — over-provision the cohort, close at the C-th arrival;
* ``buffered_async`` — close at the K-th arrival, staleness-discount
  late contributions FedBuff-style.

The x-axis is cumulative *simulated* time: each round's ``sim_time`` (the
policy's closing time, in units of a nominal client round) accumulated
until the global model first reaches the accuracy milestone.  The
headline result — deadline / buffered-async reach the milestone in less
simulated time than full_sync at (near-)equal rounds — is embedded in
``benchmarks/artifacts/fig8_result.json`` so CI can assert it, and the
per-round histories stream to ``fig8_<policy>.jsonl`` for
``benchmarks.obs_report``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

from repro.configs.base import FLConfig
from repro.data.federated import ChaosConfig, FederatedDataset
from repro.data.partition import artificial_noniid_partition
from repro.fl.server import run_federated

from benchmarks.common import (ART_DIR, bench_cnn, best_acc, mnist_like,
                               print_table, round_records, write_csv)

POLICIES = ("full_sync", "deadline", "buffered_async")

# heavy-tailed straggling: lognormal(sigma=1.2) speeds put the slowest of
# a 4-client cohort ~5-10x behind the median; 5% dropouts on top
CHAOS = ChaosConfig(speed_sigma=1.2, jitter=0.15, dropout=0.05,
                    truncation=0.0, seed=17)


def sim_time_to_acc(hist: List[Dict], target: float) -> float:
    """Cumulative simulated time when the milestone is first reached
    (-1.0 if never)."""
    t = 0.0
    for h in hist:
        t += h.get("sim_time", 1.0)
        if h.get("acc", -1.0) >= target:
            return t
    return -1.0


def run(quick: bool = True):
    rounds = 16 if quick else 60
    n_per = 32 if quick else 100
    milestone = 0.5 if quick else 0.6
    n_clients, per_round = 8, 4

    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    bundle = bench_cnn("mnist", quick)
    base_fl = FLConfig(algorithm="fedavg", clients_per_round=per_round,
                       local_steps=4, local_batch=32, lr=0.06,
                       lr_decay=0.99)

    rows, times = [], {}
    for policy in POLICIES:
        parts = artificial_noniid_partition(x, y, n_clients)
        data = FederatedDataset(parts, {"x": xt, "y": yt}, seed=0,
                                chaos=CHAOS)
        fl = dataclasses.replace(base_fl, participation=policy,
                                 over_provision=1.5, buffer_k=2,
                                 staleness_alpha=0.5)
        res = run_federated(bundle, fl, data, rounds=rounds, seed=0,
                            eval_every=1, telemetry=True)
        hist = round_records(res.comm, save_as=f"fig8_{policy}.jsonl")
        t = sim_time_to_acc(hist, milestone)
        times[policy] = t
        total_t = sum(h.get("sim_time", 1.0) for h in hist)
        rows.append({
            "policy": policy,
            "cohort": res.stats["round_cohort"],
            "best_acc": round(best_acc(hist), 4),
            "sim_time_to_acc": round(t, 3) if t >= 0 else -1,
            "total_sim_time": round(total_t, 3),
            "mean_eff_cohort": round(
                sum(h.get("tele/effective_cohort", per_round)
                    for h in hist) / len(hist), 2),
            "mb_up": round(res.comm.bytes_up / 1e6, 3),
        })

    base_t = times["full_sync"]
    for row in rows:
        t = times[row["policy"]]
        row["speedup_vs_sync"] = (round(base_t / t, 3)
                                  if t > 0 and base_t > 0 else -1)
    print_table("Fig. 8: time-to-accuracy under stragglers "
                f"(milestone {milestone})", rows)
    write_csv("fig8_stragglers.csv", rows)

    result = {
        "milestone": milestone,
        "rounds": rounds,
        "chaos": {"speed_sigma": CHAOS.speed_sigma, "jitter": CHAOS.jitter,
                  "dropout": CHAOS.dropout, "seed": CHAOS.seed},
        "sim_time_to_acc": {r["policy"]: r["sim_time_to_acc"]
                            for r in rows},
        "speedup_vs_sync": {r["policy"]: r["speedup_vs_sync"]
                            for r in rows},
        # the headline claim, machine-checkable: at least one async-ish
        # policy reaches the milestone in less simulated time than
        # full_sync (both must have reached it at all)
        "async_beats_sync": bool(
            base_t > 0 and any(
                0 < times[p] < base_t
                for p in ("deadline", "buffered_async"))),
    }
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "fig8_result.json"), "w") as f:
        json.dump(result, f, indent=2)
    print(f"fig8: async_beats_sync={result['async_beats_sync']} "
          f"(sync t={base_t:.2f}, "
          f"deadline t={times['deadline']:.2f}, "
          f"buffered t={times['buffered_async']:.2f})")
    return rows


if __name__ == "__main__":
    import sys
    run(quick="--full" not in sys.argv)
