"""Kernel microbenchmarks + the paper's "little extra computation" claim.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock numbers come from the jnp reference path; the Pallas path is
checked for agreement at each benched shape.  On TPU the same harness
times the compiled kernels (impl='pallas').

Second table: per-local-step cost of fedavg vs fedmmd vs fedfusion on the
paper's CNN — the paper argues the extra mechanisms add little compute
relative to a communication round.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.local import make_local_trainer
from repro.kernels import ops
from repro.models.registry import make_bundle

from benchmarks.common import bench_cnn, print_table, write_csv

WIDTHS = (1.0, 2.0, 4.0, 8.0, 16.0)


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_mmd(quick):
    shapes = [(64, 128), (128, 512)] if quick else [
        (64, 128), (128, 512), (256, 1024), (512, 2048)]
    rows = []
    f = jax.jit(lambda a, b: ops.mk_mmd2(a, b, WIDTHS, impl="jnp"))
    for n, d in shapes:
        kx, ky = jax.random.split(jax.random.PRNGKey(n))
        x = jax.random.normal(kx, (n, d))
        y = jax.random.normal(ky, (n, d))
        us = _time(f, x, y)
        # interpret-mode agreement at this shape
        err = abs(float(ops.mk_mmd2(x, y, WIDTHS, impl="pallas_interpret")
                        - f(x, y)))
        flops = 3 * (2 * n * n * d)  # three gram matrices
        rows.append({"kernel": "mk_mmd2", "shape": f"{n}x{d}",
                     "us_per_call": round(us, 1),
                     "gflops_s": round(flops / us / 1e3, 2),
                     "pallas_abs_err": f"{err:.2e}"})
    return rows


def bench_fusion(quick):
    shapes = [(1024, 64), (4096, 256)] if quick else [
        (1024, 64), (4096, 256), (16384, 512), (8192, 1024)]
    rows = []
    f = jax.jit(lambda a, b, w: ops.fused_fusion_conv(a, b, w, impl="jnp"))
    for t, c in shapes:
        ks = jax.random.split(jax.random.PRNGKey(t), 3)
        fg = jax.random.normal(ks[0], (t, c))
        fl = jax.random.normal(ks[1], (t, c))
        w = jax.random.normal(ks[2], (2 * c, c)) / np.sqrt(2 * c)
        us = _time(f, fg, fl, w)
        from repro.kernels.fusion_conv import fusion_conv
        err = float(jnp.abs(fusion_conv(fg, fl, w, interpret=True)
                            - f(fg, fl, w)).max())
        flops = 2 * t * 2 * c * c
        rows.append({"kernel": "fusion_conv", "shape": f"{t}x{c}",
                     "us_per_call": round(us, 1),
                     "gflops_s": round(flops / us / 1e3, 2),
                     "pallas_abs_err": f"{err:.2e}"})
    return rows


def bench_decode(quick):
    shapes = [(4, 2048, 8, 2, 64)] if quick else [
        (4, 2048, 8, 2, 64), (8, 8192, 8, 1, 64), (16, 4096, 16, 4, 128)]
    rows = []
    f = jax.jit(lambda q, k, v: ops.gqa_flash_decode(q, k, v, impl="jnp"))
    for B, L, H, KV, hd in shapes:
        ks = jax.random.split(jax.random.PRNGKey(L), 3)
        q = jax.random.normal(ks[0], (B, 1, H, hd))
        k = jax.random.normal(ks[1], (B, L, KV, hd))
        v = jax.random.normal(ks[2], (B, L, KV, hd))
        us = _time(f, q, k, v)
        bytes_ = 2 * B * L * KV * hd * 4
        rows.append({"kernel": "flash_decode",
                     "shape": f"B{B}_L{L}_H{H}_KV{KV}",
                     "us_per_call": round(us, 1),
                     "gbytes_s": round(bytes_ / us / 1e3, 2),
                     "pallas_abs_err": "tested_in_pytest"})
    return rows


def bench_ef_scatter(quick):
    """EF table row gather/scatter (repro.engine): jnp oracle timing +
    interpret-mode Pallas agreement.  Shapes: [n_clients, n_params] tables
    with a round's worth of sampled rows."""
    shapes = [(64, 8, 1 << 14)] if quick else [
        (64, 8, 1 << 14), (128, 16, 1 << 16), (256, 32, 1 << 18)]
    rows_out = []
    g = jax.jit(lambda t, i: ops.ef_gather(t, i, impl="jnp"))
    s = jax.jit(lambda t, i, r: ops.ef_scatter(t, i, r, impl="jnp"),
                donate_argnums=(0,))
    for N, k, n in shapes:
        ks = jax.random.split(jax.random.PRNGKey(n % 1009), 3)
        idx = jax.random.permutation(ks[1], N)[:k].astype(jnp.int32)
        rows = jax.random.normal(ks[2], (k, n))

        def make_table():
            return jax.random.normal(ks[0], (N, n))

        table = make_table()
        us_g = _time(g, table, idx)
        # donation consumes the table: pre-build one per rep, time only s()
        s(make_table(), idx, rows)     # compile
        reps = 5
        tables = [make_table() for _ in range(reps)]
        jax.block_until_ready(tables)
        t0 = time.perf_counter()
        for t_in in tables:
            out = s(t_in, idx, rows)
        jax.block_until_ready(out)
        us_s = (time.perf_counter() - t0) / reps * 1e6
        table = make_table()
        err_g = float(jnp.abs(
            ops.ef_gather(table, idx, impl="pallas_interpret")
            - g(table, idx)).max())
        err_s = float(jnp.abs(
            ops.ef_scatter(table, idx, rows, impl="pallas_interpret")
            - ops.ef_scatter(table, idx, rows, impl="jnp")).max())
        bytes_g = k * n * 4 * 2
        rows_out.append({"kernel": "ef_gather", "shape": f"{N}x{n}_k{k}",
                         "us_per_call": round(us_g, 1),
                         "gbytes_s": round(bytes_g / us_g / 1e3, 2),
                         "pallas_abs_err": f"{err_g:.2e}"})
        rows_out.append({"kernel": "ef_scatter(+donate)",
                         "shape": f"{N}x{n}_k{k}",
                         "us_per_call": round(us_s, 1),
                         "gbytes_s": round(bytes_g / us_s / 1e3, 2),
                         "pallas_abs_err": f"{err_s:.2e}"})
    return rows_out


def bench_two_stream_overhead(quick):
    """Wall-clock per local step: the paper's compute-overhead claim."""
    bundle = bench_cnn("mnist", quick=True)
    rows = []
    key = jax.random.PRNGKey(0)
    batch = {"x": jax.random.normal(key, (8, 32, 28, 28, 1)),
             "y": jax.random.randint(key, (8, 32), 0, 10)}
    for algo, op in (("fedavg", "multi"), ("fedmmd", "multi"),
                     ("fedl2", "multi"), ("fedfusion", "conv"),
                     ("fedfusion", "multi")):
        fl = FLConfig(algorithm=algo, fusion_op=op, local_steps=8, lr=0.05)
        from repro.core.rounds import init_global_state
        state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
        trainer = jax.jit(make_local_trainer(bundle, fl))
        args = (state["model"], state.get("fusion"), batch, jnp.float32(0.05))
        trainer(*args)  # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            out = trainer(*args)
            jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / reps / 8 * 1e6
        rows.append({"kernel": f"local_step[{algo}"
                               + (f"+{op}]" if algo == "fedfusion" else "]"),
                     "shape": "B32_mnist_cnn", "us_per_call": round(us, 1),
                     "gflops_s": "", "pallas_abs_err": ""})
    base = rows[0]["us_per_call"]
    for r in rows:
        r["overhead_vs_fedavg"] = f"{(r['us_per_call'] / base - 1) * 100:.0f}%"
    return rows


def run(quick: bool = True):
    rows = (bench_mmd(quick) + bench_fusion(quick) + bench_decode(quick)
            + bench_ef_scatter(quick) + bench_two_stream_overhead(quick))
    write_csv("kernels_bench.csv", rows)
    print_table("Kernel microbenchmarks (CPU jnp path; Pallas checked)", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
