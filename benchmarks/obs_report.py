"""CLI over ``repro.obs.report``: run JSONL files -> a readable report.

Feed it the two artifacts an instrumented engine run leaves behind —
the :class:`repro.obs.RunLog` span/event stream (``--runlog``) and the
:meth:`repro.fl.comm.CommLog.save` round history (``--comm``); either
alone works.  Prints the rendered report and, with ``--out``, writes the
full report dict as JSON (the same shape ``bench_engine.py`` embeds
under its ``observability`` key).  Cohort-paged runs
(``ef_store="host"``) additionally get an ``ef_page`` section — rows
gathered/written back/patched, gather seconds on the dispatch thread and
writeback seconds on the lane's worker thread — folded from the
``ef.page.*`` counters and spans the engine emits.

    PYTHONPATH=src python -m benchmarks.obs_report \
        --runlog benchmarks/artifacts/runlog.jsonl \
        --comm benchmarks/artifacts/comm.jsonl

Stdlib-only on purpose: reports must be buildable on any machine the
JSONL was copied to, no jax required.
"""
from __future__ import annotations

import argparse
import json

from repro.obs.report import build_report, render


def _load_jsonl(path):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def main():
    ap = argparse.ArgumentParser(
        description="summarize an instrumented engine run")
    ap.add_argument("--runlog", default=None, metavar="JSONL",
                    help="RunLog span/event stream (engine runlog=PATH)")
    ap.add_argument("--comm", default=None, metavar="JSONL",
                    help="CommLog.save round history")
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="also write the report dict as JSON")
    args = ap.parse_args()
    if not args.runlog and not args.comm:
        ap.error("need --runlog and/or --comm")

    runlog_records = _load_jsonl(args.runlog) if args.runlog else None
    comm_records = None
    if args.comm:
        comm_records = [r for r in _load_jsonl(args.comm)
                        if r.get("kind") == "round"]
    report = build_report(runlog_records, comm_records)
    print(render(report))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
