"""Collate dry-run artifacts into the §Roofline table (EXPERIMENTS.md).

Reads benchmarks/artifacts/dryrun/*.json (written by repro.launch.dryrun)
and prints/writes the per-(arch x shape x mesh) three-term roofline table:
compute / memory / collective seconds, dominant bottleneck, MODEL_FLOPS
ratio, per-chip bytes.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART_DIR, print_table, write_csv

DRY_DIR = os.path.join(ART_DIR, "dryrun")


def load_records():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = True):
    del quick
    rows = []
    for r in load_records():
        row = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
               "variant": r.get("tag", "baseline"),
               "status": r["status"]}
        if r["status"] == "ok":
            rl = r["roofline"]
            row.update({
                "t_compute_ms": round(rl["t_compute"] * 1e3, 2),
                "t_memory_ms": round(rl["t_memory"] * 1e3, 2),
                "t_collective_ms": round(rl["t_collective"] * 1e3, 2),
                "bottleneck": rl["bottleneck"],
                "useful_ratio": round(rl["useful_ratio"], 3),
                "mfu_bound": round(rl["mfu_bound"], 3),
                "GB_per_chip": round(r["bytes_per_chip"] / 1e9, 2),
                "fits_16GB": r["fits_16gb_hbm"],
            })
        elif r["status"] == "skip":
            row["bottleneck"] = f"SKIP: {r['reason'][:40]}"
        else:
            row["bottleneck"] = f"ERROR: {r.get('error', '?')[:40]}"
        rows.append(row)
    if rows:
        write_csv("roofline_report.csv", rows)
    print_table("Roofline (from dry-run artifacts)", rows)
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skip")
    err = len(rows) - ok - skip
    print(f"\n{ok} compiled, {skip} skipped (documented), {err} errors")
    return rows


if __name__ == "__main__":
    run()
