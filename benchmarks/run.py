"""Benchmark driver: one harness per paper table/figure + kernels + roofline.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick mode (CI/CPU)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale rounds
    PYTHONPATH=src python -m benchmarks.run --only fig4,kernels
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (fig4_fedmmd, fig5_fedfusion, fig6_newclient,
                        fig7_compression, fig8_stragglers, kernels_bench,
                        roofline_report, table2_milestones)

SUITES = {
    "fig4": fig4_fedmmd.run,          # FedMMD vs FedAvg vs L2
    "fig5": fig5_fedfusion.run,       # FedFusion operators + Table 1
    "table2": table2_milestones.run,  # rounds-to-milestone reductions
    "fig6": fig6_newclient.run,       # new-client generalization
    "fig7": fig7_compression.run,     # wire codecs: acc vs uplink bytes
    "fig8": fig8_stragglers.run,      # straggler policies: sim-time-to-acc
    "kernels": kernels_bench.run,     # kernel microbench + overhead claim
    "roofline": roofline_report.run,  # collate dry-run artifacts
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds (slow on CPU)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(SUITES))
    args = ap.parse_args()

    names = [n.strip() for n in args.only.split(",") if n.strip()] or \
        list(SUITES)
    t0 = time.time()
    for name in names:
        t = time.time()
        print(f"\n##### {name} " + "#" * 50)
        SUITES[name](quick=not args.full)
        print(f"[{name}: {time.time() - t:.1f}s]")
    print(f"\nAll benchmarks done in {time.time() - t0:.1f}s; "
          f"CSV artifacts in benchmarks/artifacts/")


if __name__ == "__main__":
    main()
