"""Paper Table 2: communication rounds to accuracy milestones on the
user-specific non-IID partition (permuted MNIST analogue).

FedAvg is the reference; the paper reports FedFusion+conv cutting rounds by
>60% to the 94%/95% milestones.  With the synthetic stand-in we use two
milestones placed at moderate/high accuracy for the task and report the
same reduction metric.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import permuted_partition

from benchmarks.common import (bench_cnn, best_acc, mnist_like,
                               permuted_union_test, print_table,
                               round_records, rounds_to_acc, run_fl,
                               write_csv)

VARIANTS = (("fedavg", "none"), ("fedfusion", "single"),
            ("fedfusion", "multi"), ("fedfusion", "conv"))


def run(quick: bool = True):
    rounds = 25 if quick else 80
    n_per = 40 if quick else 100
    milestones = (0.5, 0.6)

    x, y = mnist_like(n_per)
    xt, yt = mnist_like(20, seed=1)
    bundle = bench_cnn("mnist", quick)

    rows = []
    for algo, op in VARIANTS:
        parts = permuted_partition(x, y, 8)
        data = FederatedDataset(parts, permuted_union_test(xt, yt, parts))
        fl = FLConfig(algorithm=algo,
                      fusion_op=op if op != "none" else "multi",
                      clients_per_round=4, local_steps=4, local_batch=32,
                      lr=0.06, lr_decay=0.99)
        variant = op if algo == "fedfusion" else "fedavg"
        res = run_fl(bundle, data, fl, rounds)
        hist = round_records(res.comm, save_as=f"table2_{variant}.jsonl")
        row = {"variant": variant,
               "best_acc": round(best_acc(hist), 4)}
        for m in milestones:
            row[f"rounds_to_{int(m*100)}"] = rounds_to_acc(hist, m)
        rows.append(row)

    base = rows[0]
    for r in rows:
        for m in milestones:
            k = f"rounds_to_{int(m*100)}"
            bt, rt = base[k], r[k]
            r[f"reduce_{int(m*100)}"] = (
                f"{(1 - rt / bt) * 100:.1f}%" if bt > 0 and rt > 0 else "n/a")

    write_csv("table2_milestones.csv", rows)
    print_table("Table 2 — rounds to milestones, user-specific non-IID", rows)
    return rows


if __name__ == "__main__":
    run(quick=False)
