"""Paper Fig. 6 as a runnable example: how fast does a NEW client converge?

Trains a federated system on the user-specific (permuted) partition with
each algorithm, then drops in a never-seen client (fresh permutation) and
tracks its local-adaptation curve from the aggregated global state.

Run:  PYTHONPATH=src python examples/newclient_generalization.py
"""
import dataclasses

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import permuted_partition
from repro.data.synth import class_images
from repro.fl.newclient import newclient_convergence
from repro.fl.server import run_federated
from repro.models.registry import make_bundle

ROUNDS, EPOCHS = 12, 6

cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"], conv_channels=(8, 16),
                          fc_units=(64,), dropout=0.0)
bundle = make_bundle(cfg)

x, y = class_images(40, n_classes=10, shape=(28, 28, 1), seed=0, noise=0.2,
                    template_seed=0)
xt, yt = class_images(10, n_classes=10, shape=(28, 28, 1), seed=1, noise=0.2,
                      template_seed=0)

# the newcomer has a permutation no training client ever saw
new = permuted_partition(x, y, 1, seed=777)[0]

print(f"{'variant':18s} " + " ".join(f"ep{i+1:<6d}" for i in range(EPOCHS)))
for algo, op in [("fedavg", "multi"), ("fedfusion", "single"),
                 ("fedfusion", "multi"), ("fedfusion", "conv")]:
    fl = FLConfig(algorithm=algo, fusion_op=op, clients_per_round=4,
                  local_steps=6, local_batch=16, lr=0.08, lr_decay=0.99)
    data = FederatedDataset(permuted_partition(x, y, 8), {"x": xt, "y": yt})
    res = run_federated(bundle, fl, data, rounds=ROUNDS)
    accs = newclient_convergence(bundle, fl, res.global_state,
                                 {"x": new["x"], "y": new["y"]},
                                 epochs=EPOCHS, batch=16, lr=0.08)
    tag = op if algo == "fedfusion" else "fedavg"
    print(f"{tag:18s} " + " ".join(f"{a:.3f}  " for a in accs))
