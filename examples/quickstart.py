"""Quickstart: federated training with the paper's mechanisms in ~40 lines.

Trains the paper's MNIST CNN (width-reduced for CPU) on a synthetic
non-IID split with FedAvg, FedMMD and FedFusion, and prints the
communication-round savings — the paper's headline metric.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import artificial_noniid_partition
from repro.data.synth import class_images
from repro.fl.api import FederatedTrainer
from repro.models.registry import make_bundle

ROUNDS, TARGET = 15, 0.5

# 1. Model: the paper's CNN (§4.1.1), narrowed for CPU speed.
cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                          conv_channels=(8, 16), fc_units=(64,), dropout=0.0)
bundle = make_bundle(cfg)

# 2. Data: synthetic MNIST-like images, artificial non-IID partition
#    (each client holds ~2 classes — the paper's hardest split).
x, y = class_images(40, n_classes=10, shape=(28, 28, 1), seed=0, noise=0.2,
                    template_seed=0)
xt, yt = class_images(10, n_classes=10, shape=(28, 28, 1), seed=1, noise=0.2,
                      template_seed=0)
clients = artificial_noniid_partition(x, y, 8, shards_per_client=2)
data = FederatedDataset(clients, {"x": xt, "y": yt})

# 3. Train each algorithm (any repro.fl.api registry name works here —
#    the trainer resolves the plugin) and compare rounds-to-target.
results = {}
for algo, op in [("fedavg", "multi"), ("fedmmd", "multi"),
                 ("fedfusion", "conv")]:
    fl = FLConfig(algorithm=algo, fusion_op=op, clients_per_round=4,
                  local_steps=6, local_batch=16, lr=0.1, mmd_lambda=0.1)
    res = FederatedTrainer(bundle, fl, data).fit(ROUNDS)
    hist = res.comm.history
    to_target = next((h["round"] for h in hist if h.get("acc", 0) >= TARGET),
                     -1)
    results[algo] = (to_target, hist[-1]["acc"])
    print(f"{algo:10s} rounds_to_{TARGET:.0%}: {to_target:3d}   "
          f"final_acc: {hist[-1]['acc']:.3f}   "
          f"MB_uploaded: {res.comm.bytes_up / 1e6:.1f}")

base = results["fedavg"][0]
for algo, (rt, _) in results.items():
    if algo != "fedavg" and rt > 0 and base > 0:
        print(f"{algo}: {100 * (1 - rt / base):.0f}% fewer rounds than FedAvg")
