"""Serving example: batched prefill + autoregressive decode with KV cache.

Deploys the *global* model produced by federated training (any --arch, the
reduced variant on CPU), prefills a batch of prompts, then decodes tokens
one at a time through ``decode_step`` — the same code path the decode_32k /
long_500k dry-run shapes exercise on the production mesh.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-1b \
          --prompt-len 32 --gen-len 16 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_CONFIGS
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch].reduced()
    max_len = args.prompt_len + args.gen_len
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(cfg, key)

    # batch of synthetic prompts
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_vision_tokens,
                                    cfg.d_model))
    if cfg.family == "audio":
        batch["audio_frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (args.batch, cfg.n_audio_frames,
                                    cfg.d_model))

    # ---- prefill: one forward pass builds the KV/state cache --------------
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, b: tfm.forward_seq(
        cfg, p, b, want_cache=True, max_cache_len=max_len))
    out = prefill(params, batch)
    jax.block_until_ready(out["logits"])
    print(f"prefill[{args.batch}x{args.prompt_len}]: "
          f"{(time.perf_counter()-t0)*1e3:.0f} ms (incl. compile)")

    # ---- decode loop -------------------------------------------------------
    step = jax.jit(lambda p, t, c, pos: tfm.decode_step(cfg, p, t, c, pos))
    cache = out["cache"]
    last_logits = out["logits"][:, -1]
    toks = []
    key = jax.random.PRNGKey(7)
    t0 = time.perf_counter()
    for i in range(args.gen_len):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last_logits / args.temperature)
        else:
            nxt = jnp.argmax(last_logits, axis=-1)
        toks.append(nxt)
        logits, cache = step(params, nxt[:, None], cache,
                             jnp.int32(args.prompt_len + i))
        last_logits = logits[:, 0]
    jax.block_until_ready(last_logits)
    dt = time.perf_counter() - t0
    gen = jnp.stack(toks, axis=1)
    print(f"decode {args.gen_len} steps: {dt*1e3:.0f} ms "
          f"({dt/args.gen_len*1e3:.1f} ms/token incl. first-step compile)")
    print("generated token ids (first sequence):",
          [int(t) for t in gen[0]])


if __name__ == "__main__":
    main()
