"""End-to-end driver: federated training of a ~135M-class LM architecture.

Uses the smollm-135m config (reduced by --scale for CPU; full scale on a
real pod via launch/train.py) on a source-partitioned synthetic token
stream — the LM analogue of the paper's non-IID image splits — and runs a
few hundred FedAvg/FedMMD/FedFusion rounds, reporting loss + comm cost.

Run:  PYTHONPATH=src python examples/train_lm_federated.py \
          --algorithm fedfusion --fusion-op conv --rounds 300 --scale tiny
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.checkpoint.io import save_server_state
from repro.configs import ARCH_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import source_partition
from repro.data.synth import token_stream
from repro.fl.api import (ALGORITHM_NAMES, EvalOptions, FederatedTrainer,
                          RunOptions)
from repro.models.registry import make_bundle


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--algorithm", default="fedfusion",
                    choices=sorted(ALGORITHM_NAMES))
    ap.add_argument("--fusion-op", default="conv",
                    choices=("conv", "multi", "single"))
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--scale", default="tiny", choices=("tiny", "full"),
                    help="tiny = reduced() config for CPU; full = real size")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clients-per-round", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--save", default="",
                    help="directory to checkpoint the final server state")
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch]
    if args.scale == "tiny":
        cfg = dataclasses.replace(cfg.reduced(), vocab_size=256)
    bundle = make_bundle(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"algorithm={args.algorithm}")

    toks, src = token_stream(64 * args.clients, args.seq_len,
                             vocab=cfg.vocab_size, n_sources=args.clients)
    data = FederatedDataset(source_partition(toks, src, args.clients),
                            {"tokens": toks[:64]})

    fl = FLConfig(algorithm=args.algorithm, fusion_op=args.fusion_op,
                  clients_per_round=args.clients_per_round,
                  local_steps=args.local_steps,
                  local_batch=args.local_batch, lr=args.lr, lr_decay=0.995)
    trainer = FederatedTrainer(bundle, fl, data, RunOptions(
        verbose=True, eval=EvalOptions(every=args.eval_every, examples=64)))
    res = trainer.fit(args.rounds)
    print(f"\nuploaded {res.comm.bytes_up/1e6:.1f} MB over "
          f"{res.comm.rounds} rounds  "
          f"final eval: {trainer.evaluate()}")
    if args.save:
        save_server_state(args.save, res.global_state, res.comm.rounds,
                          extra={"algorithm": args.algorithm})
        print(f"saved server state to {args.save}")


if __name__ == "__main__":
    main()
