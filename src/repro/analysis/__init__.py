"""Static invariant analyzer for the jitted supersteps.

``repro.analysis`` checks the engine's structural contracts — one psum
per fused round, honored donations, no host syncs in the scan, f32 end
to end, HLO collective traffic equal to the bytes model — by walking
traced jaxprs and compiled HLO, without running a single training step.
Passes live in a registry (``register_pass`` / ``make_pass``) like the
repo's codec/algorithm/controller plugins; ``python -m repro.analysis``
runs a pass set over the config matrix and exits non-zero on violation.
"""
from repro.analysis.jaxprs import (COLLECTIVE_PRIMITIVES,  # noqa: F401
                                   HOST_SYNC_PRIMITIVES, collect_avals,
                                   collective_execution_model,
                                   count_collectives, count_primitives,
                                   find_primitives, iter_eqns,
                                   psum_payload_bytes, round_body,
                                   scan_bodies)
from repro.analysis.registry import (AnalysisFailure,  # noqa: F401
                                     AnalysisPass, Finding, make_pass,
                                     register_pass, registered_passes)
from repro.analysis.lower import (CODEC_CASES, LoweredSuperstep,  # noqa: F401
                                  SuperstepSpec, analysis_bundle,
                                  default_matrix, fl_for, lower_superstep)
from repro.analysis import passes as _passes  # noqa: F401 (registers)
from repro.analysis import lint as _lint      # noqa: F401 (registers)
from repro.analysis.runner import Report, run_analysis  # noqa: F401
