"""``python -m repro.analysis`` — run the invariant passes, emit JSON.

The sharded half of the matrix needs more than one XLA device.  When
the current process has only one (the usual CPU host), the CLI respawns
itself as a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the flag must
be set before jax initializes its backend, which has long since
happened by the time ``__main__`` runs — and merges the child's report
into its own.  Exit status: 0 clean, 1 findings or per-point errors,
2 usage errors.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from typing import List, Optional

from repro.analysis.lower import default_matrix
from repro.analysis.registry import (AnalysisFailure, make_pass,
                                     registered_passes)
from repro.analysis.runner import Report, run_analysis


def _forced_device_env(n: int) -> dict:
    env = dict(os.environ)
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in t]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n}"])
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = os.pathsep.join(
        [src] + [p for p in env.get("PYTHONPATH", "").split(os.pathsep)
                 if p])
    return env


def _run_sharded_subprocess(passes: List[str], preset: str,
                            devices: int) -> Report:
    """Re-run this CLI for the sharded points under forced devices."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
        report_path = tmp.name
    cmd = [sys.executable, "-m", "repro.analysis", "--scope", "sharded",
           "--preset", preset, "--passes", ",".join(passes),
           "--report", report_path, "--quiet"]
    try:
        proc = subprocess.run(cmd, env=_forced_device_env(devices),
                              capture_output=True, text=True, timeout=3600)
        if not os.path.exists(report_path) or \
                os.path.getsize(report_path) == 0:
            return Report(passes=passes, errors=[{
                "point": "<sharded subprocess>", "pass": "cli",
                "error": f"exit {proc.returncode}; no report written; "
                         f"stderr tail: {proc.stderr[-2000:]}"}])
        with open(report_path) as f:
            data = json.load(f)
    finally:
        try:
            os.unlink(report_path)
        except OSError:
            pass
    from repro.analysis.registry import Finding
    return Report(
        passes=data.get("passes", passes),
        points=data.get("points", {}),
        findings=[Finding(d["pass"], d["point"], d["message"],
                          severity=d.get("severity", "error"))
                  for d in data.get("findings", [])],
        errors=data.get("errors", []),
        elapsed_s=data.get("elapsed_s", 0.0))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant analyzer for the jitted supersteps")
    ap.add_argument("--passes", default=None,
                    help="comma-separated pass names (default: all "
                         "registered)")
    ap.add_argument("--preset", default="quick",
                    choices=("quick", "full"),
                    help="config-matrix size (default: quick)")
    ap.add_argument("--scope", default="all",
                    choices=("all", "unsharded", "sharded"),
                    help="restrict to un/sharded matrix points")
    ap.add_argument("--devices", type=int, default=2,
                    help="forced host device count for the sharded "
                         "subprocess (default: 2)")
    ap.add_argument("--report", default=None,
                    help="write the JSON report here")
    ap.add_argument("--list-passes", action="store_true",
                    help="list registered passes and exit")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the human-readable summary")
    args = ap.parse_args(argv)

    if args.list_passes:
        for name in registered_passes():
            p = make_pass(name)
            print(f"{name:18s} [{p.scope}]"
                  f"{' (compiles)' if p.needs_compiled else ''} "
                  f"{p.description}")
        return 0

    names = ([n.strip() for n in args.passes.split(",") if n.strip()]
             if args.passes else list(registered_passes()))
    try:
        instances = [make_pass(n) for n in names]
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2
    lowered_names = [p.name for p in instances if p.scope == "lowered"]

    import jax

    rep = Report(passes=names)
    # source passes + whatever lowered points this process can trace
    local_sharded = jax.device_count() >= 2
    if args.scope == "sharded":
        specs = default_matrix(args.preset, sharded=True)
        try:
            rep = run_analysis(specs, passes=names)
        except AnalysisFailure as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    else:
        specs = default_matrix(args.preset, sharded=False)
        if args.scope == "all" and local_sharded:
            specs = specs + default_matrix(args.preset, sharded=True)
        try:
            rep = run_analysis(specs, passes=names)
        except AnalysisFailure as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        if args.scope == "all" and not local_sharded and lowered_names:
            child = _run_sharded_subprocess(lowered_names, args.preset,
                                            args.devices)
            rep = rep.merged(child)

    if args.report:
        d = os.path.dirname(args.report)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(rep.to_json(), f, indent=2, sort_keys=True)

    if not args.quiet:
        print(f"repro.analysis: {len(rep.points)} point(s), passes "
              f"{','.join(rep.passes)}, {rep.elapsed_s:.1f}s")
        for f in rep.findings:
            print(f"FINDING {f}")
        for e in rep.errors:
            print(f"ERROR [{e.get('pass')}] {e.get('point')}: "
                  f"{e.get('error')}")
        print("OK" if rep.ok else
              f"VIOLATIONS: {len(rep.findings)} finding(s), "
              f"{len(rep.errors)} error(s)")
    return 0 if rep.ok else 1
