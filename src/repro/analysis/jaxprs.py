"""Jaxpr-walking substrate for the static invariant passes.

Every check in ``repro.analysis`` that operates before XLA — collective
counts, host-callback detection, dtype drift — is a walk over the traced
jaxpr of a superstep.  This module is the one place that walk lives:
``iter_eqns`` descends into every sub-jaxpr an equation carries (scan and
while bodies, cond branches, pjit/closed-call bodies, custom-vjp
closures), so a psum hidden three levels deep in a scanned round fn
counts exactly like a top-level one.

The public :func:`count_collectives` is the exported replacement for the
five copy-pasted ``count_psums`` helpers the subprocess invariant tests
grew between PR 5 and PR 9 — they now all import it from here.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import jax

# Cross-device collective primitives as they appear in jaxprs.  ``psum``
# is the only one the engine is ever allowed to emit; the rest are listed
# so a sneaky all_gather trips the same counters.
COLLECTIVE_PRIMITIVES: Tuple[str, ...] = (
    "psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
    "reduce_scatter", "ppermute", "pgather", "psum_scatter",
)

# Host-synchronizing primitives: anything that round-trips to Python or
# the host runtime from inside a traced computation.
HOST_SYNC_PRIMITIVES: Tuple[str, ...] = (
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
    "debug_print",
)


def _as_jaxpr(jaxpr):
    """Accept a Jaxpr or a ClosedJaxpr (``jax.make_jaxpr`` output)."""
    return getattr(jaxpr, "jaxpr", jaxpr)


def subjaxprs(jaxpr) -> Iterator:
    """Immediate sub-jaxprs referenced by ``jaxpr``'s equations."""
    is_sub = lambda x: hasattr(x, "eqns") or hasattr(x, "jaxpr")
    for eqn in _as_jaxpr(jaxpr).eqns:
        for v in eqn.params.values():
            for j in jax.tree_util.tree_leaves(v, is_leaf=is_sub):
                inner = (j.jaxpr if hasattr(j, "jaxpr")
                         else (j if hasattr(j, "eqns") else None))
                if inner is not None:
                    yield inner


def iter_eqns(jaxpr) -> Iterator:
    """Every equation in ``jaxpr`` and all nested sub-jaxprs."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
    for sub in subjaxprs(jaxpr):
        yield from iter_eqns(sub)


def count_primitives(jaxpr, names: Sequence[str]) -> int:
    """Number of equations (recursively) whose primitive name is in
    ``names``.  A scanned body counts ONCE — this is an equation count,
    not an execution count (scale by trip counts for the latter)."""
    names = frozenset(names)
    return sum(1 for eqn in iter_eqns(jaxpr) if eqn.primitive.name in names)


def count_collectives(jaxpr, names: Optional[Sequence[str]] = None) -> int:
    """Count cross-device collective equations in a (closed) jaxpr.

    The public psum counter the one-collective-per-round invariant tests
    are built on: with the default ``names`` every primitive in
    :data:`COLLECTIVE_PRIMITIVES` counts, so the assertion "exactly one"
    also proves no other collective flavour snuck in.  Pass
    ``names=("psum",)`` to count psums alone.
    """
    return count_primitives(jaxpr, COLLECTIVE_PRIMITIVES
                            if names is None else names)


def scan_bodies(jaxpr) -> List:
    """All ``lax.scan`` body jaxprs in ``jaxpr``, recursively."""
    out = []
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            out.append(eqn.params["jaxpr"].jaxpr)
    for sub in subjaxprs(jaxpr):
        out.extend(scan_bodies(sub))
    return out


def _scan_bodies_with_depth(jaxpr, depth=0):
    out = []
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "scan":
            out.append((depth, eqn.params["jaxpr"].jaxpr))
    for sub in subjaxprs(jaxpr):
        out.extend(_scan_bodies_with_depth(sub, depth + 1))
    return out


def round_body(jaxpr):
    """The K-round loop body of a superstep jaxpr.

    The round scan is the OUTERMOST scan — the one at the shallowest
    sub-jaxpr nesting depth (ties broken by most equations).  Depth, not
    size: the plain superstep's round body (aggregate + sgd step) has
    fewer equations than the per-local-step training scan nested inside
    it.  Returns None when the program has no scan at all (a ``K == 1``
    superstep bypasses ``lax.scan``; its "round body" is the whole
    jaxpr).
    """
    bodies = _scan_bodies_with_depth(jaxpr)
    if not bodies:
        return None
    d_min = min(d for d, _ in bodies)
    return max((b for d, b in bodies if d == d_min),
               key=lambda b: len(b.eqns))


def collect_avals(jaxpr) -> Iterator:
    """Every abstract value flowing through ``jaxpr``: inputs, outputs
    and all intermediate equation operands/results, recursively."""
    jaxpr = _as_jaxpr(jaxpr)
    for v in list(jaxpr.invars) + list(jaxpr.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None:
            yield aval
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None:
                yield aval
    for sub in subjaxprs(jaxpr):
        yield from collect_avals(sub)


def find_primitives(jaxpr, names: Sequence[str]) -> List:
    """The equations (recursively) whose primitive name is in ``names``."""
    names = frozenset(names)
    return [eqn for eqn in iter_eqns(jaxpr) if eqn.primitive.name in names]


def collective_execution_model(jaxpr, names: Optional[Sequence[str]] = None
                               ) -> Tuple[int, int]:
    """Trip-weighted ``(op_count, payload_bytes)`` of a jaxpr's
    collectives — the quantities the lowered HLO must agree with.

    Each collective equation contributes ``n_operands × trips`` ops and
    ``payload_bytes × trips`` bytes, where ``trips`` is the product of
    the ``length`` params of every enclosing ``lax.scan``: XLA lowers an
    n-ary psum to one all-reduce per operand (modulo combining, which
    the optimized-HLO byte total is invariant to), and a psum inside the
    K-round scan executes K times.  Cross-checked against
    :func:`repro.roofline.hlo.collective_bytes` /
    ``collective_op_counts`` by the analyzer's collective-bytes pass.
    """
    names = frozenset(COLLECTIVE_PRIMITIVES if names is None else names)

    def walk(jx, trips):
        ops = nbytes = 0
        jx = _as_jaxpr(jx)
        for eqn in jx.eqns:
            mult = trips
            if eqn.primitive.name == "scan":
                mult = trips * int(eqn.params["length"])
            if eqn.primitive.name in names:
                for v in eqn.invars:
                    aval = getattr(v, "aval", None)
                    if aval is not None and hasattr(aval, "shape"):
                        n = 1
                        for d in aval.shape:
                            n *= int(d)
                        ops += trips
                        nbytes += n * aval.dtype.itemsize * trips
            is_sub = lambda x: hasattr(x, "eqns") or hasattr(x, "jaxpr")
            for v in eqn.params.values():
                for j in jax.tree_util.tree_leaves(v, is_leaf=is_sub):
                    inner = (j.jaxpr if hasattr(j, "jaxpr")
                             else (j if hasattr(j, "eqns") else None))
                    if inner is not None:
                        o, b = walk(inner, mult)
                        ops += o
                        nbytes += b
        return ops, nbytes

    return walk(jaxpr, 1)


def psum_payload_bytes(jaxpr, names: Iterable[str] = ("psum",)) -> int:
    """Total bytes of collective OPERANDS in ``jaxpr`` (one trip each).

    For the fused superstep this is the packed flat-buffer size of each
    psum equation — the quantity the collective-bytes pass cross-checks
    against the lowered HLO's all-reduce payloads.
    """
    total = 0
    for eqn in find_primitives(jaxpr, tuple(names)):
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                n = 1
                for d in aval.shape:
                    n *= int(d)
                total += n * aval.dtype.itemsize
    return total
