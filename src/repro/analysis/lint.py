"""AST lint over ``src/repro`` — the analyzer's source-scope pass.

Three rules, each a repo-wide convention the jaxpr/HLO passes cannot
see:

* **bare-assert** — ``assert`` in library code vanishes under
  ``python -O``, silently skipping validation; library checks raise
  typed errors with messages.  (Tests are not linted — pytest asserts
  are the point there.)
* **algorithm-branch** — ``fl.algorithm == "..."`` (or literal-tuple
  membership) outside the plugin packages bypasses the
  ``repro.fl.api`` registry; new mechanisms come in through
  ``register_algorithm``, not core branches.  Comparisons against a
  NAME (e.g. ``algorithm not in ALGORITHM_NAMES`` registry validation)
  are fine.
* **local-import** — function-local imports of anything but ``repro``
  / ``jax`` modules: the deliberate lazy imports break import cycles or
  defer heavy deps, and those are all repro/jax; a stray local
  ``import os`` is just a hidden module dependency.

The allowlist (``"relpath"`` or ``"relpath:lineno"`` strings) exists as
a mechanism for incremental adoption — it ships EMPTY, and the tier-1
suite pins that it stays empty.
"""
from __future__ import annotations

import ast
import os
from typing import Iterator, List, Sequence, Tuple

from repro.analysis.registry import AnalysisPass, Finding, register_pass

# packages whose modules ARE the algorithm plugins — string dispatch on
# the algorithm name is their job (mirrors tests/test_api.py's old grep
# gate exclusions)
PLUGIN_PREFIXES = (os.path.join("fl", "api") + os.sep,
                   "contrib" + os.sep)

# import roots that may be deferred into function bodies (lazy
# cycle-breaking / optional heavy deps)
ALLOWED_LOCAL_IMPORT_ROOTS = ("repro", "jax")

ALLOWLIST: Tuple[str, ...] = ()   # stays empty; see module docstring


def src_root() -> str:
    """The ``src/repro`` directory this module was imported from."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def iter_source_files(root=None) -> Iterator[Tuple[str, str]]:
    """``(relpath, abspath)`` of every python file under ``src/repro``."""
    root = root or src_root()
    for dirpath, _, files in sorted(os.walk(root)):
        for fname in sorted(files):
            if fname.endswith(".py"):
                path = os.path.join(dirpath, fname)
                yield os.path.relpath(path, root), path


def _is_algo_name(node: ast.AST) -> bool:
    return ((isinstance(node, ast.Attribute) and node.attr == "algorithm")
            or (isinstance(node, ast.Name) and node.id == "algorithm"))


def _literal_strings(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, str)
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return all(isinstance(e, ast.Constant) and isinstance(e.value, str)
                   for e in node.elts)
    return False


def _algorithm_branches(tree: ast.AST) -> Iterator[ast.Compare]:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Compare):
            continue
        sides = [node.left] + list(node.comparators)
        if not any(_is_algo_name(s) for s in sides):
            continue
        for op, right in zip(node.ops, node.comparators):
            if isinstance(op, (ast.Eq, ast.NotEq)) and (
                    _literal_strings(right) or _literal_strings(node.left)):
                yield node
                break
            if isinstance(op, (ast.In, ast.NotIn)) \
                    and _literal_strings(right):
                yield node
                break


def _local_imports(tree: ast.AST) -> Iterator[Tuple[ast.stmt, str]]:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    root = alias.name.split(".")[0]
                    if root not in ALLOWED_LOCAL_IMPORT_ROOTS:
                        yield node, alias.name
            elif isinstance(node, ast.ImportFrom):
                root = (node.module or "").split(".")[0]
                if not node.level \
                        and root not in ALLOWED_LOCAL_IMPORT_ROOTS:
                    yield node, node.module or "."


@register_pass
class SourceLintPass(AnalysisPass):
    name = "source-lint"
    scope = "source"
    description = ("bare asserts, registry-bypassing algorithm branches "
                   "and non-repro/jax function-local imports in "
                   "src/repro")

    def __init__(self, root=None, allowlist: Sequence[str] = ALLOWLIST):
        self.root = root or src_root()
        self.allowlist = tuple(allowlist)

    def _allowed(self, rel: str, lineno: int) -> bool:
        return rel in self.allowlist or f"{rel}:{lineno}" in self.allowlist

    def run(self, target=None) -> List[Finding]:
        out = []
        for rel, path in iter_source_files(self.root):
            with open(path) as f:
                try:
                    tree = ast.parse(f.read(), filename=path)
                except SyntaxError as e:
                    out.append(self.finding(f"{rel}:{e.lineno}",
                                            f"syntax error: {e.msg}"))
                    continue
            for node in ast.walk(tree):
                if isinstance(node, ast.Assert) \
                        and not self._allowed(rel, node.lineno):
                    out.append(self.finding(
                        f"{rel}:{node.lineno}",
                        "bare assert in library code (skipped under "
                        "python -O); raise a typed error with a message"))
            if not rel.startswith(PLUGIN_PREFIXES):
                for node in _algorithm_branches(tree):
                    if not self._allowed(rel, node.lineno):
                        out.append(self.finding(
                            f"{rel}:{node.lineno}",
                            "string branch on the algorithm name outside "
                            "the plugin packages; dispatch through the "
                            "repro.fl.api registry"))
            for node, mod in _local_imports(tree):
                if not self._allowed(rel, node.lineno):
                    out.append(self.finding(
                        f"{rel}:{node.lineno}",
                        f"function-local import of {mod!r}; only lazy "
                        f"repro/jax imports may live inside functions"))
        return out
