"""Config matrix and superstep lowering for the invariant analyzer.

The analyzer never runs training: every lowered pass works on the traced
jaxpr (and optionally the compiled executable) of a superstep built for
one :class:`SuperstepSpec` — a point in the mode × codec × telemetry ×
participation × controller × ef_store × sharding matrix.  This module
owns that construction so the passes, the CLI and the tests all lower
the exact program the engine would jit, with the exact donations
(:func:`repro.engine.superstep.donation_argnums`) and the exact abstract
argument layout (:func:`repro.engine.superstep.abstract_superstep_args`).

The fixture is deliberately tiny (the tests' 8×8 CNN, 8 clients) —
invariants like "one psum per round body" are shape-independent, and a
small model keeps tracing the full matrix cheap enough for CI.
"""
from __future__ import annotations

import dataclasses
import math
import warnings as _warnings
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax

from repro.analysis.registry import AnalysisFailure
from repro.compress import make_codec
from repro.control import (LadderSpec, ladder_kind, ladder_values,
                           make_controller)
from repro.core.rounds import init_global_state
from repro.engine.sharded import client_sharding, make_sharded_superstep
from repro.engine.superstep import (abstract_superstep_args,
                                    donation_argnums,
                                    make_compressed_superstep,
                                    make_plain_superstep)
from repro.launch.mesh import make_engine_mesh
from repro.obs.telemetry import make_telemetry

# Fixture federation: mirrors tests/test_engine.py so every pinned count
# in the subprocess invariant tests and every analyzer expectation talk
# about the same traced program family.
N_CLIENTS = 8
CLIENTS_PER_ROUND = 4
INPUT_SHAPE = (8, 8, 1)

# Codec cases (fl overrides per case), the same axis the engine tests
# sweep: identity wire, stateful top-k EF, stateless int8, asymmetric
# int8-up/topk-down, and the fedfusion algorithm on a top-k wire.
CODEC_CASES = {
    "plain": dict(),
    "topk": dict(uplink_codec="topk", topk_frac=0.1),
    "int8": dict(uplink_codec="int8"),
    "quant+downtopk": dict(uplink_codec="int8", downlink_codec="topk",
                           topk_frac=0.1),
    "fusion-topk": dict(algorithm="fedfusion", fusion_op="conv",
                        uplink_codec="topk", topk_frac=0.1),
}

_BUNDLE = None


def analysis_bundle():
    """The analyzer's model fixture: the tests' tiny 8×8 CNN."""
    global _BUNDLE
    if _BUNDLE is None:
        from repro.configs import CNN_CONFIGS
        from repro.models.registry import make_bundle
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                                  input_shape=INPUT_SHAPE,
                                  conv_channels=(4,), fc_units=(8,),
                                  dropout=0.0)
        _BUNDLE = make_bundle(cfg)
    return _BUNDLE


@dataclass(frozen=True)
class SuperstepSpec:
    """One point of the analysis matrix.

    ``codec`` keys :data:`CODEC_CASES`; ``controller`` is a
    ``repro.control`` registry name (``"static"`` = off); ``fused`` only
    matters when ``sharded`` (the unsharded superstep has no collectives
    at all); ``ef_store="host"`` lowers against the cohort-paged EF page
    layout instead of the dense/resident table.
    """
    mode: str = "client_parallel"
    codec: str = "plain"
    sharded: bool = False
    fused: bool = True
    telemetry: bool = False
    participation: bool = False
    controller: str = "static"
    ef_store: str = "device"
    n_rounds: int = 4

    @property
    def compressed(self) -> bool:
        return bool(CODEC_CASES[self.codec])

    @property
    def point(self) -> str:
        """Stable id for reports/findings."""
        bits = [self.mode, self.codec,
                ("fused" if self.fused else "unfused") if self.sharded
                else "unsharded"]
        if self.telemetry:
            bits.append("tele")
        if self.participation:
            bits.append("part")
        if self.controller != "static":
            bits.append(f"ctrl={self.controller}")
        if self.ef_store != "device":
            bits.append(f"ef={self.ef_store}")
        return "/".join(bits)


def fl_for(spec: SuperstepSpec):
    """The :class:`FLConfig` the engine would run at this matrix point."""
    from repro.configs.base import FLConfig
    kw = dict(CODEC_CASES[spec.codec])
    algo = kw.pop("algorithm", "fedavg")
    if spec.participation:
        kw.update(participation="deadline", over_provision=1.5)
    if spec.controller != "static":
        kw.update(controller=spec.controller)
    return FLConfig(algorithm=algo, clients_per_round=CLIENTS_PER_ROUND,
                    local_steps=2, local_batch=4, lr=0.05, **kw)


@dataclass
class LoweredSuperstep:
    """A superstep traced (and lazily compiled) at one matrix point.

    ``fn`` is the pre-jit callable (already ``shard_map``-wrapped when
    sharded), ``args`` the abstract argument tuple, ``jaxpr`` the closed
    jaxpr of ``fn(*args)``.  ``compiled_text`` compiles with the
    engine's donations and returns the optimized HLO module text (what
    ``repro.roofline.hlo`` parses); compile-time warnings — XLA's
    "donated buffer was not usable" in particular — are captured into
    ``compile_warnings``.
    """
    spec: SuperstepSpec
    fl: object
    fn: object
    args: Tuple
    donate_argnums: Tuple[int, ...]
    cohort: int
    ef_rows: Optional[int] = None
    uplink: object = None
    downlink: object = None
    controller: object = None
    mesh: object = None
    wire_up: Optional[int] = None
    wire_down: Optional[int] = None
    level_bytes: Optional[Tuple[int, ...]] = None
    _jaxpr: object = field(default=None, repr=False)
    _hlo: Optional[str] = field(default=None, repr=False)
    compile_warnings: List[str] = field(default_factory=list, repr=False)

    @property
    def point(self) -> str:
        return self.spec.point

    @property
    def jaxpr(self):
        if self._jaxpr is None:
            self._jaxpr = jax.make_jaxpr(self.fn)(*self.args)
        return self._jaxpr

    @property
    def compiled_text(self) -> str:
        if self._hlo is None:
            with _warnings.catch_warnings(record=True) as caught:
                _warnings.simplefilter("always")
                compiled = jax.jit(
                    self.fn, donate_argnums=self.donate_argnums
                ).lower(*self.args).compile()
            self.compile_warnings = [str(w.message) for w in caught]
            self._hlo = compiled.as_text()
        return self._hlo

    @property
    def ideal_model_bytes(self) -> int:
        """Uncompressed f32 wire bytes of one model delta (the CommLog
        'ideal' baseline every codec's wire bytes are charged against)."""
        state = self.args[0]
        total = 0
        for leaf in jax.tree.leaves(state["model"]):
            total += math.prod(leaf.shape) * 4
        return total


def _ef_rows(spec: SuperstepSpec, cohort: int, n_shards: int) -> int:
    """Leading row count of the EF table argument for this layout."""
    K = spec.n_rounds
    if spec.ef_store == "host":        # cohort-paged: one page per chunk
        page = K * cohort
        return (page + 1) * n_shards if spec.sharded else page
    if spec.sharded:                    # resident scratch-row layout
        return (N_CLIENTS // n_shards + 1) * n_shards
    return N_CLIENTS                    # dense table


def lower_superstep(spec: SuperstepSpec, *, inner_wrap=None,
                    donate="engine") -> LoweredSuperstep:
    """Build + abstractly trace the superstep at one matrix point.

    ``inner_wrap`` threads through to
    :func:`repro.engine.sharded.make_sharded_superstep` (sharded) or
    wraps the superstep directly (unsharded) — the mutation tests use it
    to seed violations.  ``donate="engine"`` uses the engine's
    :func:`donation_argnums`; pass ``()`` to lower without donation
    (how the donation pass seeds its own violation).
    """
    if spec.codec not in CODEC_CASES:
        raise AnalysisFailure(f"unknown codec case {spec.codec!r}; have "
                              f"{tuple(sorted(CODEC_CASES))}")
    bundle = analysis_bundle()
    fl = fl_for(spec)
    compressed = spec.compressed
    ctrl_active = compressed and spec.controller != "static"

    mesh = shard = None
    n_shards = 1
    if spec.sharded:
        if jax.device_count() < 2:
            raise AnalysisFailure(
                "sharded analysis points need >= 2 devices; relaunch "
                "under XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "(the repro.analysis CLI does this automatically)")
        mesh = make_engine_mesh()
        shard = client_sharding(mesh)
        n_shards = shard.n_shards

    from repro.fl.participation import make_policy
    cohort = CLIENTS_PER_ROUND
    if spec.participation:
        cohort = make_policy(fl.participation).cohort_size(
            CLIENTS_PER_ROUND, fl)
    if spec.sharded and cohort % n_shards:
        raise AnalysisFailure(f"cohort {cohort} does not divide over "
                              f"{n_shards} shards at {spec.point}")

    uplink = downlink = controller = None
    ef_rows = wire_up = wire_down = level_bytes = None
    if compressed:
        uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac,
                            quant_bits=fl.quant_bits)
        downlink = make_codec(fl.downlink_codec, topk_frac=fl.topk_frac,
                              quant_bits=fl.quant_bits)
        state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                               jax.random.PRNGKey(0))
        uplink.bind(state["model"])
        downlink.bind(state["model"])
        wire_up = uplink.wire_bytes()
        wire_down = downlink.wire_bytes()
        if ctrl_active:
            ladder = ladder_values(fl)
            uplink.set_ladder(ladder)
            level_bytes = tuple(uplink.level_bytes())
            ctrl_spec = LadderSpec(kind=ladder_kind(fl.uplink_codec),
                                   values=ladder, bytes_up=level_bytes)
            controller = make_controller(spec.controller).setup(ctrl_spec,
                                                                fl)
        ef_rows = _ef_rows(spec, cohort, n_shards)

    tele = None
    if spec.telemetry or ctrl_active:
        tele = make_telemetry(
            "compressed" if compressed else "plain", n_clients=cohort,
            n_shards=n_shards,
            available=frozenset(
                (("ef",) if compressed and uplink.stateful else ())
                + (("pmask", "staleness") if spec.participation else ())
                + (("level", "eff_bytes") if ctrl_active else ())))
        if ctrl_active:
            have = {t.name for t in tele.taps}
            missing = [n for n in controller.requires_taps
                       if n not in have]
            if missing:
                raise AnalysisFailure(
                    f"controller {spec.controller!r} needs taps {missing} "
                    f"unavailable for codec {spec.codec!r} at {spec.point}")

    if spec.sharded:
        fn = make_sharded_superstep(
            bundle, fl, spec.mode, spec.n_rounds, mesh, uplink=uplink,
            downlink=downlink, fused_collective=spec.fused, telemetry=tele,
            participation=spec.participation, controller=controller,
            inner_wrap=inner_wrap)
    else:
        if compressed:
            fn = make_compressed_superstep(
                bundle, fl, spec.mode, spec.n_rounds, uplink, downlink,
                telemetry=tele, participation=spec.participation,
                controller=controller)
        else:
            fn = make_plain_superstep(
                bundle, fl, spec.mode, spec.n_rounds, telemetry=tele,
                participation=spec.participation)
        if inner_wrap is not None:
            fn = inner_wrap(fn)

    args = abstract_superstep_args(
        bundle, fl, spec.n_rounds, cohort=cohort, uplink=uplink,
        ef_rows=ef_rows, participation=spec.participation,
        controller=controller, input_shape=INPUT_SHAPE)

    if donate == "engine":
        # the analyzer's points lower on whatever backend is present, but
        # they model the engine's accelerator posture: staged chunk
        # arrays donate (host_staged=True) except on CPU, exactly as
        # engine.get_step decides at runtime
        donate = donation_argnums(
            compressed=compressed, participation=spec.participation,
            controller=ctrl_active,
            host_staged=jax.default_backend() != "cpu")
    return LoweredSuperstep(
        spec=spec, fl=fl, fn=fn, args=args, donate_argnums=tuple(donate),
        cohort=cohort, ef_rows=ef_rows, uplink=uplink, downlink=downlink,
        controller=controller, mesh=mesh, wire_up=wire_up,
        wire_down=wire_down, level_bytes=level_bytes)


def default_matrix(preset: str = "quick", *,
                   sharded: Optional[bool] = None) -> List[SuperstepSpec]:
    """The analyzer's config matrix.

    A covering design, not a full cross-product: a base mode × codec
    grid with everything else off, one point per extra feature
    (telemetry / participation / each controller / paged EF store), and
    everything-on points — ~12 specs for ``"quick"``, ~30 for
    ``"full"``.  ``sharded`` filters: True keeps only sharded points
    (what the CLI runs in its forced-device subprocess), False only
    unsharded ones.
    """
    if preset not in ("quick", "full"):
        raise AnalysisFailure(f"unknown preset {preset!r}")
    S = SuperstepSpec
    specs: List[SuperstepSpec] = []
    # base grid: every codec unsharded, plus the sharded fused points
    for codec in CODEC_CASES:
        specs.append(S(codec=codec))
        specs.append(S(codec=codec, sharded=True))
    # the three-collective oracle layout
    specs.append(S(codec="topk", sharded=True, fused=False))
    # single-feature points on the stateful-EF wire
    specs.append(S(codec="topk", telemetry=True))
    specs.append(S(codec="topk", sharded=True, telemetry=True))
    specs.append(S(codec="topk", sharded=True, participation=True))
    specs.append(S(codec="topk", sharded=True, controller="ef_ratio"))
    specs.append(S(codec="topk", ef_store="host"))
    specs.append(S(codec="topk", sharded=True, ef_store="host"))
    # everything on
    specs.append(S(codec="topk", sharded=True, telemetry=True,
                   participation=True, controller="ef_ratio",
                   ef_store="host"))
    if preset == "full":
        specs.append(S(mode="client_sequential", codec="topk"))
        specs.append(S(mode="client_sequential", codec="topk",
                       sharded=True))
        specs.append(S(codec="plain", sharded=True, fused=False))
        specs.append(S(codec="quant+downtopk", sharded=True, fused=False))
        specs.append(S(codec="fusion-topk", sharded=True, fused=False))
        specs.append(S(codec="topk", participation=True))
        specs.append(S(codec="topk", controller="ef_ratio"))
        specs.append(S(codec="topk", controller="bytes_budget"))
        specs.append(S(codec="topk", controller="loss_trend"))
        specs.append(S(codec="topk", sharded=True,
                       controller="bytes_budget"))
        specs.append(S(codec="topk", sharded=True,
                       controller="loss_trend"))
        specs.append(S(codec="quant+downtopk", sharded=True,
                       telemetry=True))
        specs.append(S(codec="topk", sharded=True, fused=False,
                       telemetry=True, participation=True,
                       controller="ef_ratio"))
        specs.append(S(codec="topk", telemetry=True, participation=True,
                       controller="ef_ratio", ef_store="host"))
    if sharded is not None:
        specs = [s for s in specs if s.sharded == sharded]
    return specs
