"""Built-in lowered-superstep invariant passes.

Each pass inspects one :class:`repro.analysis.lower.LoweredSuperstep`
(a config point of the analysis matrix) and returns findings.  The
expectations they pin are the engine's structural contracts:

* ``collectives`` — the fused sharded round body executes exactly ONE
  psum (the paper's one-collective-per-round claim), the whole superstep
  exactly two (prologue + body), the unfused oracle at least the
  three-collective layout, unsharded programs none at all — and nothing
  but psums anywhere;
* ``donation`` — every buffer the engine donates (model state, EF
  table/page, broadcast mirror, lr slice, controller scalars) is
  actually aliased input→output in the compiled executable, with no
  donation-unused warnings and no hidden copy of the EF page;
* ``host-sync`` — no callback / infeed / outfeed primitive anywhere in
  the traced superstep: one host sync per CHUNK is the engine's whole
  performance story;
* ``dtype`` — no f64 (or complex128) value anywhere in the trace, and
  every collective operand is exactly f32: silent x64 promotion through
  a codec would double wire bytes and break the bytes model quietly;
* ``collective-bytes`` — the trip-weighted all-reduce count and payload
  bytes of the optimized HLO (``repro.roofline.hlo``) equal the
  jaxpr-level execution model exactly, and the codec wire model charged
  by the CommLog stays consistent (compressed < ideal f32 bytes, ladder
  monotone with its top rung at the static wire bytes).
"""
from __future__ import annotations

from collections import Counter
from typing import List

import jax

from repro.analysis.jaxprs import (COLLECTIVE_PRIMITIVES,
                                   HOST_SYNC_PRIMITIVES,
                                   collect_avals,
                                   collective_execution_model,
                                   count_collectives, find_primitives,
                                   round_body)
from repro.analysis.registry import AnalysisPass, Finding, register_pass

# jax dtype -> HLO shape-prefix, for matching donated leaves against the
# compiled module's entry parameters
_HLO_DTYPES = {"float32": "f32", "float64": "f64", "float16": "f16",
               "bfloat16": "bf16", "int64": "s64", "int32": "s32",
               "int16": "s16", "int8": "s8", "uint64": "u64",
               "uint32": "u32", "uint16": "u16", "uint8": "u8",
               "bool": "pred"}

# per-device sharding of the superstep arguments: argnum -> the axis the
# client shards split (absent/None = replicated).  Positions follow
# ``abstract_superstep_args``; only donated argnums are ever looked up.
_SHARDED_AXIS_COMPRESSED = {1: 0, 3: 1, 4: 1, 9: 1, 10: 1}
_SHARDED_AXIS_PLAIN = {1: 1, 2: 1, 4: 1, 5: 1}


@register_pass
class CollectivesPass(AnalysisPass):
    name = "collectives"
    scope = "lowered"
    description = ("exactly one psum per fused round body (2 per "
                   "superstep), >= 3 for the unfused oracle, 0 "
                   "unsharded; psum is the only collective flavour")

    def run(self, low) -> List[Finding]:
        out = []
        spec = low.spec
        jx = low.jaxpr
        total = count_collectives(jx)
        psums = count_collectives(jx, names=("psum",))
        if total != psums:
            out.append(self.finding(
                low.point, f"{total - psums} non-psum collective(s) in the "
                f"superstep jaxpr — psum is the only collective the engine "
                f"may emit"))
        if not spec.sharded:
            if total:
                out.append(self.finding(
                    low.point, f"unsharded superstep traced {total} "
                    f"collective(s); a 1-shard program must have none"))
            return out
        body = round_body(jx)
        if body is None:
            out.append(self.finding(
                low.point, "no round scan found in the superstep jaxpr"))
            return out
        n_body = count_collectives(body)
        if spec.fused:
            if n_body != 1:
                out.append(self.finding(
                    low.point, f"fused round body has {n_body} collectives, "
                    f"invariant is exactly 1 (the packed psum)"))
            if total != 2:
                out.append(self.finding(
                    low.point, f"fused superstep has {total} collective "
                    f"equations, invariant is exactly 2 (prologue + round "
                    f"body)"))
        else:
            if n_body < 3:
                out.append(self.finding(
                    low.point, f"unfused round body has {n_body} "
                    f"collectives; the three-collective oracle layout "
                    f"expects >= 3"))
            if total != n_body:
                out.append(self.finding(
                    low.point, f"unfused superstep has {total - n_body} "
                    f"collective(s) outside the round body; the oracle "
                    f"layout keeps every exchange inside the round"))
        return out


@register_pass
class HostSyncPass(AnalysisPass):
    name = "host-sync"
    scope = "lowered"
    description = ("no callback / infeed / outfeed / debug primitive "
                   "anywhere in the traced superstep")

    def run(self, low) -> List[Finding]:
        eqns = find_primitives(low.jaxpr, HOST_SYNC_PRIMITIVES)
        return [self.finding(
            low.point, f"host-synchronizing primitive "
            f"{eqn.primitive.name!r} in the traced superstep — the engine "
            f"syncs with the host once per chunk, never inside the scan")
            for eqn in eqns]


@register_pass
class DtypePass(AnalysisPass):
    name = "dtype"
    scope = "lowered"
    description = ("no f64/complex128 anywhere in the trace; collective "
                   "operands are exactly f32")

    def run(self, low) -> List[Finding]:
        out = []
        seen64 = Counter()
        for aval in collect_avals(low.jaxpr):
            dt = str(getattr(aval, "dtype", ""))
            if dt in ("float64", "complex128"):
                seen64[dt] += 1
        for dt, n in sorted(seen64.items()):
            out.append(self.finding(
                low.point, f"{n} {dt} value(s) in the traced superstep — "
                f"silent x64 promotion (the engine is f32 end to end)"))
        for eqn in find_primitives(low.jaxpr, COLLECTIVE_PRIMITIVES):
            for v in eqn.invars:
                dt = str(getattr(getattr(v, "aval", None), "dtype", ""))
                if dt and dt != "float32":
                    out.append(self.finding(
                        low.point, f"collective {eqn.primitive.name!r} "
                        f"carries a {dt} operand; the packed wire buffer "
                        f"must stay f32"))
        return out


def _expected_aliased_shapes(low) -> Counter:
    """Multiset of per-device ``"dtype[dims]"`` strings the compiled
    module must alias — one per donated argument leaf."""
    spec = low.spec
    n_shards = 1
    if spec.sharded:
        from repro.engine.sharded import client_sharding
        n_shards = client_sharding(low.mesh).n_shards
    axis_of = (_SHARDED_AXIS_COMPRESSED if spec.compressed
               else _SHARDED_AXIS_PLAIN)
    expect = Counter()
    for argnum in low.donate_argnums:
        axis = axis_of.get(argnum)
        for leaf in jax.tree.leaves(low.args[argnum]):
            dims = list(leaf.shape)
            if spec.sharded and axis is not None and dims:
                dims[axis] //= n_shards
            dt = _HLO_DTYPES.get(str(leaf.dtype), str(leaf.dtype))
            expect[f"{dt}[{','.join(str(d) for d in dims)}]"] += 1
    return expect


@register_pass
class DonationPass(AnalysisPass):
    name = "donation"
    scope = "lowered"
    needs_compiled = True
    description = ("every engine-donated buffer is input->output aliased "
                   "in the compiled executable (no dropped donations, no "
                   "hidden EF-page copies, no donation-unused warnings)")

    def run(self, low) -> List[Finding]:
        from repro.roofline.hlo import entry_io_aliases, entry_param_shapes
        out = []
        text = low.compiled_text
        aliases = entry_io_aliases(text)
        params = entry_param_shapes(text)
        expect = _expected_aliased_shapes(low)
        n_expected = sum(expect.values())
        if len(aliases) != n_expected:
            out.append(self.finding(
                low.point, f"compiled executable aliases {len(aliases)} "
                f"buffer(s), but the engine donates {n_expected} leaves "
                f"({low.donate_argnums}) — donation dropped or a hidden "
                f"copy inserted"))
        aliased_params = {p for _, p in aliases}
        if len(aliased_params) != len(aliases):
            out.append(self.finding(
                low.point, "a parameter is aliased to two outputs in "
                "input_output_alias — malformed donation"))
        got = Counter()
        for _, p in aliases:
            if p < len(params):
                dt, dims = params[p]
                got[f"{dt}[{dims}]"] += 1
        if params and got != expect:
            missing = expect - got
            extra = got - expect
            out.append(self.finding(
                low.point, f"aliased buffer shapes differ from the donated "
                f"leaves: missing {dict(missing)} unexpected {dict(extra)}"))
        for w in low.compile_warnings:
            if "donat" in w.lower():
                out.append(self.finding(
                    low.point, f"donation warning at compile time: {w}"))
        return out


@register_pass
class CollectiveBytesPass(AnalysisPass):
    name = "collective-bytes"
    scope = "lowered"
    needs_compiled = True
    description = ("lowered HLO all-reduce count/bytes == the jaxpr "
                   "execution model; codec wire model consistent "
                   "(compressed < ideal, ladder monotone)")

    def run(self, low) -> List[Finding]:
        from repro.roofline.hlo import collective_summary
        out = []
        spec = low.spec
        # wire-model audit runs everywhere (it needs no device program)
        ideal = low.ideal_model_bytes
        if low.uplink is not None:
            if low.wire_up > ideal:
                out.append(self.finding(
                    low.point, f"uplink codec charges {low.wire_up} wire "
                    f"bytes, above the ideal f32 model ({ideal}) — the "
                    f"compression accounting is inverted"))
            if low.wire_down is not None and low.wire_down > ideal:
                out.append(self.finding(
                    low.point, f"downlink codec charges {low.wire_down} > "
                    f"ideal {ideal} wire bytes"))
        if low.level_bytes is not None:
            lv = low.level_bytes
            if list(lv) != sorted(lv):
                out.append(self.finding(
                    low.point, f"ladder level_bytes {lv} not ascending"))
            if lv and low.wire_up is not None and lv[-1] != low.wire_up:
                out.append(self.finding(
                    low.point, f"ladder top rung charges {lv[-1]} bytes, "
                    f"static wire model charges {low.wire_up} — the "
                    f"capacity rung must BE the configured codec"))
        if not spec.sharded:
            return out
        ops, nbytes = collective_execution_model(low.jaxpr)
        hlo = collective_summary(low.compiled_text)
        other = {k: v for k, v in hlo.items() if k != "all-reduce"}
        if other:
            out.append(self.finding(
                low.point, f"compiled module contains non-all-reduce "
                f"collectives {other}; psum lowers to all-reduce only"))
        hlo_ops, hlo_bytes = hlo.get("all-reduce", (0, 0))
        if (hlo_ops, hlo_bytes) != (ops, nbytes):
            out.append(self.finding(
                low.point, f"HLO all-reduce model ({hlo_ops} ops, "
                f"{hlo_bytes} B) != jaxpr execution model ({ops} ops, "
                f"{nbytes} B) — XLA inserted or dropped collective "
                f"traffic the bytes model does not account for"))
        return out
