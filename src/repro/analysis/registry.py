"""Pass registry for the static invariant analyzer.

``register_pass`` / ``make_pass`` / ``registered_passes`` mirror the
repo's other plugin registries (``repro.compress.make_codec``,
``repro.fl.api.make_algorithm``, ``repro.control.make_controller``): a
pass is a small class registered by name, and the runner instantiates
every requested pass fresh per run.

Two pass scopes exist:

* ``scope = "lowered"`` — the pass receives a
  :class:`repro.analysis.lower.LoweredSuperstep` per config point and
  inspects its jaxpr (and, with ``needs_compiled = True``, its compiled
  HLO + input/output aliasing);
* ``scope = "source"`` — the pass runs once per analysis over the
  ``src/repro`` tree (AST lint).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple, Type


class AnalysisFailure(RuntimeError):
    """An analysis run could not be carried out (not a finding)."""


@dataclass
class Finding:
    """One invariant violation.

    ``point`` names where it was found: a config-point id for lowered
    passes, a ``path:line`` for source passes.
    """
    pass_name: str
    point: str
    message: str
    severity: str = "error"

    def to_json(self) -> Dict:
        return {"pass": self.pass_name, "point": self.point,
                "message": self.message, "severity": self.severity}

    def __str__(self) -> str:
        return f"[{self.pass_name}] {self.point}: {self.message}"


class AnalysisPass:
    """Base class for invariant passes.

    Subclasses set ``name`` (registry key), ``scope`` ("lowered" |
    "source"), ``needs_compiled`` (lowered passes that must inspect the
    compiled executable, not just the traced jaxpr) and implement
    ``run(target) -> List[Finding]``.
    """
    name: str = ""
    scope: str = "lowered"
    needs_compiled: bool = False
    description: str = ""

    def run(self, target) -> List[Finding]:
        raise NotImplementedError

    def finding(self, point: str, message: str, *,
                severity: str = "error") -> Finding:
        return Finding(self.name, point, message, severity=severity)


_PASSES: Dict[str, Type[AnalysisPass]] = {}


def register_pass(cls: Type[AnalysisPass]) -> Type[AnalysisPass]:
    """Class decorator: register an :class:`AnalysisPass` by its name."""
    if not (isinstance(cls, type) and issubclass(cls, AnalysisPass)):
        raise TypeError(f"register_pass expects an AnalysisPass subclass, "
                        f"got {cls!r}")
    if not cls.name:
        raise ValueError(f"{cls.__name__}.name must be a non-empty string")
    if cls.scope not in ("lowered", "source"):
        raise ValueError(f"{cls.__name__}.scope must be 'lowered' or "
                         f"'source', got {cls.scope!r}")
    if cls.name in _PASSES and _PASSES[cls.name] is not cls:
        raise ValueError(f"analysis pass {cls.name!r} already registered "
                         f"by {_PASSES[cls.name].__name__}")
    _PASSES[cls.name] = cls
    return cls


def make_pass(name: str, **kwargs) -> AnalysisPass:
    """Instantiate a registered pass by name."""
    if name not in _PASSES:
        raise KeyError(f"unknown analysis pass {name!r}; registered: "
                       f"{registered_passes()}")
    return _PASSES[name](**kwargs)


def registered_passes() -> Tuple[str, ...]:
    """Sorted names of every registered pass."""
    return tuple(sorted(_PASSES))
