"""Drive a set of passes over a config matrix and collect a report."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.lower import (SuperstepSpec, default_matrix,
                                  lower_superstep)
from repro.analysis.registry import (AnalysisFailure, Finding, make_pass,
                                     registered_passes)

# the analyzer's default pass set — every registered pass
DEFAULT_PASSES = None


@dataclass
class Report:
    """Outcome of one analysis run.

    ``points`` maps each lowered config point to the pass names that ran
    on it; ``findings`` is every violation; ``errors`` records points
    that could not be analyzed at all (infra failures, NOT invariant
    violations — they still fail the run)."""
    passes: List[str] = field(default_factory=list)
    points: Dict[str, List[str]] = field(default_factory=dict)
    findings: List[Finding] = field(default_factory=list)
    errors: List[Dict] = field(default_factory=list)
    elapsed_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors

    def to_json(self) -> Dict:
        return {"ok": self.ok, "passes": list(self.passes),
                "n_points": len(self.points), "points": dict(self.points),
                "findings": [f.to_json() for f in self.findings],
                "errors": list(self.errors),
                "elapsed_s": round(self.elapsed_s, 3)}

    def merged(self, other: "Report") -> "Report":
        return Report(
            passes=sorted(set(self.passes) | set(other.passes)),
            points={**self.points, **other.points},
            findings=self.findings + other.findings,
            errors=self.errors + other.errors,
            elapsed_s=self.elapsed_s + other.elapsed_s)


def run_analysis(specs: Optional[Sequence[SuperstepSpec]] = None,
                 passes: Optional[Sequence[str]] = None,
                 preset: str = "quick") -> Report:
    """Run ``passes`` (default: all registered) over ``specs`` (default:
    :func:`default_matrix` at ``preset``).

    Lowered passes run per config point (compiling only when some pass
    needs the executable); source passes run once.  Infra failures at a
    point are recorded as errors and the remaining points still run.
    """
    t0 = time.perf_counter()
    names = list(passes) if passes else list(registered_passes())
    unknown = [n for n in names if n not in registered_passes()]
    if unknown:
        raise AnalysisFailure(f"unknown pass(es) {unknown}; registered: "
                              f"{registered_passes()}")
    instances = [make_pass(n) for n in names]
    lowered_passes = [p for p in instances if p.scope == "lowered"]
    source_passes = [p for p in instances if p.scope == "source"]
    rep = Report(passes=names)

    for p in source_passes:
        rep.points["src/repro"] = sorted(
            set(rep.points.get("src/repro", [])) | {p.name})
        try:
            rep.findings.extend(p.run(None))
        except Exception as e:  # infra failure, not a finding
            rep.errors.append({"point": "src/repro", "pass": p.name,
                               "error": f"{type(e).__name__}: {e}"})

    if lowered_passes:
        if specs is None:
            specs = default_matrix(preset)
        for spec in specs:
            try:
                low = lower_superstep(spec)
            except Exception as e:
                rep.errors.append({"point": spec.point, "pass": "lower",
                                   "error": f"{type(e).__name__}: {e}"})
                continue
            rep.points[low.point] = [p.name for p in lowered_passes]
            for p in lowered_passes:
                try:
                    rep.findings.extend(p.run(low))
                except Exception as e:
                    rep.errors.append(
                        {"point": low.point, "pass": p.name,
                         "error": f"{type(e).__name__}: {e}"})
    rep.elapsed_s = time.perf_counter() - t0
    return rep
