"""Deterministic client-chaos injection — public entry point.

The implementation lives with the data loader (``repro.data.federated``)
because the fault schedule must ride the dataset's rng streams to stay
reproducible and resumable; this module is the stable import surface:

    from repro.chaos import ChaosConfig
    data = FederatedDataset(clients, test, seed=0,
                            chaos=ChaosConfig(speed_sigma=1.2, dropout=0.05))

Pair a chaos-enabled dataset with a participation policy
(``repro.fl.participation``) to decide, per round, which of the sampled
clients contribute and at what staleness weight.
"""
from repro.data.federated import ChaosConfig, ChaosDraws  # noqa: F401

__all__ = ["ChaosConfig", "ChaosDraws"]
