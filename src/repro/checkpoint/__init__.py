from repro.checkpoint.io import (load_tree, restore_server_state,  # noqa: F401
                                 save_server_state, save_tree)
