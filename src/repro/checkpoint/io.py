"""Checkpointing: pytree <-> .npz with path-encoded keys.

Round-resumable server state = {global_state, round index, rng state}.
No external deps (no orbax/msgpack): keys are '/'-joined pytree paths.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    # One device_get for the whole tree: server state and the engine's
    # full-federation EF table live on device, and fetching the pytree in
    # a single transfer (instead of one blocking np.asarray per leaf) is
    # what keeps checkpoint stalls to a single host sync.
    tree = jax.device_get(tree)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_tree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def load_tree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (treedef donor)."""
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(_path_str(p) for p in path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    leaves = [data[k] for k in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_server_state(dirpath: str, global_state, round_idx: int,
                      extra: Dict | None = None) -> None:
    os.makedirs(dirpath, exist_ok=True)
    save_tree(os.path.join(dirpath, "state.npz"), global_state)
    meta = {"round": round_idx, **(extra or {})}
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)


def restore_server_state(dirpath: str, like) -> Tuple[Any, int]:
    state = load_tree(os.path.join(dirpath, "state.npz"), like)
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    return state, meta["round"]
