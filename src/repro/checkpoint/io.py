"""Checkpointing: pytree <-> .npz with path-encoded keys.

Round-resumable server state = {global_state, round index, rng state}.
No external deps (no orbax/msgpack): keys are '/'-joined pytree paths.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Tuple

import jax
import numpy as np

# transient-OSError retry for checkpoint writes: networked / overlaid
# filesystems (NFS, overlayfs under container churn) throw sporadic
# EIO/ESTALE that a short backoff rides out; a persistent failure still
# raises after the last attempt.
_SAVE_ATTEMPTS = 3
_SAVE_BACKOFF_S = 0.05


def _retry_save(write, path: str, runlog=None) -> None:
    """Run ``write()`` with bounded exponential backoff on ``OSError``.

    Attempts beyond the first are counted on the runlog
    (``checkpoint.save_retries``) so flaky storage is visible in the run
    trace; the final failure propagates untouched.
    """
    for attempt in range(_SAVE_ATTEMPTS):
        try:
            write()
            return
        except OSError:
            if attempt == _SAVE_ATTEMPTS - 1:
                raise
            if runlog is not None:
                runlog.counter("checkpoint.save_retries", 1)
                runlog.warning("checkpoint.save_retry", path=path,
                               attempt=attempt + 1)
            time.sleep(_SAVE_BACKOFF_S * (2 ** attempt))


def _flatten(tree) -> Dict[str, np.ndarray]:
    # One device_get for the whole tree: server state and the engine's
    # full-federation EF table live on device, and fetching the pytree in
    # a single transfer (instead of one blocking np.asarray per leaf) is
    # what keeps checkpoint stalls to a single host sync.
    tree = jax.device_get(tree)
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_tree(path: str, tree, runlog=None) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)   # fetch once — retries must not re-sync device
    _retry_save(lambda: np.savez(path, **flat), path, runlog)


def load_tree(path: str, like) -> Any:
    """Restore into the structure of ``like`` (treedef donor)."""
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(_path_str(p) for p in path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    leaves = [data[k] for k in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def strip_scratch_rows(tree, n_shards: int):
    """Resident sharded EF layout -> the compact on-disk layout.

    The sharded engine's EF table carries one permanent scratch row per
    shard block (``[(N_loc + 1) * S, ...]`` — the write sink of the
    in-place scatter, see ``repro.engine.superstep``).  Checkpoints stay
    format-compatible with the unsharded ``[N, ...]`` layout: this drops
    row ``N_loc`` of every block before ``ef.npz`` is written.  Works on
    device or host arrays; returns numpy (a checkpoint is host-bound
    anyway).
    """
    def one(x):
        x = np.asarray(jax.device_get(x))
        blocks = x.reshape((n_shards, -1) + x.shape[1:])
        return blocks[:, :-1].reshape((-1,) + x.shape[1:])

    return jax.tree.map(one, tree)


def insert_scratch_rows(tree, n_shards: int):
    """Compact ``[N, ...]`` EF layout -> resident ``[(N/S + 1) * S, ...]``.

    Re-appends a zero scratch row to every shard block on restore — the
    scratch row is dead state (always overwritten before any read), so
    zeros reproduce a never-checkpointed run exactly.  ``N`` must divide
    over ``n_shards`` (the engine validates this before staging).
    """
    def one(x):
        x = np.asarray(x)
        n = x.shape[0]
        if n % n_shards:
            raise ValueError(f"EF table rows {n} do not divide over "
                             f"{n_shards} shards")
        blocks = x.reshape((n_shards, n // n_shards) + x.shape[1:])
        pad = np.zeros((n_shards, 1) + x.shape[1:], x.dtype)
        return np.concatenate([blocks, pad], axis=1).reshape(
            (-1,) + x.shape[1:])

    return jax.tree.map(one, tree)


def ef_disk_layout(ef, *, n_shards: int = 1, n_clients: int = None):
    """Normalize any engine EF backing to the compact on-disk ``[N, ...]``
    layout ``ef.npz`` has always used.

    Accepts the single-device dense table, the sharded resident
    scratch-row table (``n_shards > 1`` — scratch rows dropped), or a
    cohort-paged host store (anything with ``to_dense(n_clients)``,
    i.e. :class:`repro.engine.efstore.HostEFStore`).  Because every
    backing round-trips through this one format, checkpoints written by
    a dense run resume under a paged one and vice versa — the store
    layout is a runtime knob, not a persistence format.
    """
    if hasattr(ef, "to_dense"):
        if n_clients is None:
            raise ValueError("paged EF store needs n_clients to "
                             "rebuild the dense disk layout")
        return ef.to_dense(n_clients)
    if n_shards > 1:
        return strip_scratch_rows(ef, n_shards)
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), ef)


def save_server_state(dirpath: str, global_state, round_idx: int,
                      extra: Dict | None = None, runlog=None) -> None:
    os.makedirs(dirpath, exist_ok=True)
    save_tree(os.path.join(dirpath, "state.npz"), global_state,
              runlog=runlog)
    meta = {"round": round_idx, **(extra or {})}
    meta_path = os.path.join(dirpath, "meta.json")

    def write_meta():
        with open(meta_path, "w") as f:
            json.dump(meta, f)

    _retry_save(write_meta, meta_path, runlog)


def restore_server_state(dirpath: str, like) -> Tuple[Any, int]:
    state = load_tree(os.path.join(dirpath, "state.npz"), like)
    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    return state, meta["round"]
