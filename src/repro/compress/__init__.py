"""Pluggable uplink/downlink compression for federated communication.

The paper's headline metric is communication cost; this package supplies
the codecs that actually reduce it.  A :class:`Codec` turns a model pytree
(weights or deltas) into a wire payload and reports true wire bytes, so
``CommLog`` can account real MB instead of idealized fp32 sizes.

Codecs (select via ``FLConfig.uplink_codec`` / ``downlink_codec``):

    identity            raw fp32 (baseline)
    int8 / int4 / quant stochastic uniform quantization, per-leaf scale
                        (``quant`` reads ``FLConfig.quant_bits``)
    topk / topk_noef    top-k sparsification (+ client error feedback)
    mask / lowrank      seed-expanded random sketching

The quant hot paths (fused quantize+pack, scatter-unpack) run as Pallas
kernels on TPU with pure-jnp references on CPU; a top-k threshold-select
kernel is available via ``ops.topk_threshold_select`` for tie-free dense
masking (the topk codec's residual uses the exact scatter complement so
ties at the k-th magnitude never leak untransmitted mass) — see
``repro.kernels.compress_pack`` and ``repro.kernels.ops``.
"""
from repro.compress.codec import Codec, IdentityCodec  # noqa: F401
from repro.compress.quant import QuantCodec  # noqa: F401
from repro.compress.sketch import SketchCodec  # noqa: F401
from repro.compress.topk import TopKCodec  # noqa: F401

CODEC_NAMES = ("identity", "quant", "int8", "int4", "topk", "topk_noef",
               "mask", "lowrank")


def make_codec(name: str, *, topk_frac: float = 0.05, quant_bits: int = 8,
               impl: str = "auto") -> Codec:
    """Build a codec by config name (see :data:`CODEC_NAMES`).

    Out-of-range parameters are rejected HERE, not just in
    ``FLConfig.__post_init__`` — codecs built outside a config (tests,
    benchmarks, plugins) get the same construction-time errors.
    """
    if name in ("topk", "topk_noef", "mask", "lowrank"):
        if not 0.0 < topk_frac <= 1.0:
            raise ValueError(
                f"codec {name!r}: topk_frac={topk_frac!r} must be in (0, 1]")
    if name == "quant" and quant_bits not in (4, 8):
        raise ValueError(
            f"codec 'quant': quant_bits={quant_bits!r} must be 4 or 8")
    if name == "identity":
        return IdentityCodec()
    if name == "quant":
        return QuantCodec(quant_bits, impl=impl)
    if name in ("int8", "int4"):
        return QuantCodec(int(name[3:]), impl=impl)
    if name == "topk":
        return TopKCodec(topk_frac, error_feedback=True, impl=impl)
    if name == "topk_noef":
        return TopKCodec(topk_frac, error_feedback=False, impl=impl)
    if name == "mask":
        return SketchCodec(topk_frac, mode="mask", impl=impl)
    if name == "lowrank":
        return SketchCodec(topk_frac, mode="lowrank", impl=impl)
    raise ValueError(f"unknown codec {name!r}; choose from {CODEC_NAMES}")
