"""The ``Codec`` protocol: pluggable uplink/downlink pytree compression.

A codec maps a model pytree (weights or weight deltas) to a *wire payload*
— a pytree whose array leaves are exactly the bytes that would cross the
network — and back.  ``nbytes`` reports true wire size from the payload's
static shapes/dtypes (it also works on ``jax.eval_shape`` results, which is
how the server accounts bytes without running an encode).

Codecs are jax-traceable: ``encode``/``decode`` run under jit/vmap inside
the round function, so per-client compression vectorises with the same
``client_parallel`` vmap that parallelises local training.

Stateful codecs (error feedback) thread a per-client ``state`` pytree
through ``encode``; the federated server persists one state per client
across rounds (see ``repro.fl.server``).

Wire-format note: payload leaves are the transmitted buffers; seed-expanded
codecs (sketching) additionally transmit one int32 seed per leaf, carried
in the payload as an array so ``nbytes`` counts it.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _nbytes_of(x) -> int:
    """Wire bytes of one payload array (works on ShapeDtypeStruct too)."""
    return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize


class Codec:
    """Base codec: bind to a template tree, then encode/decode leaves.

    Subclasses implement the per-leaf hooks ``_encode_leaf(x_flat, state,
    key, i)`` -> (leaf_payload, new_leaf_state) and ``_decode_leaf(payload,
    i)`` -> x_flat; the base class handles tree flatten/unflatten, shape
    restore and byte accounting.
    """

    name = "identity"
    stateful = False          # True -> per-client state (error feedback)

    def bind(self, template_tree) -> "Codec":
        """Record the tree structure + leaf shapes the codec operates on."""
        leaves, self._treedef = jax.tree_util.tree_flatten(template_tree)
        self._shapes = [tuple(x.shape) for x in leaves]
        self._dtypes = [jnp.dtype(x.dtype) for x in leaves]
        return self

    def _n(self, i) -> int:
        """Element count of bound leaf ``i``."""
        n = 1
        for d in self._shapes[i]:
            n *= d
        return n

    # -- per-leaf hooks -------------------------------------------------
    def _encode_leaf(self, x, state, key, i) -> Tuple[Any, Any]:
        return x, state

    def _encode_leaf_level(self, x, state, key, i, level) -> Tuple[Any, Any]:
        raise NotImplementedError(
            f"codec {self.name!r} does not support level-parameterized "
            "encode (no compression ladder)")

    def _decode_leaf(self, payload, i):
        return payload

    def _init_leaf_state(self, i):
        return ()

    # -- level ladder (adaptive compression, repro.control) -------------
    # Ladder-capable codecs bind once at the top (capacity) level; a
    # traced int32 ``level`` then masks each payload down to the
    # effective rung while the wire buffers keep their static capacity
    # shape under jit.  ``level_bytes`` reports what a real wire would
    # carry per rung, for CommLog's effective-bytes accounting.
    _ladder = None            # ascending effective levels; None -> static

    def set_ladder(self, values) -> "Codec":
        raise ValueError(
            f"codec {self.name!r} has no compression ladder; adaptive "
            "controllers need a ladder-capable uplink codec "
            "(topk/topk_noef/quant/int8/int4)")

    def level_bytes(self) -> Tuple[int, ...]:
        """Effective wire bytes per ladder level (bind + set_ladder first)."""
        raise ValueError(f"codec {self.name!r} has no compression ladder")

    # -- public API -----------------------------------------------------
    def init_state(self, template_tree=None):
        """Fresh per-client codec state (EF residuals; () if stateless)."""
        if template_tree is not None:
            self.bind(template_tree)
        return [self._init_leaf_state(i) for i in range(len(self._shapes))]

    def encode(self, tree, state=None, key=None, level=None):
        """tree -> (payload, new_state).  ``key`` drives stochastic
        rounding / sketch seeds; None selects the deterministic variant.
        ``level`` (a traced int32 scalar) selects the effective rung of a
        bound ladder (``set_ladder``); None encodes at the static
        configuration and traces exactly the pre-ladder program."""
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self._shapes):
            raise ValueError(f"codec bound to a {len(self._shapes)}-leaf "
                             f"tree, got {len(leaves)} leaves")
        if state is None:
            state = self.init_state()
        keys = (jax.random.split(key, len(leaves)) if key is not None
                else [None] * len(leaves))
        payload: List[Any] = []
        new_state: List[Any] = []
        for i, (x, s) in enumerate(zip(leaves, state)):
            xf = x.reshape(-1).astype(jnp.float32)
            if level is None:
                p, ns = self._encode_leaf(xf, s, keys[i], i)
            else:
                p, ns = self._encode_leaf_level(xf, s, keys[i], i, level)
            payload.append(p)
            new_state.append(ns)
        return payload, new_state

    def decode(self, payload):
        """payload -> tree (shapes/dtypes of the bound template)."""
        leaves = [self._decode_leaf(p, i).reshape(self._shapes[i])
                  .astype(self._dtypes[i])
                  for i, p in enumerate(payload)]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def nbytes(self, payload) -> int:
        """True wire bytes of one payload (sum over transmitted buffers)."""
        return int(sum(_nbytes_of(x)
                       for x in jax.tree_util.tree_leaves(payload)))

    def wire_bytes(self) -> int:
        """Static per-message wire bytes, via an abstract encode."""
        template = jax.tree_util.tree_unflatten(
            self._treedef,
            [jax.ShapeDtypeStruct(s, d)
             for s, d in zip(self._shapes, self._dtypes)])
        k = jax.random.PRNGKey(0) if self.uses_key else None
        payload, _ = jax.eval_shape(
            lambda t: self.encode(t, self.init_state(), k), template)
        return self.nbytes(payload)

    uses_key = False          # True -> encode consumes a PRNG key


class IdentityCodec(Codec):
    """No compression: the payload is the raw fp32 tree (baseline)."""

    name = "identity"
