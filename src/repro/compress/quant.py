"""Stochastic int8 / int4 uniform quantization with per-leaf scale.

Per leaf: scale = max|x| / qmax, codes = clip(floor(x/scale + u), ±qmax)
with u ~ U[0,1) (unbiased stochastic rounding; u = 0.5 when no key is
given).  int4 codes are nibble-packed two-per-byte, so the wire payload is
n/8 of fp32.  The quantize+pack and unpack hot paths dispatch to the
Pallas kernels in ``repro.kernels.compress_pack`` (jnp reference on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec
from repro.kernels import ops


class QuantCodec(Codec):
    """Stochastic uniform quantizer; ``bits`` in {4, 8}."""

    stateful = False
    uses_key = True

    def __init__(self, bits: int = 8, *, impl: str = "auto"):
        assert bits in (4, 8), bits
        self.bits = bits
        self.impl = impl
        self.name = f"int{bits}"

    def _padded_n(self, i) -> int:
        n = self._n(i)
        return n + (n % 2 if self.bits == 4 else 0)

    def _encode_leaf(self, x, state, key, i):
        n = x.shape[0]
        pn = self._padded_n(i)
        if pn != n:
            x = jnp.pad(x, (0, pn - n))
        qmax = 127 if self.bits == 8 else 7
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
        if key is None:
            noise = jnp.full((pn,), 0.5, jnp.float32)
        else:
            noise = jax.random.uniform(key, (pn,), jnp.float32)
        packed = ops.quantize_pack(x, scale, noise, bits=self.bits,
                                   impl=self.impl)
        return {"q": packed, "scale": scale.reshape(1)}, state

    def _decode_leaf(self, payload, i):
        pn = self._padded_n(i)
        y = ops.quantize_unpack(payload["q"], payload["scale"][0],
                                bits=self.bits, n=pn, impl=self.impl)
        return y[:self._n(i)]
