"""Stochastic int8 / int4 uniform quantization with per-leaf scale.

Per leaf: scale = max|x| / qmax, codes = clip(floor(x/scale + u), ±qmax)
with u ~ U[0,1) (unbiased stochastic rounding; u = 0.5 when no key is
given).  int4 codes are nibble-packed two-per-byte, so the wire payload is
n/8 of fp32.  The quantize+pack and unpack hot paths dispatch to the
Pallas kernels in ``repro.kernels.compress_pack`` (jnp reference on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec
from repro.kernels import ops


class QuantCodec(Codec):
    """Stochastic uniform quantizer; ``bits`` in {4, 8}."""

    stateful = False
    uses_key = True

    def __init__(self, bits: int = 8, *, impl: str = "auto"):
        if bits not in (4, 8):
            raise ValueError(f"quant bits={bits!r} must be 4 or 8")
        self.bits = bits
        self.impl = impl
        self.name = f"int{bits}"

    def _padded_n(self, i) -> int:
        n = self._n(i)
        return n + (n % 2 if self.bits == 4 else 0)

    def _encode_leaf(self, x, state, key, i):
        n = x.shape[0]
        pn = self._padded_n(i)
        if pn != n:
            x = jnp.pad(x, (0, pn - n))
        qmax = 127 if self.bits == 8 else 7
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
        if key is None:
            noise = jnp.full((pn,), 0.5, jnp.float32)
        else:
            noise = jax.random.uniform(key, (pn,), jnp.float32)
        packed = ops.quantize_pack(x, scale, noise, bits=self.bits,
                                   impl=self.impl)
        return {"q": packed, "scale": scale.reshape(1)}, state

    def _decode_leaf(self, payload, i):
        pn = self._padded_n(i)
        y = ops.quantize_unpack(payload["q"], payload["scale"][0],
                                bits=self.bits, n=pn, impl=self.impl)
        return y[:self._n(i)]

    # -- level ladder ---------------------------------------------------
    def set_ladder(self, values):
        vals = tuple(int(v) for v in values)
        if not vals or list(vals) != sorted(set(vals)):
            raise ValueError(f"ladder {values!r} must be strictly ascending")
        if not all(v in (4, 8) for v in vals):
            raise ValueError(f"ladder {values!r} needs bits in (4, 8)")
        if vals[-1] != self.bits:
            raise ValueError(f"ladder top {vals[-1]} must equal the codec's "
                             f"capacity bits {self.bits}")
        self._ladder = vals
        return self

    def _qmax_table(self):
        return jnp.asarray([2 ** (b - 1) - 1 for b in self._ladder],
                           jnp.float32)

    def _encode_leaf_level(self, x, state, key, i, level):
        n = x.shape[0]
        pn = self._padded_n(i)
        if pn != n:
            x = jnp.pad(x, (0, pn - n))
        # effective bits enter through the scale: codes span +-qmax_eff,
        # which always fits inside the capacity packing
        qmax = jnp.take(self._qmax_table(), level)
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
        if key is None:
            noise = jnp.full((pn,), 0.5, jnp.float32)
        else:
            noise = jax.random.uniform(key, (pn,), jnp.float32)
        packed = ops.quantize_pack(x, scale, noise, bits=self.bits,
                                   impl=self.impl)
        return {"q": packed, "scale": scale.reshape(1)}, state

    def level_bytes(self):
        if self._ladder is None:
            raise ValueError("set_ladder first")
        out = []
        for b in self._ladder:
            total = 0
            for i in range(len(self._shapes)):
                n = self._n(i)
                total += (n + n % 2) // 2 if b == 4 else n
                total += 4  # fp32 scale
            out.append(total)
        return tuple(out)
