"""Seed-expanded random sketching: coordinate masks and low-rank projection.

Both modes transmit a dense buffer that is ``frac`` of the leaf plus one
int32 seed; the receiver re-expands the random operator from the seed, so
indices / projection matrices never cross the wire.

* ``mask``: a seeded random coordinate subset of size k = ceil(frac * n);
  transmitted values are scaled by n/k so the estimator is unbiased
  (importance-sampled sparsification, cf. random-mask gradient sketching).
* ``lowrank``: matrix leaves X [m, n] send U = X G with G [n, r] Gaussian,
  G entries ~ N(0, 1/r); the receiver forms X̂ = U Gᵀ, and E[X̂] = X.
  Non-matrix leaves fall back to ``mask``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec


def _leaf_key(seed):
    """Rebuild the per-leaf PRNG key from the transmitted int32 seed."""
    return jax.random.PRNGKey(seed.astype(jnp.uint32))


class SketchCodec(Codec):
    """Random-mask / low-rank sketching; ``mode`` in {"mask", "lowrank"}."""

    stateful = False
    uses_key = True

    def __init__(self, frac: float = 0.1, *, mode: str = "mask",
                 impl: str = "auto"):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"sketch frac={frac!r} must be in (0, 1]")
        if mode not in ("mask", "lowrank"):
            raise ValueError(f"sketch mode={mode!r} must be 'mask' or "
                             "'lowrank'")
        self.frac = frac
        self.mode = mode
        self.impl = impl
        self.name = mode if mode == "lowrank" else "mask"

    def _is_matrix(self, i) -> bool:
        shape = self._shapes[i]
        return (self.mode == "lowrank" and len(shape) >= 2
                and shape[-1] > 1 and self._n(i) // shape[-1] > 1)

    def _rank(self, i) -> int:
        return max(1, int(round(self.frac * self._shapes[i][-1])))

    def _k(self, i) -> int:
        return max(1, min(self._n(i), math.ceil(self.frac * self._n(i))))

    def _seed_from(self, key, i):
        if key is None:
            return jnp.asarray(i + 1, jnp.int32)
        return jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max,
                                  jnp.int32)

    def _encode_leaf(self, x, state, key, i):
        seed = self._seed_from(key, i)
        if self._is_matrix(i):
            cols = self._shapes[i][-1]
            rows = self._n(i) // cols
            r = self._rank(i)
            g = jax.random.normal(_leaf_key(seed), (cols, r),
                                  jnp.float32) * (r ** -0.5)
            u = x.reshape(rows, cols) @ g
            return {"u": u, "seed": seed.reshape(1)}, state
        n, k = self._n(i), self._k(i)
        idx = jax.random.choice(_leaf_key(seed), n, (k,), replace=False)
        val = jnp.take(x, idx) * (n / k)
        return {"mval": val.astype(jnp.float32),
                "seed": seed.reshape(1)}, state

    def _decode_leaf(self, payload, i):
        seed = payload["seed"][0]
        if self._is_matrix(i):
            cols = self._shapes[i][-1]
            r = self._rank(i)
            g = jax.random.normal(_leaf_key(seed), (cols, r),
                                  jnp.float32) * (r ** -0.5)
            return (payload["u"] @ g.T).reshape(-1)
        n, k = self._n(i), self._k(i)
        idx = jax.random.choice(_leaf_key(seed), n, (k,), replace=False)
        dense = jnp.zeros((n,), jnp.float32)
        return dense.at[idx].set(payload["mval"])
