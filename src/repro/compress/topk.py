"""Top-k magnitude sparsification with client-side error feedback.

Per leaf (flattened, k = max(1, round(frac * n))): transmit the k largest-
magnitude entries as (int32 index, fp32 value) pairs — wire bytes = 8k
vs 4n raw.  With error feedback (memory of what compression dropped, added
back before the next encode) non-IID convergence stays close to the
uncompressed baseline at aggressive sparsity, which is what lets the
bytes-to-milestone metric actually improve.

The residual uses the exact scatter complement (``g.at[idx].set(0)``) so
ties at the k-th magnitude never leak untransmitted mass into the model.
(The dense threshold-select approximation of decode∘encode exists as the
``topk_select`` Pallas kernel — ``ops.topk_threshold_select`` — for
callers that want tie-free dense masking without index traffic; this
codec deliberately does NOT use it, because a tie at the threshold would
make the dense mask disagree with the k-entry payload.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec


class TopKCodec(Codec):
    """Keep the top ``frac`` fraction of entries per leaf (by |value|)."""

    uses_key = False

    def __init__(self, frac: float = 0.05, *, error_feedback: bool = True,
                 impl: str = "auto"):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk frac={frac!r} must be in (0, 1]")
        self.frac = frac
        self.error_feedback = error_feedback
        self.stateful = error_feedback
        self.impl = impl
        self.name = "topk" if error_feedback else "topk_noef"

    def _k(self, i) -> int:
        return max(1, int(round(self.frac * self._n(i))))

    def _init_leaf_state(self, i):
        if not self.error_feedback:
            return ()
        return jnp.zeros((self._n(i),), jnp.float32)

    def _encode_leaf(self, x, state, key, i):
        g = x + state if self.error_feedback else x
        _, idx = jax.lax.top_k(jnp.abs(g), self._k(i))
        idx = idx.astype(jnp.int32)
        val = jnp.take(g, idx)
        payload = {"idx": idx, "val": val.astype(jnp.float32)}
        new_state = g.at[idx].set(0.0) if self.error_feedback else state
        return payload, new_state

    def _decode_leaf(self, payload, i):
        dense = jnp.zeros((self._n(i),), jnp.float32)
        return dense.at[payload["idx"]].set(payload["val"])

    # -- level ladder ---------------------------------------------------
    def set_ladder(self, values):
        vals = tuple(float(v) for v in values)
        if not vals or list(vals) != sorted(set(vals)):
            raise ValueError(f"ladder {values!r} must be strictly ascending")
        if not all(0.0 < v <= 1.0 for v in vals):
            raise ValueError(f"ladder {values!r} needs fracs in (0, 1]")
        if vals[-1] != self.frac:
            raise ValueError(f"ladder top {vals[-1]} must equal the codec's "
                             f"capacity frac {self.frac}")
        self._ladder = vals
        return self

    def _k_table(self, i):
        return jnp.asarray([max(1, int(round(f * self._n(i))))
                            for f in self._ladder], jnp.int32)

    def _encode_leaf_level(self, x, state, key, i, level):
        g = x + state if self.error_feedback else x
        k_cap = self._k(i)
        _, idx = jax.lax.top_k(jnp.abs(g), k_cap)
        idx = idx.astype(jnp.int32)
        # lax.top_k sorts by magnitude, so the first k_l slots ARE the
        # exact top-k_l payload; the mask zeroes the rest of the
        # capacity-shaped buffer (static wire shape under jit).
        keep = (jnp.arange(k_cap, dtype=jnp.int32)
                < jnp.take(self._k_table(i), level))
        val = jnp.where(keep, jnp.take(g, idx), 0.0)
        payload = {"idx": idx, "val": val.astype(jnp.float32)}
        if self.error_feedback:
            # masked-out slots scatter their own value back: the residual
            # keeps exactly what the effective level did not transmit
            new_state = g.at[idx].set(
                jnp.where(keep, 0.0, jnp.take(g, idx)))
        else:
            new_state = state
        return payload, new_state

    def level_bytes(self):
        if self._ladder is None:
            raise ValueError("set_ladder first")
        return tuple(sum(8 * max(1, int(round(f * self._n(i))))
                         for i in range(len(self._shapes)))
                     for f in self._ladder)
