"""Top-k magnitude sparsification with client-side error feedback.

Per leaf (flattened, k = max(1, round(frac * n))): transmit the k largest-
magnitude entries as (int32 index, fp32 value) pairs — wire bytes = 8k
vs 4n raw.  With error feedback (memory of what compression dropped, added
back before the next encode) non-IID convergence stays close to the
uncompressed baseline at aggressive sparsity, which is what lets the
bytes-to-milestone metric actually improve.

The residual uses the exact scatter complement (``g.at[idx].set(0)``) so
ties at the k-th magnitude never leak untransmitted mass into the model.
(The dense threshold-select approximation of decode∘encode exists as the
``topk_select`` Pallas kernel — ``ops.topk_threshold_select`` — for
callers that want tie-free dense masking without index traffic; this
codec deliberately does NOT use it, because a tie at the threshold would
make the dense mask disagree with the k-entry payload.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compress.codec import Codec


class TopKCodec(Codec):
    """Keep the top ``frac`` fraction of entries per leaf (by |value|)."""

    uses_key = False

    def __init__(self, frac: float = 0.05, *, error_feedback: bool = True,
                 impl: str = "auto"):
        assert 0.0 < frac <= 1.0, frac
        self.frac = frac
        self.error_feedback = error_feedback
        self.stateful = error_feedback
        self.impl = impl
        self.name = "topk" if error_feedback else "topk_noef"

    def _k(self, i) -> int:
        return max(1, int(round(self.frac * self._n(i))))

    def _init_leaf_state(self, i):
        if not self.error_feedback:
            return ()
        return jnp.zeros((self._n(i),), jnp.float32)

    def _encode_leaf(self, x, state, key, i):
        g = x + state if self.error_feedback else x
        _, idx = jax.lax.top_k(jnp.abs(g), self._k(i))
        idx = idx.astype(jnp.int32)
        val = jnp.take(g, idx)
        payload = {"idx": idx, "val": val.astype(jnp.float32)}
        new_state = g.at[idx].set(0.0) if self.error_feedback else state
        return payload, new_state

    def _decode_leaf(self, payload, i):
        dense = jnp.zeros((self._n(i),), jnp.float32)
        return dense.at[payload["idx"]].set(payload["val"])
