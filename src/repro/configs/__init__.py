"""Architecture registry: ``--arch <id>`` resolves through :func:`get_config`."""
from __future__ import annotations

from repro.configs.base import (ArchConfig, CNNConfig, FLConfig, InputShape,
                                INPUT_SHAPES)
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.granite_moe_1b import CONFIG as GRANITE_MOE_1B
from repro.configs.smollm_135m import CONFIG as SMOLLM_135M
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL_7B
from repro.configs.h2o_danube3_4b import CONFIG as H2O_DANUBE3_4B
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from repro.configs.gemma3_1b import CONFIG as GEMMA3_1B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.stablelm_3b import CONFIG as STABLELM_3B
from repro.configs.cnn_paper import CNN_MNIST, CNN_CIFAR

ARCH_CONFIGS = {
    c.name: c
    for c in (
        ARCTIC_480B,
        GRANITE_MOE_1B,
        SMOLLM_135M,
        QWEN2_VL_7B,
        H2O_DANUBE3_4B,
        RECURRENTGEMMA_9B,
        GEMMA3_1B,
        WHISPER_LARGE_V3,
        MAMBA2_130M,
        STABLELM_3B,
    )
}

CNN_CONFIGS = {c.name: c for c in (CNN_MNIST, CNN_CIFAR)}


def get_config(name: str) -> ArchConfig:
    if name not in ARCH_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCH_CONFIGS)}")
    return ARCH_CONFIGS[name]


def get_cnn_config(name: str) -> CNNConfig:
    return CNN_CONFIGS[name]


__all__ = [
    "ArchConfig", "CNNConfig", "FLConfig", "InputShape", "INPUT_SHAPES",
    "ARCH_CONFIGS", "CNN_CONFIGS", "get_config", "get_cnn_config",
]
