"""arctic-480b [moe] — 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Dense-MoE hybrid: a dense FFN residual runs in parallel with the MoE FFN.
Too large for per-client replicas -> client_sequential FL mode with
FSDP+expert-parallel sharding.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    rope_theta=1e6,
    fl_mode="client_sequential",
    source="hf:Snowflake/snowflake-arctic-base",
)
