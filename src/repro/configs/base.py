"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as an :class:`ArchConfig`; the
federated-learning mechanism (the paper's contribution) is configured via
:class:`FLConfig`.  Configs are plain frozen dataclasses so they hash, print
and round-trip cleanly through launch scripts.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# Block kinds appearing in ``ArchConfig.block_pattern``.
ATTN_GLOBAL = "attn_global"     # full causal attention
ATTN_LOCAL = "attn_local"       # sliding-window causal attention
RGLRU = "rglru"                 # RecurrentGemma RG-LRU recurrent block
SSD = "ssd"                     # Mamba-2 state-space-duality block

FAMILIES = ("dense", "moe", "vlm", "hybrid", "ssm", "audio")
FL_MODES = ("client_parallel", "client_sequential")

# Wire codecs from repro.compress (kept literal here so the config layer
# stays import-light; repro.compress.CODEC_NAMES is the authoritative set
# and test_compress asserts the two stay in sync).
CODEC_NAMES = ("identity", "quant", "int8", "int4", "topk", "topk_noef",
               "mask", "lowrank")

# Algorithm plugins from repro.fl.api (same literal-mirror pattern:
# repro.fl.api.ALGORITHM_NAMES is the authoritative registry and
# test_api asserts the two stay in sync).  Names registered at runtime
# beyond these are validated against the live registry lazily.
ALGORITHM_NAMES = ("fedavg", "fedmmd", "fedfusion", "fedl2", "fedprox")

# Participation policies from repro.fl.participation (same pattern;
# test_participation asserts sync with registered_policies()).
PARTICIPATION_NAMES = ("full_sync", "deadline", "buffered_async")

# Adaptive compression controllers from repro.control (same pattern;
# test_control asserts sync with registered_controllers()).
CONTROLLER_NAMES = ("static", "ef_ratio", "bytes_budget", "loss_trend")

# Uplink codecs that support a level ladder (mirror of
# repro.control.LADDER_CODECS; test_control asserts sync).
_LADDER_CODECS = ("topk", "topk_noef", "quant", "int8", "int4")


@dataclass(frozen=True)
class ArchConfig:
    """A transformer-family architecture from the assigned pool."""

    name: str
    family: str                     # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ()   # () -> all ATTN_GLOBAL

    # --- attention details ---
    sliding_window: int = 4096      # window for ATTN_LOCAL blocks
    rope_theta: float = 10_000.0
    partial_rotary_pct: float = 1.0
    mrope: bool = False             # Qwen2-VL multimodal RoPE (3 sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # 0 -> d_ff
    dense_residual: bool = False    # Arctic: dense FFN in parallel with MoE
    moe_capacity: float = 1.25      # expert capacity factor (train/prefill)

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4

    # --- RG-LRU (recurrentgemma) ---
    lru_width: int = 0              # 0 -> d_model

    # --- encoder / modality frontend stubs ---
    n_enc_layers: int = 0           # whisper encoder depth (0 = decoder-only)
    n_audio_frames: int = 1500      # stub encoder sequence length
    n_vision_tokens: int = 0        # VLM: number of stub patch embeddings

    # --- misc ---
    norm_eps: float = 1e-6
    act: str = "silu"               # "silu" (SwiGLU) or "gelu" (plain MLP)
    tie_embeddings: bool = True
    max_seq_len: int = 524_288

    # --- distribution plan ---
    fl_mode: str = "client_parallel"
    source: str = ""                # citation bracket from the assignment

    # --- performance knobs (§Perf; defaults = paper-faithful baseline) ---
    remat: str = "none"             # none | attn | layer  (activation ckpt)
    attn_impl: str = "jnp"          # jnp | pallas (flash train kernel)
    serve_expert_parallel: bool = False  # shard experts over data at serve
    moe_shard_capacity: bool = False     # capacity dim over 'model' (no vmap)
    moe_dispatch: str = "gather"         # gather | a2a (shard_map all-to-all;
    # requires EP params + no vmap over clients, i.e. client_sequential)

    def __post_init__(self):
        # plain ValueErrors, not asserts: asserts vanish under python -O,
        # silently skipping config validation
        if self.family not in FAMILIES:
            raise ValueError(f"{self.name}: family {self.family!r} not in "
                             f"{FAMILIES}")
        if self.fl_mode not in FL_MODES:
            raise ValueError(f"{self.name}: fl_mode {self.fl_mode!r} not in "
                             f"{FL_MODES}")
        if self.remat not in ("none", "attn", "layer"):
            raise ValueError(f"{self.name}: remat {self.remat!r} must be "
                             "'none', 'attn' or 'layer'")
        if self.attn_impl not in ("jnp", "pallas"):
            raise ValueError(f"{self.name}: attn_impl {self.attn_impl!r} "
                             "must be 'jnp' or 'pallas'")
        if self.moe_dispatch not in ("gather", "a2a"):
            raise ValueError(f"{self.name}: moe_dispatch "
                             f"{self.moe_dispatch!r} must be 'gather' or "
                             "'a2a'")
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", (ATTN_GLOBAL,) * self.n_layers)
        if len(self.block_pattern) != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern len {len(self.block_pattern)} != "
                f"{self.n_layers}")
        if self.n_experts and not 0 < self.top_k <= self.n_experts:
            raise ValueError(f"{self.name}: top_k {self.top_k} must be in "
                             f"(0, n_experts={self.n_experts}]")
        if self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return all(b == SSD for b in self.block_pattern)

    @property
    def has_subquadratic_decode(self) -> bool:
        """True if the decode-time cache is sub-linear in context length for
        most layers (SSM state, RG-LRU state or sliding-window caches)."""
        return any(b in (SSD, RGLRU, ATTN_LOCAL) for b in self.block_pattern)

    @property
    def supports_decode(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def param_count(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        per_attn = d * h * hd + 2 * d * kv * hd + h * hd * d          # q,k,v,o
        mlp_mult = 3 if self.act == "silu" else 2
        per_dense_ff = mlp_mult * d * self.d_ff
        n = 0
        for blk in self.block_pattern:
            if blk in (ATTN_GLOBAL, ATTN_LOCAL):
                n += per_attn
            elif blk == RGLRU:
                w = self.lru_width
                # w_x, w_gate, w_out projections + w_a/w_i gate matrices
                n += 3 * d * w + 2 * w * w + 5 * w
            elif blk == SSD:
                d_in = self.ssm_expand * d
                n += 2 * d * d_in + d_in * self.ssm_state * 2 + d_in * d
            if self.n_experts:
                n += self.n_experts * mlp_mult * d * self.moe_d_ff + d * self.n_experts
                if self.dense_residual:
                    n += per_dense_ff
            elif blk not in (SSD,):
                n += per_dense_ff
            n += 2 * d  # norms
        n += self.vocab_size * d  # embedding (tied head)
        if not self.tie_embeddings:
            n += self.vocab_size * d
        if self.n_enc_layers:
            n += self.n_enc_layers * (per_attn + per_dense_ff + 2 * d)
            n += self.n_layers * per_attn  # decoder cross-attention
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        mlp_mult = 3 if self.act == "silu" else 2
        per_expert = mlp_mult * self.d_model * self.moe_d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * per_expert
        return self.param_count() - inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, tiny vocab.

        Keeps the *family shape* (same block kinds, GQA ratio, MoE top-k
        clipped) so smoke tests exercise the same code paths as the full
        config.
        """
        d = min(self.d_model, 256)
        heads = max(2, min(self.n_heads, 4))
        # keep the GQA flavour but ensure kv divides heads
        if self.n_kv_heads == self.n_heads:
            kv = heads
        elif self.n_kv_heads == 1:
            kv = 1
        else:
            kv = 2
        # preserve "pattern flavour": take 2 representative blocks
        kinds = []
        for k in (SSD, RGLRU, ATTN_LOCAL, ATTN_GLOBAL):
            if k in self.block_pattern:
                kinds.append(k)
        pattern = tuple((kinds * 2)[:2]) if kinds else (ATTN_GLOBAL, ATTN_GLOBAL)
        n_exp = min(self.n_experts, 4)
        # rescale M-RoPE sections (2:3:3 ratio) to the reduced head_dim
        half = (d // heads) // 2
        t_sec = half * 2 // 8
        h_sec = half * 3 // 8
        sections = (t_sec, h_sec, half - t_sec - h_sec)
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 512) or 512,
            moe_d_ff=min(self.moe_d_ff, 256) if self.n_experts else 0,
            vocab_size=min(self.vocab_size, 512),
            mrope_sections=sections,
            block_pattern=pattern,
            sliding_window=64,
            n_experts=n_exp,
            top_k=min(self.top_k, n_exp) if n_exp else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_chunk=8,
            ssm_head_dim=16,
            lru_width=d,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_audio_frames=16,
            n_vision_tokens=min(self.n_vision_tokens, 8),
            max_seq_len=512,
        )


@dataclass(frozen=True)
class CNNConfig:
    """The paper's MNIST / CIFAR CNNs (§4.1.1)."""

    name: str
    input_shape: Tuple[int, int, int]          # H, W, C
    conv_channels: Tuple[int, ...]             # per conv layer (5x5 kernels)
    pool_size: int
    pool_stride: int
    fc_units: Tuple[int, ...]
    n_classes: int = 10
    dropout: float = 0.5

    @property
    def feature_hw(self) -> Tuple[int, int]:
        h, w, _ = self.input_shape
        for _ in self.conv_channels:
            h = (h - self.pool_size) // self.pool_stride + 1
            w = (w - self.pool_size) // self.pool_stride + 1
        return h, w


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning round configuration (the paper's mechanisms)."""

    algorithm: str = "fedavg"         # an ALGORITHM_NAMES / registry name
    fusion_op: str = "multi"          # conv | multi | single   (fedfusion)
    mmd_lambda: float = 0.1           # λ for L_MMD (paper §4.2)
    mmd_widths: Tuple[float, ...] = (1.0, 2.0, 4.0, 8.0, 16.0)  # RBF multi-width
    l2_lambda: float = 0.01           # two-stream L2 baseline coefficient
    prox_mu: float = 0.01             # FedProx proximal strength (contrib)
    clients_per_round: int = 16       # C·K in the paper
    local_steps: int = 2              # batches per local epoch
    local_epochs: int = 1             # passes over the round's batches (E)
    cache_global_features: bool = True  # paper §3.3: compute the frozen
    # global stream's features once per round and reuse across epochs
    local_batch: int = 16             # B
    lr: float = 2e-3
    lr_decay: float = 1.0             # exponential decay per round
    momentum: float = 0.0
    ema_beta: float = 0.5             # gate EMA for multi/single aggregation
    optimizer: str = "sgd"            # sgd | adam
    weighted_by_examples: bool = True

    # --- communication codecs (repro.compress) ---
    uplink_codec: str = "identity"    # client -> server delta codec
    downlink_codec: str = "identity"  # server -> client broadcast codec
    topk_frac: float = 0.05           # kept fraction (topk / mask / lowrank)
    quant_bits: int = 8               # the "quant" codec's bit width

    # --- participation policy (repro.fl.participation) ---
    participation: str = "full_sync"  # a PARTICIPATION_NAMES / registry name
    over_provision: float = 1.5       # deadline: cohort C' = ceil(C * this)
    buffer_k: int = 0                 # buffered_async: close at K-th arrival
    # (0 -> clients_per_round // 2)
    staleness_alpha: float = 0.5      # buffered_async: (1+s)^(-alpha) weight

    # --- adaptive compression controller (repro.control) ---
    controller: str = "static"        # a CONTROLLER_NAMES / registry name
    ladder: Tuple[float, ...] = ()    # ascending effective levels, top =
    # the codec's static parameter; () -> a default 3-level topk ladder
    # (f/4, f/2, f) or the quant ladder (4, 8)
    ctrl_band: Tuple[float, float] = (0.5, 2.0)  # ef_ratio hold band
    ctrl_budget_frac: float = 0.5     # bytes_budget: frac of capacity/round
    ctrl_ema: float = 0.8             # controller signal EMA coefficient

    def __post_init__(self):
        # plain ValueErrors, not asserts: asserts vanish under python -O,
        # silently skipping config validation
        if self.algorithm not in ALGORITHM_NAMES:
            # runtime-registered plugin?  consult the registry lazily so
            # out-of-tree algorithms validate without editing this file
            from repro.fl.api import registered_algorithms
            if self.algorithm not in registered_algorithms():
                raise ValueError(
                    f"unknown algorithm {self.algorithm!r}; registered: "
                    f"{registered_algorithms()}")
        if self.fusion_op not in ("conv", "multi", "single"):
            raise ValueError(f"fusion_op {self.fusion_op!r} must be 'conv', "
                             "'multi' or 'single'")
        if self.uplink_codec not in CODEC_NAMES:
            raise ValueError(f"unknown uplink_codec {self.uplink_codec!r}; "
                             f"choose from {CODEC_NAMES}")
        if self.downlink_codec not in CODEC_NAMES:
            raise ValueError(
                f"unknown downlink_codec {self.downlink_codec!r}; choose "
                f"from {CODEC_NAMES}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac={self.topk_frac!r} must be in "
                             "(0, 1]")
        if self.quant_bits not in (4, 8):
            raise ValueError(f"quant_bits={self.quant_bits!r} must be 4 "
                             "or 8")
        if self.participation not in PARTICIPATION_NAMES:
            from repro.fl.participation import registered_policies
            if self.participation not in registered_policies():
                raise ValueError(
                    f"unknown participation {self.participation!r}; "
                    f"registered: {registered_policies()}")
        if self.over_provision < 1.0:
            raise ValueError(f"over_provision={self.over_provision!r} must "
                             "be >= 1.0")
        if self.buffer_k < 0:
            raise ValueError(f"buffer_k={self.buffer_k!r} must be >= 0")
        if self.staleness_alpha < 0.0:
            raise ValueError(f"staleness_alpha={self.staleness_alpha!r} "
                             "must be >= 0.0")
        if self.controller not in CONTROLLER_NAMES:
            from repro.control import registered_controllers
            if self.controller not in registered_controllers():
                raise ValueError(
                    f"unknown controller {self.controller!r}; registered: "
                    f"{registered_controllers()}")
        if self.ladder and (list(self.ladder) != sorted(set(self.ladder))):
            raise ValueError(f"ladder {self.ladder!r} must be strictly "
                             "ascending")
        if self.controller != "static" and \
                self.uplink_codec not in _LADDER_CODECS:
            raise ValueError(
                f"controller {self.controller!r} needs a ladder-capable "
                f"uplink codec {_LADDER_CODECS}, got "
                f"{self.uplink_codec!r}")
        if len(self.ctrl_band) != 2 or not \
                0.0 <= self.ctrl_band[0] < self.ctrl_band[1]:
            raise ValueError(f"ctrl_band {self.ctrl_band!r} must be "
                             "(lo, hi) with 0 <= lo < hi")
        if not 0.0 < self.ctrl_budget_frac <= 1.0:
            raise ValueError(
                f"ctrl_budget_frac={self.ctrl_budget_frac!r} must be in "
                "(0, 1]")
        if not 0.0 <= self.ctrl_ema < 1.0:
            raise ValueError(f"ctrl_ema={self.ctrl_ema!r} must be in "
                             "[0, 1)")

    @property
    def compressed(self) -> bool:
        return (self.uplink_codec, self.downlink_codec) != \
            ("identity", "identity")


@dataclass(frozen=True)
class InputShape:
    """One of the assigned (seq_len, global_batch) evaluation shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    def __post_init__(self):
        if self.kind not in ("train", "prefill", "decode"):
            raise ValueError(f"{self.name}: kind {self.kind!r} must be "
                             "'train', 'prefill' or 'decode'")


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def local_global_pattern(n_layers: int, local: int, global_: int,
                         window_kind: str = ATTN_LOCAL) -> Tuple[str, ...]:
    """`local:global` repeating pattern, e.g. gemma3's 5:1."""
    pat = []
    cycle = [window_kind] * local + [ATTN_GLOBAL] * global_
    while len(pat) < n_layers:
        pat.extend(cycle)
    return tuple(pat[:n_layers])


def hybrid_pattern(n_layers: int, recurrent: int = 2, attn: int = 1) -> Tuple[str, ...]:
    """RecurrentGemma's (RG-LRU, RG-LRU, local-attn) repeating pattern."""
    pat = []
    cycle = [RGLRU] * recurrent + [ATTN_LOCAL] * attn
    while len(pat) < n_layers:
        pat.extend(cycle)
    return tuple(pat[:n_layers])
