"""The paper's own CNN models (§4.1.1) for faithful reproduction.

MNIST: two 5x5 convs (32, 64 ch) each + ReLU + 2x2 maxpool, FC 512 + ReLU +
dropout, softmax head.
CIFAR: two 5x5 convs (64, 64 ch) each + ReLU + 3x3 maxpool stride 2,
FC 384 -> FC 192 each + ReLU + dropout, softmax head.
"""
from repro.configs.base import CNNConfig

CNN_MNIST = CNNConfig(
    name="cnn_mnist",
    input_shape=(28, 28, 1),
    conv_channels=(32, 64),
    pool_size=2,
    pool_stride=2,
    fc_units=(512,),
    n_classes=10,
)

CNN_CIFAR = CNNConfig(
    name="cnn_cifar",
    input_shape=(32, 32, 3),
    conv_channels=(64, 64),
    pool_size=3,
    pool_stride=2,
    fc_units=(384, 192),
    n_classes=10,
)
