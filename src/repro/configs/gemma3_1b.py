"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt]
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim=256,
sliding window 512 on local layers, every 6th layer global.
"""
from repro.configs.base import ArchConfig, local_global_pattern

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    block_pattern=local_global_pattern(26, local=5, global_=1),
    sliding_window=512,
    rope_theta=1e6,
    act="gelu",
    fl_mode="client_parallel",
    source="hf:google/gemma-3-1b-pt",
)
