"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA.

[arXiv:2401.16818]
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding-window attn.
"""
from repro.configs.base import ArchConfig, ATTN_LOCAL

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10_240,
    vocab_size=32_000,
    block_pattern=(ATTN_LOCAL,) * 24,
    sliding_window=4096,
    rope_theta=10_000.0,
    fl_mode="client_parallel",
    source="arXiv:2401.16818",
)
