"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060]
24L d_model=768, ssm_state=128, expand=2 (d_inner=1536), head_dim=64
(24 SSD heads), chunked SSD scan, vocab=50280.
"""
from repro.configs.base import ArchConfig, SSD

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,            # SSD heads = expand*d_model / ssm_head_dim
    n_kv_heads=24,
    head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    block_pattern=(SSD,) * 24,
    ssm_state=128,
    ssm_expand=2,
    ssm_chunk=64,
    ssm_head_dim=64,
    ssm_conv_width=4,
    fl_mode="client_parallel",
    source="arXiv:2405.21060",
)
