"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution.

[arXiv:2409.12191]
28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
The vision encoder (ViT + merger) is a STUB per the assignment: input_specs
provide precomputed patch embeddings of shape [B, n_vision_tokens, d_model];
the language backbone applies M-RoPE over (temporal, height, width) position
sections.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    mrope=True,
    mrope_sections=(16, 24, 24),   # t, h, w sections of the 64 rotary pairs
    rope_theta=1e6,
    n_vision_tokens=256,
    tie_embeddings=False,
    fl_mode="client_sequential",
    source="arXiv:2409.12191",
)
