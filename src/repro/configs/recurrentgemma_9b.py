"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2.

[arXiv:2402.19427]
38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000.
Block pattern repeats (RG-LRU, RG-LRU, local-attention).
"""
from repro.configs.base import ArchConfig, hybrid_pattern

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    block_pattern=hybrid_pattern(38, recurrent=2, attn=1),
    sliding_window=2048,
    lru_width=4096,
    rope_theta=10_000.0,
    act="gelu",
    fl_mode="client_sequential",
    source="arXiv:2402.19427",
)
