"""stablelm-3b [dense] — partial rotary embeddings.

[hf:stabilityai/stablelm-2-1_6b]
32L d_model=2560 32H (GQA kv=32, full MHA) d_ff=6912 vocab=50304,
25% partial rotary.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50_304,
    partial_rotary_pct=0.25,
    rope_theta=10_000.0,
    tie_embeddings=False,
    fl_mode="client_parallel",
    source="hf:stabilityai/stablelm-2-1_6b",
)
