"""whisper-large-v3 [audio] — encoder-decoder, conv frontend (stub).

[arXiv:2212.04356]
32L (decoder) d_model=1280 20H (kv=20, full MHA) d_ff=5120 vocab=51866,
plus a 32-layer encoder over 1500 stub frame embeddings.  The mel-spectrogram
+ conv feature extractor is a STUB per the assignment: input_specs provide
precomputed frame embeddings [B, 1500, 1280].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    n_enc_layers=32,
    n_audio_frames=1500,
    act="gelu",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
    fl_mode="client_parallel",
    source="arXiv:2212.04356",
)
