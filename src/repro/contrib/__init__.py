"""Out-of-core algorithm plugins built purely on the ``repro.fl.api``
hook interface — nothing here is imported by ``repro.core`` /
``repro.engine``; each module registers itself with
:func:`repro.fl.api.register_algorithm` exactly the way a third-party
package would."""
