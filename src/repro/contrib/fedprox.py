"""FedProx (Li et al., arXiv:1812.06127) as an OUT-OF-CORE plugin.

The point of this module is the demonstration, not the mechanism: a
proximal-term variant of the paper's client-side objective,

    L = L_cls(theta_L) + (mu / 2) * ||Theta_L - Theta_G||^2,

built purely from the public :class:`repro.fl.api.Algorithm` hook API —
no edits to ``repro.core``, ``repro.engine`` or the round functions.  It
composes with every wire codec, both execution modes, the K-round
superstep and the client-parallel ``shard_map`` engine for free, because
those layers only ever talk to the hook interface.  RingFed-style
partial averaging or a CFedAvg variant would register the same way.
"""
from __future__ import annotations

from repro.core.losses import l2_tree_distance
from repro.fl.api.algorithm import Algorithm, register_algorithm
from repro.fl.api.plugins import classify_loss

__all__ = ["FedProx"]


class FedProx(Algorithm):
    """Proximal local objective; strength via ``FLConfig.prox_mu``."""

    name = "fedprox"

    def local_loss(self, bundle, fl, trainable, global_model, batch,
                   cached_feats_g=None, *, impl="auto"):
        cls, _, _ = classify_loss(bundle, trainable["model"], batch)
        prox = 0.5 * fl.prox_mu * l2_tree_distance(trainable["model"],
                                                   global_model)
        return cls + prox, {"cls": cls, "prox": prox}


register_algorithm(FedProx())
