"""repro.control: in-superstep adaptive compression controllers.

The decision rule ROADMAP item 4 asked for: a plugin registry of
controllers whose state rides the jitted superstep's scan carry, reads
the round's on-device telemetry signals (``repro.obs``), and selects the
next round's effective compression level on a discrete codec ladder —
zero host round-trips, zero extra collectives.  See
``repro.control.controller`` for the protocol and the built-ins
(``static`` / ``ef_ratio`` / ``bytes_budget`` / ``loss_trend``).
"""
from repro.control.controller import (  # noqa: F401
    LADDER_CODECS, BytesBudgetController, Controller, EFRatioController,
    LadderSpec, LossTrendController, StaticController, ladder_kind,
    ladder_values, make_controller, register_controller,
    registered_controllers)
