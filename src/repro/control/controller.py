"""In-superstep adaptive compression controllers (ROADMAP item 4).

A :class:`Controller` is the decision rule that retunes the uplink codec
round over round — *inside the jitted superstep*, at zero host
round-trips.  The controller's state (a small dict of f32/int32 scalars)
rides the superstep's ``lax.scan`` carry exactly like the EF table and
the downlink mirror; its ``update`` hook runs replicated after the
round's psum completes, reading the telemetry signals the round already
computed (``tele/ef_delta_ratio``, ``local_loss``, ...) and emitting the
NEXT round's effective compression level.

Because wire shapes must stay static under jit, "retuning the codec"
means selecting a level on a discrete **ladder** of pre-bound codec
configurations: the codec is bound once at the ladder's top (capacity)
level and the traced ``level`` scalar masks the payload down to the
effective configuration (``repro.compress`` — top-k rank masking, quant
effective-qmax scaling).  The payload buffers crossing the wire keep the
capacity shape on device; what *would* cross a real network is the
effective per-level byte count, which ``LadderSpec.bytes_up`` carries and
``CommLog`` charges per round.

Contracts:

* ``controller="static"`` is the bitwise oracle — the engine
  short-circuits it to the exact pre-controller code path, so a static
  run is bit-identical to an engine without this subsystem.
* ``update`` consumes only psum-completed round metrics, so it adds ZERO
  collectives: the fused sharded round stays at exactly one psum with
  any controller on (jaxpr-asserted in ``tests/test_control.py``).
* Controller state checkpoints to ``ctrl.npz`` next to ``ef.npz``;
  interrupt+resume is bitwise-equal to an uninterrupted run across
  ``ef_store`` layouts.

Registered like every other plugin axis (``make_codec`` /
``make_algorithm`` / ``make_policy``): ``register_controller`` /
``make_controller`` / ``registered_controllers``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax.numpy as jnp

__all__ = ["LadderSpec", "Controller", "StaticController",
           "EFRatioController", "BytesBudgetController",
           "LossTrendController", "register_controller", "make_controller",
           "registered_controllers", "ladder_kind", "ladder_values",
           "LADDER_CODECS"]

# uplink codecs that support a level ladder (repro.compress.set_ladder)
LADDER_CODECS = ("topk", "topk_noef", "quant", "int8", "int4")

# loss_trend: relative EMA-loss improvement below this reads as a plateau
_TREND_THRESH = 0.01


def ladder_kind(uplink_codec: str) -> str:
    """The ladder's parameter axis for a codec name."""
    if uplink_codec in ("topk", "topk_noef"):
        return "topk_frac"
    if uplink_codec in ("quant", "int8", "int4"):
        return "quant_bits"
    raise ValueError(
        f"uplink codec {uplink_codec!r} has no compression ladder; "
        f"adaptive controllers support {LADDER_CODECS}")


def ladder_values(fl) -> Tuple[float, ...]:
    """The run's ladder (ascending effective levels, top = capacity).

    ``fl.ladder`` when given — validated against the uplink codec family
    and required to top out at the configured static parameter (so level
    ``n_levels-1`` IS the configured codec, and the wire capacity equals
    the static run's).  Empty defaults to a 3-level top-k ladder
    ``(f/4, f/2, f)`` or the quant ladder ``(4, 8)`` / ``(4,)``.
    """
    kind = ladder_kind(fl.uplink_codec)
    # the capacity the codec actually binds at: int8/int4 fix their bits
    # by name; "quant" reads fl.quant_bits
    cap = (int(fl.uplink_codec[3:]) if fl.uplink_codec in ("int8", "int4")
           else int(getattr(fl, "quant_bits", 8)))
    vals = tuple(fl.ladder)
    if not vals:
        if kind == "topk_frac":
            f = fl.topk_frac
            return (f / 4.0, f / 2.0, f)
        return (4, 8) if cap == 8 else (4,)
    if list(vals) != sorted(vals) or len(set(vals)) != len(vals):
        raise ValueError(f"ladder {vals} must be strictly ascending")
    if kind == "topk_frac":
        if not all(0.0 < v <= 1.0 for v in vals):
            raise ValueError(f"topk ladder {vals} needs fracs in (0, 1]")
        if vals[-1] != fl.topk_frac:
            raise ValueError(
                f"ladder top {vals[-1]} must equal topk_frac="
                f"{fl.topk_frac} (the codec binds at capacity)")
    else:
        if not all(v in (4, 8) for v in vals):
            raise ValueError(f"quant ladder {vals} needs bits in (4, 8)")
        if int(vals[-1]) != cap:
            raise ValueError(
                f"ladder top {vals[-1]} must equal the uplink codec's "
                f"capacity bits {cap} (the codec binds at capacity)")
    return vals


@dataclass(frozen=True)
class LadderSpec:
    """The discrete level ladder one run compresses along.

    ``values`` ascends (cheapest level 0 -> capacity); ``bytes_up`` is
    the effective per-client uplink payload bytes at each level (from
    ``Codec.level_bytes()`` — what a real wire would carry, used by the
    CommLog accounting and the bytes-budget controller).
    """

    kind: str                       # "topk_frac" | "quant_bits"
    values: Tuple[float, ...]
    bytes_up: Tuple[int, ...]

    def __post_init__(self):
        if len(self.values) != len(self.bytes_up):
            raise ValueError("values / bytes_up length mismatch")
        if not self.values:
            raise ValueError("a ladder needs at least one level")

    @property
    def n_levels(self) -> int:
        return len(self.values)

    def bytes_table(self) -> jnp.ndarray:
        """[n_levels] f32 effective-bytes lookup (traced ``jnp.take``)."""
        return jnp.asarray(self.bytes_up, jnp.float32)


class Controller:
    """Base controller: subclass, set ``name``/``requires_taps``,
    implement ``init_state``/``update``.

    ``update(state, metrics)`` is TRACED inside the round (post-psum,
    replicated on every shard): ``metrics`` is the round's metric dict
    (``local_loss`` plus the active ``tele/...`` telemetry signals — all
    psum-completed scalars, identical on every shard), and the returned
    state dict must keep the incoming structure/dtypes (it rides the scan
    carry).  ``state["level"]`` is the contract key: the level the NEXT
    round encodes at.  ``requires_taps`` names the telemetry taps whose
    signals ``update`` reads; the engine forces them on.
    """

    name: str = "?"
    requires_taps: Tuple[str, ...] = ()

    def __init__(self):
        self.spec: LadderSpec = None  # bound by setup()

    def setup(self, spec: LadderSpec, fl) -> "Controller":
        """Bind the run's ladder + knobs (called once by the engine)."""
        self.spec = spec
        self.band = tuple(getattr(fl, "ctrl_band", (0.5, 2.0)))
        self.ema = float(getattr(fl, "ctrl_ema", 0.8))
        self.budget_frac = float(getattr(fl, "ctrl_budget_frac", 0.5))
        return self

    def _top(self) -> jnp.ndarray:
        return jnp.asarray(self.spec.n_levels - 1, jnp.int32)

    def _clip(self, level) -> jnp.ndarray:
        return jnp.clip(level, 0, self.spec.n_levels - 1).astype(jnp.int32)

    def init_state(self) -> Dict[str, jnp.ndarray]:
        return {"level": self._top()}

    def update(self, state: Dict[str, jnp.ndarray],
               metrics: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
        return state


class StaticController(Controller):
    """Today's behaviour: the configured codec every round.  The engine
    short-circuits this name to the exact pre-controller code path (no
    ladder, no controller state in the carry) — the bitwise oracle."""

    name = "static"


class EFRatioController(Controller):
    """Track ``tele/ef_delta_ratio`` (EF residual mass / delta mass) in a
    band: a rising ratio means the codec defers too much update round
    over round -> loosen one level; a ratio below the band means there is
    headroom -> tighten one level.  Starts at level 0 (cheapest) and
    escalates only when the error-feedback memory says it must — the
    CFedAvg-style schedule that beats the best static codec on
    bytes-to-milestone (``benchmarks/fig7_compression.py --adaptive``)."""

    name = "ef_ratio"
    requires_taps = ("ef",)

    def init_state(self):
        return {"level": jnp.zeros((), jnp.int32),
                "ema": jnp.zeros((), jnp.float32)}

    def update(self, state, metrics):
        ratio = jnp.asarray(metrics["tele/ef_delta_ratio"], jnp.float32)
        a = jnp.float32(self.ema)
        ema = a * state["ema"] + (1.0 - a) * ratio
        lo, hi = self.band
        step = ((ema > hi).astype(jnp.int32)
                - (ema < lo).astype(jnp.int32))
        return {"level": self._clip(state["level"] + step), "ema": ema}


class BytesBudgetController(Controller):
    """Feedback to a cumulative uplink-bytes target: spend at most
    ``ctrl_budget_frac`` of the capacity level's bytes per round on
    average.  Over budget -> tighten, under -> loosen; the running spend
    rides the controller state, so the rule needs no host accounting."""

    name = "bytes_budget"

    def init_state(self):
        return {"level": jnp.zeros((), jnp.int32),
                "spent": jnp.zeros((), jnp.float32),
                "rounds": jnp.zeros((), jnp.float32)}

    def update(self, state, metrics):
        spent = state["spent"] + jnp.take(self.spec.bytes_table(),
                                          state["level"])
        rounds = state["rounds"] + 1.0
        budget = jnp.float32(self.budget_frac * self.spec.bytes_up[-1])
        step = jnp.where(spent > budget * rounds, -1, 1).astype(jnp.int32)
        return {"level": self._clip(state["level"] + step),
                "spent": spent, "rounds": rounds}


class LossTrendController(Controller):
    """Loosen when the loss plateaus, stay cheap while it still falls:
    an EMA of the round loss is compared against its previous value, and
    a relative improvement under 1% reads as a plateau (the codec's
    compression error may be the binding constraint -> one level up)."""

    name = "loss_trend"

    def init_state(self):
        return {"level": jnp.zeros((), jnp.int32),
                "ema": jnp.zeros((), jnp.float32),
                "seen": jnp.zeros((), jnp.float32)}

    def update(self, state, metrics):
        loss = jnp.asarray(metrics["local_loss"], jnp.float32)
        a = jnp.float32(self.ema)
        first = state["seen"] < 0.5
        ema = jnp.where(first, loss, a * state["ema"] + (1.0 - a) * loss)
        rel = (state["ema"] - ema) / jnp.maximum(jnp.abs(ema), 1e-8)
        step = jnp.where(rel < _TREND_THRESH, 1, -1).astype(jnp.int32)
        lvl = self._clip(state["level"]
                         + jnp.where(first, 0, step).astype(jnp.int32))
        return {"level": lvl, "ema": ema, "seen": state["seen"] + 1.0}


# --------------------------------------------------------------------------
# Registry (mirrors repro.fl.participation / repro.fl.api / make_codec)
# --------------------------------------------------------------------------

Factory = Callable[[], Controller]

_REGISTRY: Dict[str, Factory] = {}
_BUILTINS_REGISTERED = False


def register_controller(name: str, factory: Factory, *,
                        overwrite: bool = False) -> None:
    """Add a controller to the registry (plugins call this exactly like
    ``register_policy`` / ``register_algorithm``)."""
    _ensure_builtins()
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"controller {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def make_controller(name: str) -> Controller:
    """Instantiate a registered controller by name (unbound — the engine
    calls ``setup(spec, fl)`` with the run's ladder)."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise ValueError(f"unknown controller {name!r}; registered: "
                         f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def registered_controllers() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def _ensure_builtins() -> None:
    global _BUILTINS_REGISTERED
    if _BUILTINS_REGISTERED:
        return
    _BUILTINS_REGISTERED = True
    _REGISTRY["static"] = StaticController
    _REGISTRY["ef_ratio"] = EFRatioController
    _REGISTRY["bytes_budget"] = BytesBudgetController
    _REGISTRY["loss_trend"] = LossTrendController
