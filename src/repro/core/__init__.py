"""The paper's contribution as a composable JAX module.

Public API:
    make_round_fn(bundle, fl_config, mode)  -> jit-able federated round
    init_global_state(bundle, fl_config, key)
    fusion_init / fusion_apply / fusion_aggregate
    mmd_loss

The algorithm-specific math (the per-mechanism local objectives,
extra-state aggregation and deploy-time logits) lives in
``repro.fl.api`` plugins; the factories here resolve the plugin from
``fl_config.algorithm`` and stay mechanism-agnostic.
"""
from repro.core.fusion import (FUSION_OPS, fusion_aggregate, fusion_apply,
                               fusion_init)  # noqa: F401
from repro.core.local import make_local_loss, make_local_trainer  # noqa: F401
from repro.core.losses import (accuracy, cross_entropy,  # noqa: F401
                               masked_accuracy, masked_accuracy_sum,
                               masked_cross_entropy,
                               masked_cross_entropy_sum)
from repro.core.mmd import mmd_loss  # noqa: F401
from repro.core.rounds import init_global_state, make_round_fn  # noqa: F401
