"""Server-side aggregation (paper Alg. 1 / Alg. 2 line 7).

All aggregations take an optional ``shard`` — a :class:`ClientSharding`
describing how the round's client axis is split over mesh axes inside a
``shard_map`` body.  With ``shard=None`` (the default, and the only mode
exercised on a single device) every function is exactly the pre-sharding
code path: a pure in-shard reduction with no collectives, so single-device
results stay bitwise-identical.  With a shard, each function reduces its
local clients in-shard and finishes with one ``psum`` over the client mesh
axes — the only cross-device communication FedAvg actually requires.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ClientSharding:
    """How the round's client axis maps onto mesh axes (``shard_map`` body).

    ``axes``/``sizes``: the mesh axis names the client dimension is split
    over (in major-to-minor order, e.g. ``("pod", "data")``) and their
    static sizes.  Instances only make sense inside a ``shard_map`` over
    those axes; the factories in ``repro.core.rounds`` treat ``None`` as
    "unsharded".
    """

    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]

    @property
    def axis_name(self):
        """The axis-name argument collectives take (str or tuple)."""
        return self.axes if len(self.axes) > 1 else self.axes[0]

    @property
    def n_shards(self) -> int:
        n = 1
        for s in self.sizes:
            n *= s
        return n

    def position(self):
        """This shard's row-major position along the client axis (traced)."""
        pos = jnp.zeros((), jnp.int32)
        for a, s in zip(self.axes, self.sizes):
            pos = pos * s + jax.lax.axis_index(a)
        return pos


def psum_tree(tree, shard: ClientSharding):
    """``psum`` every leaf over the client axes (identity when unsharded)."""
    if shard is None:
        return tree
    return jax.lax.psum(tree, shard.axis_name)


def fused_psum(tree, shard: ClientSharding):
    """Sum every leaf over the client axes in ONE collective.

    Ravels and concatenates all leaves into a single flat buffer, runs one
    ``psum`` over it, and unpacks via static slices — pack offsets are pure
    trace-time Python (leaf shapes are static), so the whole exchange
    lowers to a single all-reduce regardless of how many quantities ride
    it.  ``psum`` reduces elementwise in a participant order fixed by the
    mesh, so every unpacked leaf is bitwise what a standalone ``psum`` of
    that leaf would have produced — packing is a latency optimization,
    never a numerics change.  Identity when unsharded.

    All leaves must share one dtype (the engine's fused round buckets are
    float32 end to end); mixed-dtype trees raise instead of silently
    promoting through the concatenation.
    """
    if shard is None:
        return tree
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        return tree
    mixed = {str(l.dtype) for l in leaves}
    if len(mixed) > 1:
        raise TypeError(
            f"fused_psum needs a single-dtype tree, got {sorted(mixed)}; "
            f"run the unfused collectives (fused_collective=False) for "
            f"mixed-precision buckets")
    flat = (jnp.concatenate([jnp.ravel(l) for l in leaves])
            if len(leaves) > 1 else jnp.ravel(leaves[0]))
    summed = jax.lax.psum(flat, shard.axis_name)
    out, off = [], 0
    for l in leaves:
        out.append(jax.lax.slice_in_dim(summed, off, off + l.size)
                   .reshape(l.shape))
        off += l.size
    return jax.tree_util.tree_unflatten(treedef, out)


def normalize_weights(n_examples, shard: ClientSharding = None):
    n = jnp.asarray(n_examples, jnp.float32)
    total = jnp.sum(n)
    if shard is not None:
        total = jax.lax.psum(total, shard.axis_name)
    return n / total


def weighted_mean(stacked_tree, weights, shard: ClientSharding = None):
    """stacked_tree: pytree with leading client axis; weights [n_clients].

    Sharded: the tensordot reduces this shard's clients, the trailing
    ``psum`` completes the sum over the full round (weights are globally
    normalized by :func:`normalize_weights`).
    """
    local = jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(x.dtype), x, axes=1),
        stacked_tree)
    return psum_tree(local, shard)


def mean_over_clients(values, shard: ClientSharding = None):
    """Mean of a per-client [C_local] array over the FULL round's clients."""
    m = jnp.mean(values)
    if shard is None:
        return m
    return jax.lax.pmean(m, shard.axis_name)


def masked_loss_sums(losses, pmask):
    """Psum-pending numerator/denominator of a participation-masked mean
    loss.  Rides whatever collective the caller already makes (the fused
    one-psum contribs or the unfused ``psum_tree`` pack) — masking adds
    no collectives of its own."""
    m = pmask.astype(losses.dtype)
    return {"lsum": jnp.sum(losses * m), "lw": jnp.sum(m)}


def finish_masked_loss(summed):
    """Post-psum completion of :func:`masked_loss_sums` (the staleness /
    participation finish step: division happens once, after the sum over
    every shard's surviving clients)."""
    return summed["lsum"] / jnp.maximum(summed["lw"], 1.0)


def running_update(acc_tree, tree, weight):
    """acc += weight * tree   (client_sequential accumulation)."""
    return jax.tree.map(lambda a, x: a + weight.astype(x.dtype) * x,
                        acc_tree, tree)


def zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)
