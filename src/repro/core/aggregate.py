"""Server-side aggregation (paper Alg. 1 / Alg. 2 line 7)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normalize_weights(n_examples):
    n = jnp.asarray(n_examples, jnp.float32)
    return n / jnp.sum(n)


def weighted_mean(stacked_tree, weights):
    """stacked_tree: pytree with leading client axis; weights [n_clients]."""
    return jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(x.dtype), x, axes=1),
        stacked_tree)


def running_update(acc_tree, tree, weight):
    """acc += weight * tree   (client_sequential accumulation)."""
    return jax.tree.map(lambda a, x: a + weight.astype(x.dtype) * x,
                        acc_tree, tree)


def zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)
