"""Server-side aggregation (paper Alg. 1 / Alg. 2 line 7).

All aggregations take an optional ``shard`` — a :class:`ClientSharding`
describing how the round's client axis is split over mesh axes inside a
``shard_map`` body.  With ``shard=None`` (the default, and the only mode
exercised on a single device) every function is exactly the pre-sharding
code path: a pure in-shard reduction with no collectives, so single-device
results stay bitwise-identical.  With a shard, each function reduces its
local clients in-shard and finishes with one ``psum`` over the client mesh
axes — the only cross-device communication FedAvg actually requires.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ClientSharding:
    """How the round's client axis maps onto mesh axes (``shard_map`` body).

    ``axes``/``sizes``: the mesh axis names the client dimension is split
    over (in major-to-minor order, e.g. ``("pod", "data")``) and their
    static sizes.  Instances only make sense inside a ``shard_map`` over
    those axes; the factories in ``repro.core.rounds`` treat ``None`` as
    "unsharded".
    """

    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]

    @property
    def axis_name(self):
        """The axis-name argument collectives take (str or tuple)."""
        return self.axes if len(self.axes) > 1 else self.axes[0]

    @property
    def n_shards(self) -> int:
        n = 1
        for s in self.sizes:
            n *= s
        return n

    def position(self):
        """This shard's row-major position along the client axis (traced)."""
        pos = jnp.zeros((), jnp.int32)
        for a, s in zip(self.axes, self.sizes):
            pos = pos * s + jax.lax.axis_index(a)
        return pos


def psum_tree(tree, shard: ClientSharding):
    """``psum`` every leaf over the client axes (identity when unsharded)."""
    if shard is None:
        return tree
    return jax.lax.psum(tree, shard.axis_name)


def normalize_weights(n_examples, shard: ClientSharding = None):
    n = jnp.asarray(n_examples, jnp.float32)
    total = jnp.sum(n)
    if shard is not None:
        total = jax.lax.psum(total, shard.axis_name)
    return n / total


def weighted_mean(stacked_tree, weights, shard: ClientSharding = None):
    """stacked_tree: pytree with leading client axis; weights [n_clients].

    Sharded: the tensordot reduces this shard's clients, the trailing
    ``psum`` completes the sum over the full round (weights are globally
    normalized by :func:`normalize_weights`).
    """
    local = jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(x.dtype), x, axes=1),
        stacked_tree)
    return psum_tree(local, shard)


def mean_over_clients(values, shard: ClientSharding = None):
    """Mean of a per-client [C_local] array over the FULL round's clients."""
    m = jnp.mean(values)
    if shard is None:
        return m
    return jax.lax.pmean(m, shard.axis_name)


def running_update(acc_tree, tree, weight):
    """acc += weight * tree   (client_sequential accumulation)."""
    return jax.tree.map(lambda a, x: a + weight.astype(x.dtype) * x,
                        acc_tree, tree)


def zeros_like_tree(tree):
    return jax.tree.map(jnp.zeros_like, tree)
