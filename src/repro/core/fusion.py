"""FedFusion feature-fusion modules (paper §3.2).

Operators map (E_g(x), E_l(x)) in R^{...xC} x R^{...xC} -> R^{...xC}:
  conv   : W . concat(E_g, E_l) over channels, W in R^{2C x C}
  multi  : lam * E_g + (1 - lam) * E_l, learned per-channel lam in R^C
  single : scalar learned lam

The channel axis is the last axis: C x H x W CNN feature maps are handled
as NHWC, transformer hidden states as [B, S, d] with C = d.

Aggregation: `conv` weights average like any parameter; `multi`/`single`
gates use an exponential moving average (paper §3.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import dense_init

FUSION_OPS = ("conv", "multi", "single")


def fusion_init(op: str, channels: int, key, dtype=jnp.float32):
    if op == "conv":
        # initialise at "average the two streams": W = 0.5 * [I; I]
        eye = jnp.eye(channels, dtype=dtype)
        w = jnp.concatenate([0.5 * eye, 0.5 * eye], axis=0)
        noise = dense_init(key, (2 * channels, channels), dtype) * 0.01
        return {"w": w + noise}
    if op == "multi":
        return {"lam": jnp.full((channels,), 0.5, dtype)}
    if op == "single":
        return {"lam": jnp.full((), 0.5, dtype)}
    raise ValueError(op)


def fusion_apply(op: str, params, f_g, f_l, *, impl="auto"):
    if op == "conv":
        return ops.fused_fusion_conv(f_g, f_l, params["w"], impl=impl)
    lam = params["lam"]
    return lam * f_g + (1.0 - lam) * f_l


def fusion_aggregate(op: str, old_global, client_fusions, weights, ema_beta,
                     shard=None):
    """Aggregate per-client fusion params returned after local training.

    ``client_fusions``: pytree with a leading client axis.
    ``weights``: [n_clients], sums to 1 (n_t-weighted).
    conv -> weighted average; multi/single -> EMA between the old global
    gate and the weighted client average (paper: EMA smoothing).

    ``shard`` (:class:`repro.core.aggregate.ClientSharding`): inside a
    ``shard_map`` body the client axis holds only this shard's clients;
    the weighted average is completed with one ``psum`` over the client
    mesh axes BEFORE the EMA (the gate statistic is a round-global
    quantity, the EMA must see the full-round average exactly once).
    """
    from repro.core.aggregate import psum_tree
    avg = psum_tree(jax.tree.map(
        lambda x: jnp.tensordot(weights, x, axes=1), client_fusions), shard)
    if op == "conv":
        return avg
    return jax.tree.map(
        lambda old, new: ema_beta * old + (1.0 - ema_beta) * new,
        old_global, avg)
