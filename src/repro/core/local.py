"""On-device (client-side) training — the paper's additional mechanisms.

A client receives the global model, builds its *trainable* state (local
model copy + whatever extra state the algorithm plugin carries — the
fusion module for FedFusion), and runs ``fl.local_steps`` SGD steps with
the algorithm's objective.  The objective itself lives in the
:class:`repro.fl.api.Algorithm` plugin (``local_loss`` hook); this module
supplies the mechanism-independent machinery: the optimizer loop, the
epoch/step ``lax.scan`` nesting, and the paper-§3.3 frozen-stream feature
cache that two-stream algorithms (FedMMD, FedFusion) opt into via
``Algorithm.two_stream``.

The frozen global stream is closed over and NEVER updated during local
training (paper Fig. 1: "the global model is fixed while the local model
is trained through back propagation").
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.models.registry import ModelBundle
from repro.optim import make_optimizer


def _algorithm(fl: FLConfig):
    # lazy: repro.fl.api sits above repro.core in the package graph
    # (repro.fl/__init__ pulls in modules that import repro.core), so the
    # plugin is resolved at factory-call time, never at module import.
    from repro.fl.api import make_algorithm
    return make_algorithm(fl.algorithm)


def make_local_loss(bundle: ModelBundle, fl: FLConfig, *, impl="auto"):
    algo = _algorithm(fl)

    def loss_fn(trainable, global_model, batch, cached_feats_g=None):
        """``cached_feats_g``: precomputed frozen-stream features for this
        batch (paper §3.3 — E_g's maps can be recorded once per round);
        None recomputes them (the E=1 / uncached path)."""
        return algo.local_loss(bundle, fl, trainable, global_model, batch,
                               cached_feats_g, impl=impl)

    return loss_fn


def make_local_trainer(bundle: ModelBundle, fl: FLConfig, *, impl="auto"):
    """Returns local_train(global_model, global_extra, batches, lr) ->
    (trainable, mean_loss).

    ``global_extra`` is the algorithm's extra global state
    (``Algorithm.extra_from_state`` — the fusion params for FedFusion,
    None for single-stream algorithms).
    ``batches``: pytree whose leaves have leading dim ``fl.local_steps``
    (one local SGD step per slice).
    """
    algo = _algorithm(fl)
    opt_init, opt_update = make_optimizer(fl.optimizer, fl.momentum)
    loss_fn = make_local_loss(bundle, fl, impl=impl)

    cache = (fl.cache_global_features and algo.two_stream
             and fl.local_epochs > 1)

    def local_train(global_model, global_extra, batches, lr):
        trainable: Dict[str, Any] = algo.init_trainable(fl, global_model,
                                                        global_extra)
        state = opt_init(trainable)

        cached = None
        if cache:
            # paper §3.3: the frozen E_g features for the round's batches
            # are computed ONCE and reused across the E local epochs —
            # saves (E-1) global-stream forwards per client per round.
            def extract_one(_, batch):
                f, _aux = bundle.extract(
                    jax.lax.stop_gradient(global_model), batch)
                return None, jax.lax.stop_gradient(f)

            _, cached = jax.lax.scan(extract_one, None, batches)

        def step_cached(carry, xs):
            batch, feats_g = xs
            tr, st = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                tr, global_model, batch, feats_g)
            tr, st = opt_update(tr, grads, st, lr)
            return (tr, st), loss

        def step_plain(carry, batch):
            tr, st = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                tr, global_model, batch)
            tr, st = opt_update(tr, grads, st, lr)
            return (tr, st), loss

        def epoch(carry, _):
            if cache:
                return jax.lax.scan(step_cached, carry, (batches, cached))
            return jax.lax.scan(step_plain, carry, batches)

        if fl.local_epochs > 1:
            (trainable, _), losses = jax.lax.scan(
                epoch, (trainable, state), None, length=fl.local_epochs)
        else:
            (trainable, _), losses = jax.lax.scan(
                step_plain, (trainable, state), batches)
        return trainable, jnp.mean(losses)

    return local_train
