"""On-device (client-side) training — the paper's additional mechanisms.

A client receives the global model, builds its *trainable* state
(local model copy + fusion module for FedFusion), and runs
``fl.local_steps`` SGD steps with the algorithm's two-stream objective:

  fedavg    L = L_cls(theta_L)
  fedmmd    L = L_cls(theta_L) + lam * MMD^2(theta_G(X), theta_L(X))
  fedl2     L = L_cls(theta_L) + lam2 * ||Theta_L - Theta_G||^2
  fedfusion L = L_cls(C_L(F(E_l(X), E_g(X))))   with E_g frozen

The frozen global stream is closed over and NEVER updated during local
training (paper Fig. 1: "the global model is fixed while the local model is
trained through back propagation").
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.fusion import fusion_apply
from repro.core.losses import cross_entropy, l2_tree_distance
from repro.core.mmd import mmd_loss
from repro.models.registry import ModelBundle
from repro.optim import make_optimizer

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def make_local_loss(bundle: ModelBundle, fl: FLConfig, *, impl="auto"):
    def loss_fn(trainable, global_model, batch, cached_feats_g=None):
        """``cached_feats_g``: precomputed frozen-stream features for this
        batch (paper §3.3 — E_g's maps can be recorded once per round);
        None recomputes them (the E=1 / uncached path)."""
        labels = bundle.labels(batch)
        local = trainable["model"]
        if fl.algorithm == "fedfusion":
            feats_l, aux = bundle.extract(local, batch)
            if cached_feats_g is None:
                cached_feats_g, _ = bundle.extract(
                    jax.lax.stop_gradient(global_model), batch)
            feats_g = jax.lax.stop_gradient(cached_feats_g)
            fused = fusion_apply(fl.fusion_op, trainable["fusion"],
                                 feats_g, feats_l, impl=impl)
            logits = bundle.head(local, fused)
            loss = cross_entropy(logits, labels) + AUX_WEIGHT * aux
            return loss, {"cls": loss}
        out = bundle.apply(local, batch)
        cls = cross_entropy(out["logits"], labels) + AUX_WEIGHT * out["aux"]
        if fl.algorithm == "fedavg":
            return cls, {"cls": cls}
        if fl.algorithm == "fedmmd":
            if cached_feats_g is None:
                cached_feats_g, _ = bundle.extract(
                    jax.lax.stop_gradient(global_model), batch)
            reg = mmd_loss(bundle.pool(out["features"]),
                           jax.lax.stop_gradient(
                               bundle.pool(cached_feats_g)),
                           fl.mmd_widths, fl.mmd_lambda, impl=impl)
            return cls + reg, {"cls": cls, "mmd": reg}
        if fl.algorithm == "fedl2":
            reg = fl.l2_lambda * l2_tree_distance(local, global_model)
            return cls + reg, {"cls": cls, "l2": reg}
        raise ValueError(fl.algorithm)

    return loss_fn


def make_local_trainer(bundle: ModelBundle, fl: FLConfig, *, impl="auto"):
    """Returns local_train(global_model, global_fusion, batches, lr) ->
    (trainable, mean_loss).

    ``batches``: pytree whose leaves have leading dim ``fl.local_steps``
    (one local SGD step per slice).
    """
    opt_init, opt_update = make_optimizer(fl.optimizer, fl.momentum)
    loss_fn = make_local_loss(bundle, fl, impl=impl)

    two_stream = fl.algorithm in ("fedfusion", "fedmmd")
    cache = (fl.cache_global_features and two_stream
             and fl.local_epochs > 1)

    def local_train(global_model, global_fusion, batches, lr):
        trainable: Dict[str, Any] = {"model": global_model}
        if fl.algorithm == "fedfusion":
            trainable["fusion"] = global_fusion
        state = opt_init(trainable)

        cached = None
        if cache:
            # paper §3.3: the frozen E_g features for the round's batches
            # are computed ONCE and reused across the E local epochs —
            # saves (E-1) global-stream forwards per client per round.
            def extract_one(_, batch):
                f, _aux = bundle.extract(
                    jax.lax.stop_gradient(global_model), batch)
                return None, jax.lax.stop_gradient(f)

            _, cached = jax.lax.scan(extract_one, None, batches)

        def step_cached(carry, xs):
            batch, feats_g = xs
            tr, st = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                tr, global_model, batch, feats_g)
            tr, st = opt_update(tr, grads, st, lr)
            return (tr, st), loss

        def step_plain(carry, batch):
            tr, st = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                tr, global_model, batch)
            tr, st = opt_update(tr, grads, st, lr)
            return (tr, st), loss

        def epoch(carry, _):
            if cache:
                return jax.lax.scan(step_cached, carry, (batches, cached))
            return jax.lax.scan(step_plain, carry, batches)

        if fl.local_epochs > 1:
            (trainable, _), losses = jax.lax.scan(
                epoch, (trainable, state), None, length=fl.local_epochs)
        else:
            (trainable, _), losses = jax.lax.scan(
                step_plain, (trainable, state), batches)
        return trainable, jnp.mean(losses)

    return local_train
