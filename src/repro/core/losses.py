"""Classification / LM losses + the two-stream local objectives."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    """logits [..., V]; labels [...] int -> scalar mean CE.

    The gold logit is gathered via a one-hot contraction (not
    take_along_axis): with the vocabulary dim sharded over the `model` mesh
    axis this fuses to a masked local reduction + psum instead of a
    cross-shard gather.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(logz - gold)


def accuracy(logits, labels):
    return jnp.mean(jnp.argmax(logits, axis=-1) == labels)


def _broadcast_mask(mask, labels):
    """Per-example mask [B] -> weights broadcast to the labels' shape
    ([B] for classification, [B, S] for LM token labels)."""
    mask = mask.astype(jnp.float32)
    return jnp.broadcast_to(
        mask.reshape(mask.shape + (1,) * (labels.ndim - mask.ndim)),
        labels.shape)


def masked_cross_entropy_sum(logits, labels, mask):
    """Masked CE *sum* and weight sum: ``(Σ ce·w, Σ w)``.

    The un-normalized form is what cross-shard evaluation psums — each
    shard reduces its slice of the padded batch, one collective adds the
    numerators and the true example count, and the quotient equals the
    full-batch masked mean (pad rows carry zero weight on every shard)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    w = _broadcast_mask(mask, labels)
    return jnp.sum((logz - gold) * w), jnp.sum(w)


def masked_accuracy_sum(logits, labels, mask):
    """Masked correct-prediction *sum* and weight sum: ``(Σ 1[correct]·w,
    Σ w)`` — the psum-able form of :func:`masked_accuracy`."""
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    w = _broadcast_mask(mask, labels)
    return jnp.sum(correct * w), jnp.sum(w)


def masked_cross_entropy(logits, labels, mask):
    """Mean CE over the valid examples only (mask [B] bool/float).

    The padded tail of a fixed-shape eval batch contributes zero weight, so
    one compiled evaluator serves any test-set size (repro.fl.server)."""
    ce_sum, w_sum = masked_cross_entropy_sum(logits, labels, mask)
    return ce_sum / jnp.maximum(w_sum, 1.0)


def masked_accuracy(logits, labels, mask):
    """Accuracy over the valid examples only (mask [B] bool/float)."""
    correct_sum, w_sum = masked_accuracy_sum(logits, labels, mask)
    return correct_sum / jnp.maximum(w_sum, 1.0)


def l2_tree_distance(tree_a, tree_b):
    """Sum of squared parameter distances (the paper's L2 two-stream
    baseline constraint)."""
    leaves = jax.tree.map(
        lambda a, b: jnp.sum(jnp.square(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))),
        tree_a, tree_b)
    return jax.tree.reduce(jnp.add, leaves)
