"""Classification / LM losses + the two-stream local objectives."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    """logits [..., V]; labels [...] int -> scalar mean CE.

    The gold logit is gathered via a one-hot contraction (not
    take_along_axis): with the vocabulary dim sharded over the `model` mesh
    axis this fuses to a masked local reduction + psum instead of a
    cross-shard gather.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(logz - gold)


def accuracy(logits, labels):
    return jnp.mean(jnp.argmax(logits, axis=-1) == labels)


def l2_tree_distance(tree_a, tree_b):
    """Sum of squared parameter distances (the paper's L2 two-stream
    baseline constraint)."""
    leaves = jax.tree.map(
        lambda a, b: jnp.sum(jnp.square(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))),
        tree_a, tree_b)
    return jax.tree.reduce(jnp.add, leaves)
