"""MK-MMD loss for two-stream federated learning (paper §2.2, §3.1)."""
from __future__ import annotations

from repro.kernels import ops


def mmd_loss(local_feats, global_feats, widths, lam, *, impl="auto"):
    """lam * MMD^2(theta_G(X), theta_L(X))  — paper Eq. (5).

    ``local_feats`` / ``global_feats``: pooled per-example features [B, C]
    (the outputs of the two streams on the same local batch X^t).
    """
    return lam * ops.mk_mmd2(local_feats, global_feats, widths, impl=impl)
