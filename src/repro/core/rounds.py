"""One federated round as a single jit-able step function.

Two execution modes map the round onto the device mesh (DESIGN.md §4):

* ``client_parallel`` — vmap over the round's clients; the client axis of
  the batch is sharded over the mesh's ``data`` (and ``pod``) axes, so each
  data-group trains one client's replica and the final weighted average is
  the only cross-group collective (exactly the communication FedAvg counts).

* ``client_sequential`` — ``lax.scan`` over clients with a running weighted
  parameter sum; a single (FSDP/expert-sharded) model instance lives at a
  time, and the batch *within* a client is sharded over ``data``.

Both return (new_global_state, metrics).  ``global_state`` is
``{'model': params, 'fusion': fusion_params_or_absent}``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aggregate import (normalize_weights, running_update,
                                  weighted_mean, zeros_like_tree)
from repro.core.fusion import fusion_aggregate
from repro.core.local import make_local_trainer
from repro.models.registry import ModelBundle


def make_round_fn(bundle: ModelBundle, fl: FLConfig, mode: str, *,
                  impl="auto"):
    """Returns round_fn(global_state, client_batches, n_examples, lr).

    ``client_batches``: pytree with leading dims [n_clients, local_steps, ...].
    ``n_examples``: [n_clients] float (n_t weighting).
    """
    assert mode in ("client_parallel", "client_sequential"), mode
    trainer = make_local_trainer(bundle, fl, impl=impl)
    is_fusion = fl.algorithm == "fedfusion"

    def _finalize(global_state, stacked_models, stacked_fusions, weights,
                  losses):
        new_model = weighted_mean(stacked_models, weights)
        new_state: Dict[str, Any] = {"model": new_model}
        if is_fusion:
            new_state["fusion"] = fusion_aggregate(
                fl.fusion_op, global_state["fusion"], stacked_fusions,
                weights, fl.ema_beta)
        return new_state, {"local_loss": jnp.mean(losses)}

    if mode == "client_parallel":
        def round_fn(global_state, client_batches, n_examples, lr):
            weights = normalize_weights(n_examples)
            gm = global_state["model"]
            gf = global_state.get("fusion")

            def train_one(batches):
                return trainer(gm, gf, batches, lr)

            trainables, losses = jax.vmap(train_one)(client_batches)
            return _finalize(global_state, trainables["model"],
                             trainables.get("fusion"), weights, losses)

        return round_fn

    def round_fn(global_state, client_batches, n_examples, lr):
        weights = normalize_weights(n_examples)
        gm = global_state["model"]
        gf = global_state.get("fusion")
        acc0 = {"model": zeros_like_tree(gm)}
        if is_fusion:
            acc0["fusion"] = zeros_like_tree(gf)

        def body(acc, xs):
            batches, w = xs
            trainable, loss = trainer(gm, gf, batches, lr)
            acc = dict(acc)
            acc["model"] = running_update(acc["model"], trainable["model"], w)
            if is_fusion:
                # accumulate the weighted client gates; EMA applied after
                acc["fusion"] = running_update(acc["fusion"],
                                               trainable["fusion"], w)
            return acc, loss

        acc, losses = jax.lax.scan(body, acc0, (client_batches, weights))
        new_state: Dict[str, Any] = {"model": acc["model"]}
        if is_fusion:
            if fl.fusion_op == "conv":
                new_state["fusion"] = acc["fusion"]
            else:
                new_state["fusion"] = jax.tree.map(
                    lambda old, new: fl.ema_beta * old + (1 - fl.ema_beta) * new,
                    gf, acc["fusion"])
        return new_state, {"local_loss": jnp.mean(losses)}

    return round_fn


def init_global_state(bundle: ModelBundle, fl: FLConfig, key):
    """Server line 1: initialise the global model (+ fusion module)."""
    from repro.core.fusion import fusion_init
    k1, k2 = jax.random.split(key)
    state: Dict[str, Any] = {"model": bundle.init(k1)}
    if fl.algorithm == "fedfusion":
        state["fusion"] = fusion_init(fl.fusion_op, bundle.feature_channels,
                                      k2)
    return state
