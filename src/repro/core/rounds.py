"""One federated round as a single jit-able step function.

Two execution modes map the round onto the device mesh (DESIGN.md §4):

* ``client_parallel`` — vmap over the round's clients; the client axis of
  the batch is sharded over the mesh's ``data`` (and ``pod``) axes, so each
  data-group trains one client's replica and the final weighted average is
  the only cross-group collective (exactly the communication FedAvg counts).

* ``client_sequential`` — ``lax.scan`` over clients with a running weighted
  parameter sum; a single (FSDP/expert-sharded) model instance lives at a
  time, and the batch *within* a client is sharded over ``data``.

Both return (new_global_state, metrics).  ``global_state`` is
``{'model': params, 'fusion': fusion_params_or_absent}``.

Engine contract (``repro.engine``): the superstep ``lax.scan``s these
round fns over a chunk of pre-staged rounds, so they must stay *pure*
functions of their arguments with a stable output structure — state and
metrics shapes cannot depend on data, and everything that varies per
round (batches, sizes, lr, sampled cids, the fold_in round key) arrives
as an argument, never from Python-level state.  For the compressed fn the
returned broadcast (4th output) IS the clients' next downlink mirror; the
engine threads it and the per-client EF rows through the scan carry and
scatters the EF rows back into the device-resident full-federation table
(``ops.ef_scatter``).

Sharding contract (``repro.engine.sharded``): with ``shard`` — a
:class:`repro.core.aggregate.ClientSharding` — the round fn is a
``shard_map`` BODY: its client axis holds only this shard's slice of the
round's clients (positional split: shard s trains sampled positions
``[s*C_loc, (s+1)*C_loc)``), every per-client quantity (local training,
codec encode/decode, EF rows) stays shard-local, and the only collectives
are the in-shard-reduce + single ``psum`` aggregations in
``repro.core.aggregate`` / ``fusion_aggregate``.  Replicated inputs
(global model, mirror, round key, lr) produce bitwise-identical replicated
outputs on every shard because the psum results agree everywhere.  With
``shard=None`` the code path is exactly the pre-sharding one — no
collectives — which is what keeps the single-device engine
bitwise-equal to the reference loop.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aggregate import (ClientSharding, mean_over_clients,
                                  normalize_weights, psum_tree,
                                  running_update, weighted_mean,
                                  zeros_like_tree)
from repro.core.fusion import fusion_aggregate
from repro.core.local import make_local_trainer
from repro.models.registry import ModelBundle


def _local_client_keys(key, n_local: int, shard: Optional[ClientSharding]):
    """Per-client rng keys for THIS shard's clients.

    The reference loop splits the round key over the full C sampled
    clients in positional order; a shard must use the identical keys for
    its positional slice, so the full split is computed (replicated — it
    is a few dozen uint32s) and dynamically sliced at the shard offset.
    """
    if shard is None:
        return jax.random.split(key, n_local)
    full = jax.random.split(key, n_local * shard.n_shards)
    start = (shard.position() * n_local).astype(jnp.int32)
    return jax.lax.dynamic_slice_in_dim(full, start, n_local, axis=0)


def make_round_fn(bundle: ModelBundle, fl: FLConfig, mode: str, *,
                  impl="auto", shard: Optional[ClientSharding] = None):
    """Returns round_fn(global_state, client_batches, n_examples, lr).

    ``client_batches``: pytree with leading dims [n_clients, local_steps, ...].
    ``n_examples``: [n_clients] float (n_t weighting).
    Under ``shard`` both carry only this shard's clients.
    """
    assert mode in ("client_parallel", "client_sequential"), mode
    trainer = make_local_trainer(bundle, fl, impl=impl)
    is_fusion = fl.algorithm == "fedfusion"

    def _finalize(global_state, stacked_models, stacked_fusions, weights,
                  losses):
        new_model = weighted_mean(stacked_models, weights, shard)
        new_state: Dict[str, Any] = {"model": new_model}
        if is_fusion:
            new_state["fusion"] = fusion_aggregate(
                fl.fusion_op, global_state["fusion"], stacked_fusions,
                weights, fl.ema_beta, shard=shard)
        return new_state, {"local_loss": mean_over_clients(losses, shard)}

    if mode == "client_parallel":
        def round_fn(global_state, client_batches, n_examples, lr):
            weights = normalize_weights(n_examples, shard)
            gm = global_state["model"]
            gf = global_state.get("fusion")

            def train_one(batches):
                return trainer(gm, gf, batches, lr)

            trainables, losses = jax.vmap(train_one)(client_batches)
            return _finalize(global_state, trainables["model"],
                             trainables.get("fusion"), weights, losses)

        return round_fn

    def round_fn(global_state, client_batches, n_examples, lr):
        weights = normalize_weights(n_examples, shard)
        gm = global_state["model"]
        gf = global_state.get("fusion")
        acc0 = {"model": zeros_like_tree(gm)}
        if is_fusion:
            acc0["fusion"] = zeros_like_tree(gf)

        def body(acc, xs):
            batches, w = xs
            trainable, loss = trainer(gm, gf, batches, lr)
            acc = dict(acc)
            acc["model"] = running_update(acc["model"], trainable["model"], w)
            if is_fusion:
                # accumulate the weighted client gates; EMA applied after
                acc["fusion"] = running_update(acc["fusion"],
                                               trainable["fusion"], w)
            return acc, loss

        acc, losses = jax.lax.scan(body, acc0, (client_batches, weights))
        # the running sums covered this shard's clients; one psum per tree
        # completes them over the round (no-op when unsharded)
        acc = psum_tree(acc, shard)
        new_state: Dict[str, Any] = {"model": acc["model"]}
        if is_fusion:
            if fl.fusion_op == "conv":
                new_state["fusion"] = acc["fusion"]
            else:
                new_state["fusion"] = jax.tree.map(
                    lambda old, new: fl.ema_beta * old + (1 - fl.ema_beta) * new,
                    gf, acc["fusion"])
        return new_state, {"local_loss": mean_over_clients(losses, shard)}

    return round_fn


def make_compressed_round_fn(bundle: ModelBundle, fl: FLConfig, mode: str,
                             uplink, downlink, *, impl="auto",
                             shard: Optional[ClientSharding] = None):
    """A federated round with the wire path routed through codecs.

    Returns round_fn(global_state, client_batches, n_examples, lr,
    ef_state, down_mirror, key) -> (new_global_state, metrics,
    new_ef_state, new_down_mirror):

      1. downlink: the server broadcasts the *model update* against a
         mirror of what clients already hold — it transmits
         ``downlink.encode(model - mirror)`` and every client forms
         ``bcast = mirror + decode(payload)``, which becomes the next
         mirror.  Compressing the update (not the raw weights) is what
         makes sparse downlink codecs sound: a top-k broadcast of the
         weights themselves would hand clients a mostly-zero network,
         while the mirrored update stream converges to the model
         (EF21-style server compression).  The mirror gap itself carries
         every previously-dropped unit of mass, so the compressor is
         applied STATELESSLY here — adding an error-feedback residual on
         top would count dropped mass twice and the stream provably
         diverges (g_{r+1} = 2e_r - e_{r-1} on unselected coordinates).
      2. each client trains locally, forms its delta vs the broadcast, and
         uplinks ``uplink.encode(delta, ef)`` (error-feedback state is
         per-client, threaded via ``ef_state`` with leading client axis).
      3. the server decodes every payload and applies the aggregate to its
         FULL-PRECISION model: ``model + Σ w_i · decode(payload_i)`` —
         downlink codec error therefore never accumulates into the server
         state (clients see it through the mirror only).  Identical to
         FedAvg's weighted model average when both codecs are identity.

    Fusion-module parameters (FedFusion) ride along uncompressed, exactly
    as before — their raw bytes stay accounted in ``CommLog``.

    Under ``shard`` (see module docstring) ``ef_state`` carries the EF
    rows of THIS shard's positional clients only; steps 1 and the
    server-side model update run replicated (their inputs are replicated
    and the aggregate arrives via psum, so every shard applies the exact
    same update), and the per-client rng keys are the positional slice of
    the reference loop's full split.
    """
    assert mode in ("client_parallel", "client_sequential"), mode
    trainer = make_local_trainer(bundle, fl, impl=impl)
    is_fusion = fl.algorithm == "fedfusion"

    def round_fn(global_state, client_batches, n_examples, lr, ef_state,
                 down_mirror, key):
        weights = normalize_weights(n_examples, shard)
        n_clients = weights.shape[0]
        kd, ku = jax.random.split(key)
        down_update = jax.tree.map(lambda m, w: m - w,
                                   global_state["model"], down_mirror)
        down_payload, _ = downlink.encode(
            down_update, downlink.init_state(),   # stateless: see above
            kd if downlink.uses_key else None)
        bcast = jax.tree.map(lambda w, d: w + d.astype(w.dtype),
                             down_mirror, downlink.decode(down_payload))
        gf = global_state.get("fusion")
        client_keys = _local_client_keys(ku, n_clients, shard)

        def client_step(batches, ef, ck):
            trainable, loss = trainer(bcast, gf, batches, lr)
            delta = jax.tree.map(lambda a, b: a - b, trainable["model"],
                                 bcast)
            payload, new_ef = uplink.encode(
                delta, ef, ck if uplink.uses_key else None)
            decoded = uplink.decode(payload)
            out = {"delta": decoded, "ef": new_ef, "loss": loss}
            if is_fusion:
                out["fusion"] = trainable["fusion"]
            return out

        if mode == "client_parallel":
            outs = jax.vmap(client_step)(client_batches, ef_state,
                                         client_keys)
            agg_delta = weighted_mean(outs["delta"], weights, shard)
            new_ef = outs["ef"]
            stacked_fusions = outs.get("fusion")
            losses = outs["loss"]
        else:
            acc0 = zeros_like_tree(global_state["model"])
            if is_fusion:
                acc0 = (acc0, zeros_like_tree(gf))

            def body(acc, xs):
                batches, w, ef, ck = xs
                out = client_step(batches, ef, ck)
                if is_fusion:
                    acc = (running_update(acc[0], out["delta"], w),
                           running_update(acc[1], out["fusion"], w))
                else:
                    acc = running_update(acc, out["delta"], w)
                return acc, (out["ef"], out["loss"])

            acc, (new_ef, losses) = jax.lax.scan(
                body, acc0, (client_batches, weights, ef_state, client_keys))
            acc = psum_tree(acc, shard)
            if is_fusion:
                agg_delta, fusion_sum = acc
                stacked_fusions = None
            else:
                agg_delta = acc

        # apply the aggregate update to the FULL-PRECISION server model;
        # the aggregate of the client models themselves is bcast+Σw·Δ, but
        # folding the broadcast's codec error back into the server state
        # would compound it round over round.
        new_model = jax.tree.map(lambda g, d: g + d.astype(g.dtype),
                                 global_state["model"], agg_delta)
        new_state: Dict[str, Any] = {"model": new_model}
        if is_fusion:
            if mode == "client_parallel":
                new_state["fusion"] = fusion_aggregate(
                    fl.fusion_op, global_state["fusion"], stacked_fusions,
                    weights, fl.ema_beta, shard=shard)
            elif fl.fusion_op == "conv":
                new_state["fusion"] = fusion_sum
            else:
                new_state["fusion"] = jax.tree.map(
                    lambda old, new: fl.ema_beta * old
                    + (1 - fl.ema_beta) * new, gf, fusion_sum)
        return (new_state, {"local_loss": mean_over_clients(losses, shard)},
                new_ef, bcast)

    return round_fn


def init_global_state(bundle: ModelBundle, fl: FLConfig, key):
    """Server line 1: initialise the global model (+ fusion module)."""
    from repro.core.fusion import fusion_init
    k1, k2 = jax.random.split(key)
    state: Dict[str, Any] = {"model": bundle.init(k1)}
    if fl.algorithm == "fedfusion":
        state["fusion"] = fusion_init(fl.fusion_op, bundle.feature_channels,
                                      k2)
    return state
