"""One federated round as a single jit-able step function.

Two execution modes map the round onto the device mesh (DESIGN.md §4):

* ``client_parallel`` — vmap over the round's clients; the client axis of
  the batch is sharded over the mesh's ``data`` (and ``pod``) axes, so each
  data-group trains one client's replica and the final weighted average is
  the only cross-group collective (exactly the communication FedAvg counts).

* ``client_sequential`` — ``lax.scan`` over clients with a running weighted
  parameter sum; a single (FSDP/expert-sharded) model instance lives at a
  time, and the batch *within* a client is sharded over ``data``.

Both return (new_global_state, metrics).  ``global_state`` is
``{'model': params, **extras}`` where ``extras`` are the algorithm
plugin's ``Algorithm.extra_state`` entries (FedFusion's fusion params;
empty for single-stream algorithms).  The round fns thread and
accumulate those extras generically — what they *mean* lives in the
plugin's ``aggregate_extras`` / ``finalize_extra_sums`` hooks, so a new
mechanism registers with ``repro.fl.api`` and rides through here without
edits.

Engine contract (``repro.engine``): the superstep ``lax.scan``s these
round fns over a chunk of pre-staged rounds, so they must stay *pure*
functions of their arguments with a stable output structure — state and
metrics shapes cannot depend on data, and everything that varies per
round (batches, sizes, lr, sampled cids, the fold_in round key) arrives
as an argument, never from Python-level state.  For the compressed fn the
returned broadcast (4th output) IS the clients' next downlink mirror; the
engine threads it and the per-client EF rows through the scan carry and
scatters the EF rows back into the device-resident full-federation table
(``ops.ef_scatter``).

Sharding contract (``repro.engine.sharded``): with ``shard`` — a
:class:`repro.core.aggregate.ClientSharding` — the round fn is a
``shard_map`` BODY: its client axis holds only this shard's slice of the
round's clients (positional split: shard s trains sampled positions
``[s*C_loc, (s+1)*C_loc)``), every per-client quantity (local training,
codec encode/decode, EF rows) stays shard-local, and the only collectives
are the in-shard-reduce + single ``psum`` aggregations in
``repro.core.aggregate`` / the plugin's ``aggregate_extras``.  Replicated
inputs (global model, mirror, round key, lr) produce bitwise-identical
replicated outputs on every shard because the psum results agree
everywhere.  With ``shard=None`` the code path is exactly the
pre-sharding one — no collectives — which is what keeps the
single-device engine bitwise-equal to the reference loop.

Fused-collective contract (``repro.engine.superstep`` with
``fused_collective=True``): the ``*_round_parts`` factories split a round
into a *local* function — everything up to and including this shard's
weighted contribution sums, no collectives — and a *finish* function that
consumes the psum-completed sums.  The superstep packs the local sums
into ONE flat buffer together with the EF exchange and the next round's
weight total and runs a single ``psum``
(:func:`repro.core.aggregate.fused_psum`).  The split keeps every
arithmetic op of the unfused path (weights are normalized against a
total psummed one round ahead — the sizes are pre-staged inputs, so the
value is identical; extras close through ``finalize_extra_sums``, whose
ops equal the in-tree plugins' ``aggregate_extras`` after the weighted
sum), which is what makes fused and unfused rounds bitwise-equal.

Participation contract (``repro.fl.participation``): with
``participation=True`` every factory's round fn takes two extra
``[n_clients]`` float32 inputs — ``pmask`` (0/1 contribution mask) and
``pstale`` (staleness, telemetry only).  Masked clients are zeroed
purely *by weight*: the engine pre-multiplies the staged sizes by
``mask * staleness_weight`` on the host, so the existing normalized
weighted mean — including the fused path's pipelined total — silently
excludes them with no shape changes and no extra collectives.  The
round-level additions are (a) the per-client EF update is guarded so a
masked client's residual is carried forward untouched (its payload
never reached the server, so its dropped mass must stay local), and
(b) the round loss becomes the mask-weighted mean, its numerator /
denominator riding the round's existing collective and dividing in the
post-psum finish step.  With ``participation=False`` (the default)
every traced code path is byte-identical to before this axis existed.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig
from repro.core.aggregate import (ClientSharding, finish_masked_loss,
                                  masked_loss_sums, mean_over_clients,
                                  normalize_weights, psum_tree,
                                  running_update, zeros_like_tree)
from repro.core.local import _algorithm, make_local_trainer
from repro.models.registry import ModelBundle
# repro.obs sits at the bottom of the import graph (jax only) — no cycle
from repro.obs.telemetry import ClientTapCtx


def _local_client_keys(key, n_local: int, shard: Optional[ClientSharding]):
    """Per-client rng keys for THIS shard's clients.

    The reference loop splits the round key over the full C sampled
    clients in positional order; a shard must use the identical keys for
    its positional slice, so the full split is computed (replicated — it
    is a few dozen uint32s) and dynamically sliced at the shard offset.
    """
    if shard is None:
        return jax.random.split(key, n_local)
    full = jax.random.split(key, n_local * shard.n_shards)
    start = (shard.position() * n_local).astype(jnp.int32)
    return jax.lax.dynamic_slice_in_dim(full, start, n_local, axis=0)


_RESERVED_CONTRIB_KEYS = frozenset(("model", "delta", "loss", "lsum", "lw",
                                    "tele"))


def _sum_clients(tele):
    """[C]-stacked per-client tap sums -> this shard's scalar sums (the
    psum-pending half of the round's telemetry; {} passes through)."""
    return {k: jnp.sum(v, axis=0) for k, v in tele.items()}


def _check_extra_keys(extra_keys):
    """The fused-collective contribution dicts key the model/delta sums
    and the chunk loss alongside the plugin's extras — an extra named
    after one of those would be silently clobbered, so fail at build
    time instead."""
    clash = _RESERVED_CONTRIB_KEYS.intersection(extra_keys)
    if clash:
        raise ValueError(
            f"Algorithm.extra_state keys {sorted(clash)} collide with the "
            f"round accumulators' reserved keys {sorted(_RESERVED_CONTRIB_KEYS)}"
            f" — rename the extra state entries")


def _weighted_sums(stacked, weights):
    """tensordot(weights, leading-client-axis tree) — the in-shard half of
    :func:`repro.core.aggregate.weighted_mean` (psum completes it)."""
    return jax.tree.map(
        lambda x: jnp.tensordot(weights.astype(x.dtype), x, axes=1), stacked)


def _make_plain_clients(bundle: ModelBundle, fl: FLConfig, mode: str, *,
                        impl="auto", telemetry=None):
    """Shared client-side computation of one uncompressed round.

    Returns ``run_clients(global_state, client_batches, weights, lr,
    n_examples) -> (wsums, stacked_extras, losses, tele)``: ``wsums``
    holds this shard's weighted sums ``{"model": tree, **extras}``
    (psum-pending), ``stacked_extras`` the per-client extras
    (client_parallel only; the sequential scan only materializes the
    running sums), and ``tele`` this shard's telemetry tap sums
    (psum-pending scalars; ``{}`` with ``telemetry=None`` — the code path
    is then byte-identical to the untapped one).

    ``pmask``/``pstale`` (participation mask + staleness, ``None`` when
    the participation axis is off) feed the telemetry tap contexts only:
    plain-round masking itself is entirely weight-borne (the engine
    zeroes masked clients' example weights on the host), so with
    ``telemetry=None`` the traced computation never sees them.
    """
    if mode not in ("client_parallel", "client_sequential"):
        raise ValueError(f"unknown fl mode {mode!r}")
    algo = _algorithm(fl)
    trainer = make_local_trainer(bundle, fl, impl=impl)
    extra_keys = algo.extra_state

    def run_clients(global_state, client_batches, weights, lr,
                    n_examples=None, pmask=None, pstale=None):
        gm = global_state["model"]
        gx = algo.extra_from_state(global_state)

        if mode == "client_parallel":
            if telemetry is None:
                def train_one(batches):
                    return trainer(gm, gx, batches, lr)

                trainables, losses = jax.vmap(train_one)(client_batches)
                tele = {}
            elif pmask is None:
                def train_one(batches, nex):
                    trainable, loss = trainer(gm, gx, batches, lr)
                    t = telemetry.client_sums(ClientTapCtx(
                        n_examples=nex, loss=loss,
                        model=trainable["model"], global_model=gm))
                    return trainable, loss, t

                trainables, losses, tele_c = jax.vmap(train_one)(
                    client_batches, n_examples)
                tele = _sum_clients(tele_c)
            else:
                def train_one(batches, nex, m, st):
                    trainable, loss = trainer(gm, gx, batches, lr)
                    t = telemetry.client_sums(ClientTapCtx(
                        n_examples=nex, loss=loss,
                        model=trainable["model"], global_model=gm,
                        pmask=m, staleness=st))
                    return trainable, loss, t

                trainables, losses, tele_c = jax.vmap(train_one)(
                    client_batches, n_examples, pmask, pstale)
                tele = _sum_clients(tele_c)
            wsums = {"model": _weighted_sums(trainables["model"], weights)}
            for k in extra_keys:
                wsums[k] = _weighted_sums(trainables[k], weights)
            return (wsums, {k: trainables[k] for k in extra_keys}, losses,
                    tele)

        acc0 = {"model": zeros_like_tree(gm)}
        for k in extra_keys:
            acc0[k] = zeros_like_tree(global_state[k])

        if telemetry is None:
            def body(acc, xs):
                batches, w = xs
                trainable, loss = trainer(gm, gx, batches, lr)
                # accumulate the weighted client params (and extras — e.g.
                # fusion gates; the plugin's EMA etc. applies after the sum)
                acc = {k: running_update(acc[k], trainable[k], w)
                       for k in acc}
                return acc, loss

            acc, losses = jax.lax.scan(body, acc0, (client_batches, weights))
            return acc, None, losses, {}

        if pmask is None:
            def body(acc, xs):
                batches, w, nex = xs
                trainable, loss = trainer(gm, gx, batches, lr)
                acc = {k: running_update(acc[k], trainable[k], w)
                       for k in acc}
                t = telemetry.client_sums(ClientTapCtx(
                    n_examples=nex, loss=loss, model=trainable["model"],
                    global_model=gm))
                return acc, (loss, t)

            acc, (losses, tele_c) = jax.lax.scan(
                body, acc0, (client_batches, weights, n_examples))
            return acc, None, losses, _sum_clients(tele_c)

        def body(acc, xs):
            batches, w, nex, m, st = xs
            trainable, loss = trainer(gm, gx, batches, lr)
            acc = {k: running_update(acc[k], trainable[k], w) for k in acc}
            t = telemetry.client_sums(ClientTapCtx(
                n_examples=nex, loss=loss, model=trainable["model"],
                global_model=gm, pmask=m, staleness=st))
            return acc, (loss, t)

        acc, (losses, tele_c) = jax.lax.scan(
            body, acc0, (client_batches, weights, n_examples, pmask, pstale))
        return acc, None, losses, _sum_clients(tele_c)

    return run_clients


def make_round_fn(bundle: ModelBundle, fl: FLConfig, mode: str, *,
                  impl="auto", shard: Optional[ClientSharding] = None,
                  telemetry=None, participation=False):
    """Returns round_fn(global_state, client_batches, n_examples, lr).

    ``client_batches``: pytree with leading dims [n_clients, local_steps, ...].
    ``n_examples``: [n_clients] float (n_t weighting).
    Under ``shard`` both carry only this shard's clients.

    ``telemetry`` (a :class:`repro.obs.telemetry.Telemetry`) adds
    ``tele/...`` entries to the round metrics; the tap sums ride the
    aggregation psum the round already performs (``psum`` of a tree is one
    collective regardless of leaf count, and elementwise reduction keeps
    the pre-existing leaves' bits), so the round stays one-psum and
    bitwise-equal to the untapped build.

    ``participation=True`` appends ``pmask``/``pstale`` [n_clients]
    inputs (see module docstring): ``n_examples`` then arrives already
    mask-and-staleness-weighted from the host, and the round loss is the
    mask-weighted mean whose sums ride the same psum.
    """
    algo = _algorithm(fl)
    extra_keys = algo.extra_state
    run_clients = _make_plain_clients(bundle, fl, mode, impl=impl,
                                      telemetry=telemetry)

    def _finish(global_state, summed, stacked_extras, weights):
        if mode == "client_parallel":
            new_state: Dict[str, Any] = {"model": summed["model"]}
            new_state.update(algo.aggregate_extras(fl, global_state,
                                                   stacked_extras, weights,
                                                   shard=shard))
        else:
            new_state = {"model": summed["model"]}
            new_state.update(algo.finalize_extra_sums(
                fl, global_state, {k: summed[k] for k in extra_keys}))
        return new_state

    if participation:
        def round_fn(global_state, client_batches, n_examples, lr,
                     pmask, pstale):
            weights = normalize_weights(n_examples, shard)
            wsums, stacked_extras, losses, tele = run_clients(
                global_state, client_batches, weights, lr, n_examples,
                pmask, pstale)
            lsums = masked_loss_sums(losses, pmask)
            if mode == "client_parallel":
                summed = psum_tree(
                    {"model": wsums["model"], "tele": tele, **lsums}, shard)
            else:
                summed = psum_tree({**wsums, "tele": tele, **lsums}, shard)
            new_state = _finish(global_state, summed, stacked_extras,
                                weights)
            metrics = {"local_loss": finish_masked_loss(summed)}
            if telemetry is not None:
                metrics.update(telemetry.finish(summed["tele"]))
            return new_state, metrics

        return round_fn

    def round_fn(global_state, client_batches, n_examples, lr):
        weights = normalize_weights(n_examples, shard)
        wsums, stacked_extras, losses, tele = run_clients(
            global_state, client_batches, weights, lr, n_examples)
        if mode == "client_parallel":
            # tele rides the model-sum psum: same single collective
            summed = psum_tree({"model": wsums["model"], "tele": tele},
                               shard)
        else:
            # the running sums covered this shard's clients; one psum per
            # tree completes them over the round (no-op when unsharded)
            summed = psum_tree({**wsums, "tele": tele}, shard)
        new_state = _finish(global_state, summed, stacked_extras, weights)
        metrics = {"local_loss": mean_over_clients(losses, shard)}
        if telemetry is not None:
            metrics.update(telemetry.finish(summed["tele"]))
        return new_state, metrics

    return round_fn


def make_round_parts(bundle: ModelBundle, fl: FLConfig, mode: str, *,
                     impl="auto", shard: ClientSharding, telemetry=None,
                     participation=False):
    """Deferred-psum split of :func:`make_round_fn` (fused collectives).

    Returns ``(local_fn, finish_fn)``:

    ``local_fn(global_state, client_batches, total, n_examples, lr) ->
    contribs`` — this shard's psum-pending contributions ``{"model": tree,
    **extras, "loss": scalar}``.  ``total`` is the round's psum-completed
    example count (the superstep pipelines it one collective ahead, since
    sizes are pre-staged inputs); dividing by it reproduces
    ``normalize_weights`` bit for bit.

    ``finish_fn(global_state, summed) -> (new_state, metrics)`` consumes
    the psum-completed contributions.  Extras close through the plugin's
    ``finalize_extra_sums`` — for weighted-sum-then-postprocess
    aggregations (every in-tree plugin) that is op-for-op the tail of
    ``aggregate_extras``, keeping fused == unfused bitwise.

    ``telemetry`` taps contribute a ``"tele"`` sub-dict to ``contribs`` —
    a few extra f32 scalars riding the superstep's single fused psum —
    and their finalized ``tele/...`` metrics to ``finish_fn``'s output.

    ``participation=True``: ``local_fn`` takes trailing ``pmask``/
    ``pstale`` inputs, the mask-weighted loss sums replace the plain
    chunk-loss scalar in ``contribs`` (two f32 lanes on the same fused
    psum), and ``finish_fn`` divides them post-psum.
    """
    algo = _algorithm(fl)
    extra_keys = algo.extra_state
    _check_extra_keys(extra_keys)
    run_clients = _make_plain_clients(bundle, fl, mode, impl=impl,
                                      telemetry=telemetry)

    if participation:
        def local_fn(global_state, client_batches, total, n_examples, lr,
                     pmask, pstale):
            weights = jnp.asarray(n_examples, jnp.float32) / total
            wsums, _, losses, tele = run_clients(
                global_state, client_batches, weights, lr, n_examples,
                pmask, pstale)
            return {**wsums, **masked_loss_sums(losses, pmask),
                    "tele": tele}
    else:
        def local_fn(global_state, client_batches, total, n_examples, lr):
            weights = jnp.asarray(n_examples, jnp.float32) / total
            wsums, _, losses, tele = run_clients(
                global_state, client_batches, weights, lr, n_examples)
            return {**wsums, "loss": jnp.mean(losses), "tele": tele}

    def finish_fn(global_state, summed):
        new_state: Dict[str, Any] = {"model": summed["model"]}
        new_state.update(algo.finalize_extra_sums(
            fl, global_state, {k: summed[k] for k in extra_keys}))
        if participation:
            metrics = {"local_loss": finish_masked_loss(summed)}
        else:
            metrics = {"local_loss": summed["loss"] / shard.n_shards}
        if telemetry is not None:
            metrics.update(telemetry.finish(summed["tele"]))
        return new_state, metrics

    return local_fn, finish_fn


def make_compressed_round_fn(bundle: ModelBundle, fl: FLConfig, mode: str,
                             uplink, downlink, *, impl="auto",
                             shard: Optional[ClientSharding] = None,
                             telemetry=None, participation=False,
                             controller=None):
    """A federated round with the wire path routed through codecs.

    Returns round_fn(global_state, client_batches, n_examples, lr,
    ef_state, down_mirror, key) -> (new_global_state, metrics,
    new_ef_state, new_down_mirror):

      1. downlink: the server broadcasts the *model update* against a
         mirror of what clients already hold — it transmits
         ``downlink.encode(model - mirror)`` and every client forms
         ``bcast = mirror + decode(payload)``, which becomes the next
         mirror.  Compressing the update (not the raw weights) is what
         makes sparse downlink codecs sound: a top-k broadcast of the
         weights themselves would hand clients a mostly-zero network,
         while the mirrored update stream converges to the model
         (EF21-style server compression).  The mirror gap itself carries
         every previously-dropped unit of mass, so the compressor is
         applied STATELESSLY here — adding an error-feedback residual on
         top would count dropped mass twice and the stream provably
         diverges (g_{r+1} = 2e_r - e_{r-1} on unselected coordinates).
      2. each client trains locally, forms its delta vs the broadcast, and
         uplinks ``uplink.encode(delta, ef)`` (error-feedback state is
         per-client, threaded via ``ef_state`` with leading client axis).
      3. the server decodes every payload and applies the aggregate to its
         FULL-PRECISION model: ``model + Σ w_i · decode(payload_i)`` —
         downlink codec error therefore never accumulates into the server
         state (clients see it through the mirror only).  Identical to
         FedAvg's weighted model average when both codecs are identity.

    The algorithm's extra state (FedFusion's fusion module) rides along
    uncompressed, exactly as before — its raw bytes stay accounted in
    ``CommLog``.

    Under ``shard`` (see module docstring) ``ef_state`` carries the EF
    rows of THIS shard's positional clients only; steps 1 and the
    server-side model update run replicated (their inputs are replicated
    and the aggregate arrives via psum, so every shard applies the exact
    same update), and the per-client rng keys are the positional slice of
    the reference loop's full split.

    Controller contract (``repro.control``): with ``controller`` set the
    round fn takes a trailing ``ctrl_state`` dict (scalar leaves riding
    the superstep scan carry) and returns ``new_ctrl`` as a 5th output.
    The incoming ``ctrl_state["level"]`` selects the rung every client of
    THIS round encodes at; ``controller.update`` then runs replicated on
    the psum-completed round metrics (traced scalars, identical on every
    shard) to pick the next round's level — zero host round-trips, zero
    extra collectives.  With ``controller=None`` every traced code path
    is byte-identical to before this axis existed.
    """
    if controller is not None and telemetry is None:
        raise ValueError("a controller needs telemetry for its decision "
                         "signals (the engine forces the required taps on)")
    algo = _algorithm(fl)
    extra_keys = algo.extra_state
    run_clients = _make_compressed_clients(bundle, fl, mode, uplink,
                                           downlink, impl=impl, shard=shard,
                                           telemetry=telemetry,
                                           controller=controller)

    def _finish(global_state, summed, stacked_extras, weights):
        # apply the aggregate update to the FULL-PRECISION server model;
        # the aggregate of the client models themselves is bcast+Σw·Δ, but
        # folding the broadcast's codec error back into the server state
        # would compound it round over round.
        new_model = jax.tree.map(lambda g, d: g + d.astype(g.dtype),
                                 global_state["model"], summed["delta"])
        new_state: Dict[str, Any] = {"model": new_model}
        if mode == "client_parallel":
            new_state.update(algo.aggregate_extras(
                fl, global_state, stacked_extras, weights, shard=shard))
        else:
            new_state.update(algo.finalize_extra_sums(
                fl, global_state, {k: summed[k] for k in extra_keys}))
        return new_state

    if controller is not None:
        if participation:
            def round_fn(global_state, client_batches, n_examples, lr,
                         ef_state, down_mirror, key, pmask, pstale,
                         ctrl_state):
                weights = normalize_weights(n_examples, shard)
                wsums, stacked_extras, new_ef, losses, bcast, tele = \
                    run_clients(global_state, client_batches, weights, lr,
                                ef_state, down_mirror, key, n_examples,
                                pmask, pstale, level=ctrl_state["level"])
                lsums = masked_loss_sums(losses, pmask)
                if mode == "client_parallel":
                    summed = psum_tree(
                        {"delta": wsums["delta"], "tele": tele, **lsums},
                        shard)
                else:
                    summed = psum_tree({**wsums, "tele": tele, **lsums},
                                       shard)
                new_state = _finish(global_state, summed, stacked_extras,
                                    weights)
                metrics = {"local_loss": finish_masked_loss(summed)}
                metrics.update(telemetry.finish(summed["tele"]))
                new_ctrl = controller.update(ctrl_state, metrics)
                return new_state, metrics, new_ef, bcast, new_ctrl
        else:
            def round_fn(global_state, client_batches, n_examples, lr,
                         ef_state, down_mirror, key, ctrl_state):
                weights = normalize_weights(n_examples, shard)
                wsums, stacked_extras, new_ef, losses, bcast, tele = \
                    run_clients(global_state, client_batches, weights, lr,
                                ef_state, down_mirror, key, n_examples,
                                level=ctrl_state["level"])
                if mode == "client_parallel":
                    summed = psum_tree(
                        {"delta": wsums["delta"], "tele": tele}, shard)
                else:
                    summed = psum_tree({**wsums, "tele": tele}, shard)
                new_state = _finish(global_state, summed, stacked_extras,
                                    weights)
                metrics = {"local_loss": mean_over_clients(losses, shard)}
                metrics.update(telemetry.finish(summed["tele"]))
                new_ctrl = controller.update(ctrl_state, metrics)
                return new_state, metrics, new_ef, bcast, new_ctrl

        return round_fn

    if participation:
        def round_fn(global_state, client_batches, n_examples, lr,
                     ef_state, down_mirror, key, pmask, pstale):
            weights = normalize_weights(n_examples, shard)
            wsums, stacked_extras, new_ef, losses, bcast, tele = \
                run_clients(global_state, client_batches, weights, lr,
                            ef_state, down_mirror, key, n_examples, pmask,
                            pstale)
            lsums = masked_loss_sums(losses, pmask)
            if mode == "client_parallel":
                summed = psum_tree(
                    {"delta": wsums["delta"], "tele": tele, **lsums}, shard)
            else:
                summed = psum_tree({**wsums, "tele": tele, **lsums}, shard)
            new_state = _finish(global_state, summed, stacked_extras,
                                weights)
            metrics = {"local_loss": finish_masked_loss(summed)}
            if telemetry is not None:
                metrics.update(telemetry.finish(summed["tele"]))
            return new_state, metrics, new_ef, bcast

        return round_fn

    def round_fn(global_state, client_batches, n_examples, lr, ef_state,
                 down_mirror, key):
        weights = normalize_weights(n_examples, shard)
        wsums, stacked_extras, new_ef, losses, bcast, tele = run_clients(
            global_state, client_batches, weights, lr, ef_state,
            down_mirror, key, n_examples)
        if mode == "client_parallel":
            # tele rides the delta-sum psum: same single collective
            summed = psum_tree({"delta": wsums["delta"], "tele": tele},
                               shard)
        else:
            summed = psum_tree({**wsums, "tele": tele}, shard)
        new_state = _finish(global_state, summed, stacked_extras, weights)
        metrics = {"local_loss": mean_over_clients(losses, shard)}
        if telemetry is not None:
            metrics.update(telemetry.finish(summed["tele"]))
        return new_state, metrics, new_ef, bcast

    return round_fn


def _make_compressed_clients(bundle: ModelBundle, fl: FLConfig, mode: str,
                             uplink, downlink, *, impl="auto",
                             shard: Optional[ClientSharding] = None,
                             telemetry=None, controller=None):
    """Shared client-side computation of one codec-routed round.

    Returns ``run_clients(global_state, client_batches, weights, lr,
    ef_state, down_mirror, key, n_examples) -> (wsums, stacked_extras,
    new_ef, losses, bcast, tele)``: ``wsums`` holds this shard's
    psum-pending weighted sums ``{"delta": tree, **extras}``,
    ``stacked_extras`` the per-client extras (client_parallel only),
    ``new_ef`` the positional clients' fresh EF rows, ``bcast`` the
    mirror-based downlink result (the clients' next mirror) and ``tele``
    this shard's telemetry tap sums (``{}`` when ``telemetry=None`` — the
    code path is then byte-identical to the untapped one).

    ``pmask``/``pstale`` (participation; ``None`` when the axis is off):
    a masked client's encoded payload never reaches the server (its
    weight is zero), so its EF update is rolled back — ``new_ef`` keeps
    the client's *incoming* residual bit for bit, exactly what the
    reference semantics of "this client never uplinked" require.  Both
    arrays also feed the telemetry tap contexts.

    ``level`` (a traced int32 scalar, ``None`` when no controller is on)
    selects the uplink codec's effective ladder rung for EVERY client of
    this round — it is a closure capture, not a vmapped operand, so all
    clients encode at the same level and the codec's capacity-shaped
    payload keeps the wire shapes static.  With ``level=None`` the encode
    traces exactly the pre-ladder program.
    """
    if mode not in ("client_parallel", "client_sequential"):
        raise ValueError(f"unknown fl mode {mode!r}")
    algo = _algorithm(fl)
    trainer = make_local_trainer(bundle, fl, impl=impl)
    extra_keys = algo.extra_state

    def run_clients(global_state, client_batches, weights, lr, ef_state,
                    down_mirror, key, n_examples=None, pmask=None,
                    pstale=None, level=None):
        n_clients = weights.shape[0]
        kd, ku = jax.random.split(key)
        down_update = jax.tree.map(lambda m, w: m - w,
                                   global_state["model"], down_mirror)
        down_payload, _ = downlink.encode(
            down_update, downlink.init_state(),   # stateless: see above
            kd if downlink.uses_key else None)
        bcast = jax.tree.map(lambda w, d: w + d.astype(w.dtype),
                             down_mirror, downlink.decode(down_payload))
        gx = algo.extra_from_state(global_state)
        client_keys = _local_client_keys(ku, n_clients, shard)
        eff_bytes = (None if level is None or controller is None
                     else jnp.take(controller.spec.bytes_table(), level))

        def client_step(batches, ef, ck, nex=None, m=None, st=None):
            trainable, loss = trainer(bcast, gx, batches, lr)
            delta = jax.tree.map(lambda a, b: a - b, trainable["model"],
                                 bcast)
            payload, new_ef = uplink.encode(
                delta, ef, ck if uplink.uses_key else None, level=level)
            decoded = uplink.decode(payload)
            if m is not None:
                # dropped / late client: its payload never uplinked, so
                # the residual it would have cleared stays local intact
                new_ef = jax.tree.map(
                    lambda n, o: jnp.where(m > 0, n, o), new_ef, ef)
            out = {"delta": decoded, "ef": new_ef, "loss": loss}
            for k in extra_keys:
                out[k] = trainable[k]
            if telemetry is not None:
                out["tele"] = telemetry.client_sums(ClientTapCtx(
                    n_examples=nex, loss=loss, global_model=bcast,
                    delta=delta, decoded=decoded, ef=new_ef,
                    pmask=m, staleness=st, level=level,
                    eff_bytes=eff_bytes))
            return out

        if mode == "client_parallel":
            if pmask is None:
                if telemetry is None:
                    outs = jax.vmap(client_step)(client_batches, ef_state,
                                                 client_keys)
                    tele = {}
                else:
                    outs = jax.vmap(client_step)(client_batches, ef_state,
                                                 client_keys, n_examples)
                    tele = _sum_clients(outs["tele"])
            else:
                if telemetry is None:
                    outs = jax.vmap(
                        lambda b, e, k, m: client_step(b, e, k, m=m))(
                            client_batches, ef_state, client_keys, pmask)
                    tele = {}
                else:
                    outs = jax.vmap(client_step)(
                        client_batches, ef_state, client_keys, n_examples,
                        pmask, pstale)
                    tele = _sum_clients(outs["tele"])
            wsums = {"delta": _weighted_sums(outs["delta"], weights)}
            for k in extra_keys:
                wsums[k] = _weighted_sums(outs[k], weights)
            return (wsums, {k: outs[k] for k in extra_keys}, outs["ef"],
                    outs["loss"], bcast, tele)

        acc0 = {"delta": zeros_like_tree(global_state["model"])}
        for k in extra_keys:
            acc0[k] = zeros_like_tree(global_state[k])
        acc_keys = tuple(acc0)

        if pmask is None:
            if telemetry is None:
                def body(acc, xs):
                    batches, w, ef, ck = xs
                    out = client_step(batches, ef, ck)
                    acc = {k: running_update(acc[k], out[k], w)
                           for k in acc}
                    return acc, (out["ef"], out["loss"])

                acc, (new_ef, losses) = jax.lax.scan(
                    body, acc0,
                    (client_batches, weights, ef_state, client_keys))
                return acc, None, new_ef, losses, bcast, {}

            def body(acc, xs):
                batches, w, ef, ck, nex = xs
                out = client_step(batches, ef, ck, nex)
                acc = {k: running_update(acc[k], out[k], w)
                       for k in acc_keys}
                return acc, (out["ef"], out["loss"], out["tele"])

            acc, (new_ef, losses, tele_c) = jax.lax.scan(
                body, acc0, (client_batches, weights, ef_state, client_keys,
                             n_examples))
            return acc, None, new_ef, losses, bcast, _sum_clients(tele_c)

        if telemetry is None:
            def body(acc, xs):
                batches, w, ef, ck, m = xs
                out = client_step(batches, ef, ck, m=m)
                acc = {k: running_update(acc[k], out[k], w) for k in acc}
                return acc, (out["ef"], out["loss"])

            acc, (new_ef, losses) = jax.lax.scan(
                body, acc0, (client_batches, weights, ef_state, client_keys,
                             pmask))
            return acc, None, new_ef, losses, bcast, {}

        def body(acc, xs):
            batches, w, ef, ck, nex, m, st = xs
            out = client_step(batches, ef, ck, nex, m, st)
            acc = {k: running_update(acc[k], out[k], w) for k in acc_keys}
            return acc, (out["ef"], out["loss"], out["tele"])

        acc, (new_ef, losses, tele_c) = jax.lax.scan(
            body, acc0, (client_batches, weights, ef_state, client_keys,
                         n_examples, pmask, pstale))
        return acc, None, new_ef, losses, bcast, _sum_clients(tele_c)

    return run_clients


def make_compressed_round_parts(bundle: ModelBundle, fl: FLConfig,
                                mode: str, uplink, downlink, *, impl="auto",
                                shard: ClientSharding, telemetry=None,
                                participation=False, controller=None):
    """Deferred-psum split of :func:`make_compressed_round_fn`.

    Returns ``(local_fn, finish_fn)`` for the fused-collective superstep:

    ``local_fn(global_state, client_batches, total, n_examples, lr,
    ef_state, down_mirror, key) -> (contribs, aux)`` — ``contribs``
    ``{"delta": tree, **extras, "loss": scalar}`` are this shard's
    psum-pending sums; ``aux`` carries ``new_ef`` (positional clients'
    fresh EF rows, routed through the fused exchange by the superstep)
    and ``bcast`` (the next downlink mirror).  ``total`` is the round's
    psum-completed example count, pipelined one collective ahead.

    ``finish_fn(global_state, summed) -> (new_state, metrics)`` applies
    the psum-completed aggregate delta to the full-precision server model
    and closes extras through ``finalize_extra_sums`` (see
    :func:`make_round_parts` for why that stays bitwise).

    With ``controller`` set (``repro.control``): ``local_fn`` takes a
    trailing ``ctrl_state`` whose ``level`` selects the round's encode
    rung (pre-psum, shard-local), and ``finish_fn(global_state, summed,
    ctrl_state) -> (new_state, metrics, new_ctrl)`` runs the controller's
    decision rule on the psum-completed metrics (post-psum, replicated).
    The split adds NOTHING to the fused psum beyond the controller tap's
    two f32 lanes — the round stays exactly one collective.
    """
    if controller is not None and telemetry is None:
        raise ValueError("a controller needs telemetry for its decision "
                         "signals (the engine forces the required taps on)")
    algo = _algorithm(fl)
    extra_keys = algo.extra_state
    _check_extra_keys(extra_keys)
    run_clients = _make_compressed_clients(bundle, fl, mode, uplink,
                                           downlink, impl=impl, shard=shard,
                                           telemetry=telemetry,
                                           controller=controller)

    if controller is not None:
        if participation:
            def local_fn(global_state, client_batches, total, n_examples,
                         lr, ef_state, down_mirror, key, pmask, pstale,
                         ctrl_state):
                weights = jnp.asarray(n_examples, jnp.float32) / total
                wsums, _, new_ef, losses, bcast, tele = run_clients(
                    global_state, client_batches, weights, lr, ef_state,
                    down_mirror, key, n_examples, pmask, pstale,
                    level=ctrl_state["level"])
                contribs = {**wsums, **masked_loss_sums(losses, pmask),
                            "tele": tele}
                return contribs, {"new_ef": new_ef, "bcast": bcast}
        else:
            def local_fn(global_state, client_batches, total, n_examples,
                         lr, ef_state, down_mirror, key, ctrl_state):
                weights = jnp.asarray(n_examples, jnp.float32) / total
                wsums, _, new_ef, losses, bcast, tele = run_clients(
                    global_state, client_batches, weights, lr, ef_state,
                    down_mirror, key, n_examples,
                    level=ctrl_state["level"])
                contribs = {**wsums, "loss": jnp.mean(losses),
                            "tele": tele}
                return contribs, {"new_ef": new_ef, "bcast": bcast}

        def finish_fn(global_state, summed, ctrl_state):
            new_model = jax.tree.map(lambda g, d: g + d.astype(g.dtype),
                                     global_state["model"], summed["delta"])
            new_state: Dict[str, Any] = {"model": new_model}
            new_state.update(algo.finalize_extra_sums(
                fl, global_state, {k: summed[k] for k in extra_keys}))
            if participation:
                metrics = {"local_loss": finish_masked_loss(summed)}
            else:
                metrics = {"local_loss": summed["loss"] / shard.n_shards}
            metrics.update(telemetry.finish(summed["tele"]))
            new_ctrl = controller.update(ctrl_state, metrics)
            return new_state, metrics, new_ctrl

        return local_fn, finish_fn

    if participation:
        def local_fn(global_state, client_batches, total, n_examples, lr,
                     ef_state, down_mirror, key, pmask, pstale):
            weights = jnp.asarray(n_examples, jnp.float32) / total
            wsums, _, new_ef, losses, bcast, tele = run_clients(
                global_state, client_batches, weights, lr, ef_state,
                down_mirror, key, n_examples, pmask, pstale)
            contribs = {**wsums, **masked_loss_sums(losses, pmask),
                        "tele": tele}
            return contribs, {"new_ef": new_ef, "bcast": bcast}
    else:
        def local_fn(global_state, client_batches, total, n_examples, lr,
                     ef_state, down_mirror, key):
            weights = jnp.asarray(n_examples, jnp.float32) / total
            wsums, _, new_ef, losses, bcast, tele = run_clients(
                global_state, client_batches, weights, lr, ef_state,
                down_mirror, key, n_examples)
            contribs = {**wsums, "loss": jnp.mean(losses), "tele": tele}
            return contribs, {"new_ef": new_ef, "bcast": bcast}

    def finish_fn(global_state, summed):
        new_model = jax.tree.map(lambda g, d: g + d.astype(g.dtype),
                                 global_state["model"], summed["delta"])
        new_state: Dict[str, Any] = {"model": new_model}
        new_state.update(algo.finalize_extra_sums(
            fl, global_state, {k: summed[k] for k in extra_keys}))
        if participation:
            metrics = {"local_loss": finish_masked_loss(summed)}
        else:
            metrics = {"local_loss": summed["loss"] / shard.n_shards}
        if telemetry is not None:
            metrics.update(telemetry.finish(summed["tele"]))
        return new_state, metrics

    return local_fn, finish_fn


def init_global_state(bundle: ModelBundle, fl: FLConfig, key):
    """Server line 1: initialise the global model (+ the algorithm's
    extra state — FedFusion's fusion module)."""
    algo = _algorithm(fl)
    k1, k2 = jax.random.split(key)
    state: Dict[str, Any] = {"model": bundle.init(k1)}
    state.update(algo.init_extra_state(bundle, fl, k2))
    return state
