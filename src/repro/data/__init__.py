from repro.data.federated import FederatedDataset  # noqa: F401
from repro.data.partition import (artificial_noniid_partition,  # noqa: F401
                                  class_split_partition, iid_partition,
                                  permuted_partition, source_partition)
from repro.data.synth import class_images, token_stream  # noqa: F401
