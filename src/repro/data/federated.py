"""Federated data loader: samples clients per round and builds the stacked
round batch the round-fn consumes ([n_clients, local_steps, B, ...]).

Also hosts the deterministic *chaos layer*: per-client compute-speed
draws, per-round dropout and arrival jitter, and partial-local-epoch
truncation, all keyed off the dataset's rng streams so every fault
schedule is reproducible — and replayable through
``skip_round_sampling`` on resume-from-checkpoint."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

# Above this federation size, ``sample_clients`` switches from numpy's
# permutation-based ``choice`` (O(N) per round — it shuffles the whole id
# space) to Floyd's O(C) without-replacement draw.  The threshold keeps
# every test- and paper-scale dataset on the original ``choice`` stream so
# the bitwise reference pins are untouched; only federations too large to
# have pinned histories take the fast path.
_FLOYD_THRESHOLD = 4096


class TemplateClients:
    """A lazy federation: ``n`` virtual clients sharing one template shard.

    The million-client benches need a federation whose *size* is real but
    whose per-client data never materializes N copies: this sequence
    answers ``len`` with ``n`` and every ``[i]`` with the same template
    dict.  Combined with the cohort-paged EF store and Floyd sampling,
    a 10^6-client run allocates O(C) host memory for data, not O(N).
    """

    def __init__(self, template: Dict[str, np.ndarray], n: int):
        self._template = dict(template)
        self._n = int(n)

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i) -> Dict[str, np.ndarray]:
        if not 0 <= int(i) < self._n:
            raise IndexError(i)
        return self._template


@dataclass(frozen=True)
class ChaosConfig:
    """Deterministic client-heterogeneity injection.

    ``speed_sigma``: sigma of the *static* per-client lognormal compute
    speed (drawn once at dataset construction from a seed-derived rng;
    heavy-tailed — a client's simulated arrival time is
    ``jitter / speed``).  ``jitter``: sigma of the per-(round, client)
    lognormal arrival jitter.  ``dropout``: per-(round, client)
    probability of dropping out of the round entirely.  ``truncation``:
    probability a surviving client only completes a uniform fraction of
    its local steps (simulated as a proportional cut to its example
    weight — the psum shape never changes).  ``seed``: the static-speed
    stream seed; ``None`` derives it from the dataset seed.

    All per-round draws ride ``FederatedDataset._rng`` *after* the
    round's batch draws, in a fixed order, so a given dataset seed
    reproduces the identical fault schedule — including across
    interrupt + resume via ``skip_round_sampling``.
    """

    speed_sigma: float = 1.0
    jitter: float = 0.1
    dropout: float = 0.0
    truncation: float = 0.0
    seed: Optional[int] = None


@dataclass(frozen=True)
class ChaosDraws:
    """One round's chaos draws for the sampled cohort.

    ``arrival``: float32 [cohort] simulated completion times (1.0 == a
    nominal median client).  ``dropped``: bool [cohort].  ``work``:
    float32 [cohort] in (0, 1] — the fraction of local work a surviving
    client completed (1.0 unless truncated).
    """

    arrival: np.ndarray
    dropped: np.ndarray
    work: np.ndarray


class FederatedDataset:
    """Holds per-client datasets + a held-out test set."""

    def __init__(self, clients: List[Dict[str, np.ndarray]],
                 test: Dict[str, np.ndarray], *, seed: int = 0,
                 chaos: Optional[ChaosConfig] = None):
        self.clients = clients
        self.test = test
        self._sizes = None          # client_sizes cache (shards are frozen)
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self.chaos = chaos
        if chaos is not None:
            # static heavy-tailed per-client speeds, from their own
            # seed-derived stream so they never perturb round sampling
            speed_rng = np.random.default_rng(
                seed if chaos.seed is None else chaos.seed)
            self._client_speed = speed_rng.lognormal(
                0.0, chaos.speed_sigma, len(clients)).astype(np.float32)

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    def client_sizes(self) -> np.ndarray:
        """Per-client example counts [N] — computed once and cached (the
        shards never change size); :class:`TemplateClients` federations
        fill the vector without touching N dicts."""
        if self._sizes is None:
            key = "x" if "x" in self.clients[0] else "tokens"
            if isinstance(self.clients, TemplateClients):
                self._sizes = np.full(self.n_clients,
                                      len(self.clients[0][key]), np.float32)
            else:
                self._sizes = np.array([len(c[key]) for c in self.clients],
                                       np.float32)
        return self._sizes

    def sample_clients(self, n: int) -> np.ndarray:
        """Sample n distinct client ids.  Uniqueness is load-bearing: the
        server scatters per-client EF state back by cid (``dst[cids] =
        src`` / ``table.at[cids].set``), which silently keeps only the
        LAST write for a duplicated cid — one client's residual would be
        lost every round.

        Raises ``ValueError`` when ``n > n_clients``: a cohort quietly
        shrinking (the old behavior clamped with ``min``) is exactly the
        silent-partial-participation failure mode the participation
        policies make explicit.

        Cost: federations at or below ``_FLOYD_THRESHOLD`` use numpy's
        permutation ``choice`` (the stream every pinned history was
        recorded on); above it, Floyd's algorithm draws the n distinct
        ids in O(n) rng calls, so sampling cost follows the COHORT, not
        the federation — a 10^6-client round samples as fast as a
        10^3-client one.  Both paths ride ``self._rng``, so
        ``skip_round_sampling`` (which calls back into this method)
        replays either stream exactly."""
        if n > self.n_clients:
            raise ValueError(
                f"cannot sample {n} distinct clients from a federation of "
                f"{self.n_clients}; lower clients_per_round (or "
                f"over_provision for the deadline policy)")
        n_total = self.n_clients
        if n_total > _FLOYD_THRESHOLD:
            # Floyd's without-replacement draw: uniform over n-subsets,
            # one bounded integer draw per picked id.
            seen = set()
            picks = []
            for j in range(n_total - n, n_total):
                t = int(self._rng.integers(0, j + 1))
                pick = t if t not in seen else j
                seen.add(pick)
                picks.append(pick)
            cids = np.array(picks, np.int64)
        else:
            cids = self._rng.choice(n_total, size=n, replace=False)
        if len(np.unique(cids)) != len(cids):
            raise ValueError(
                f"sample_clients returned duplicate cids: {cids}")
        return cids

    def _draw(self, client: Dict[str, np.ndarray], n: int) -> Dict[str, np.ndarray]:
        key = "x" if "x" in client else "tokens"
        size = len(client[key])
        idx = self._rng.choice(size, size=n, replace=size < n)
        return {k: v[idx] for k, v in client.items() if k != "perm"}

    def round_batch(self, client_ids, local_steps: int, batch: int):
        """Returns (batches, n_examples):
        batches: dict of arrays [n_clients, local_steps, batch, ...]
        n_examples: [n_clients] (n_t for weighting).
        """
        per_client = []
        for cid in client_ids:
            steps = [self._draw(self.clients[cid], batch)
                     for _ in range(local_steps)]
            per_client.append({k: np.stack([s[k] for s in steps])
                               for k in steps[0]})
        stacked = {k: np.stack([pc[k] for pc in per_client])
                   for k in per_client[0]}
        sizes = self.client_sizes()[np.asarray(client_ids)]
        return _to_batch(stacked), sizes

    def chaos_round(self, client_ids) -> Optional[ChaosDraws]:
        """Draw one round's fault schedule for ``client_ids``.

        Consumes exactly three draws from ``self._rng`` (jitter, dropout,
        truncation — in that order, each sized to the cohort) iff chaos
        is configured; returns ``None`` (consuming nothing) otherwise.
        Callers must invoke this immediately after ``round_batch`` so the
        stream position is a pure function of (seed, round index) and
        ``skip_round_sampling`` can replay it.
        """
        if self.chaos is None:
            return None
        c = self.chaos
        n = len(client_ids)
        jitter = self._rng.lognormal(0.0, c.jitter, n).astype(np.float32)
        dropped = self._rng.random(n) < c.dropout
        trunc = self._rng.random(2 * n).reshape(2, n)
        work = np.where(trunc[0] < c.truncation,
                        np.maximum(trunc[1], 1.0 / 16.0), 1.0)
        arrival = jitter / self._client_speed[np.asarray(client_ids)]
        return ChaosDraws(arrival=arrival, dropped=dropped,
                          work=work.astype(np.float32))

    def _consume_chaos_round(self, n: int) -> None:
        """Consume ``chaos_round``'s rng draws without materializing them
        (the ``skip_round_sampling`` replay counterpart)."""
        c = self.chaos
        self._rng.lognormal(0.0, c.jitter, n)
        self._rng.random(n)
        self._rng.random(2 * n)

    def round_chunk(self, n_rounds: int, clients_per_round: int,
                    local_steps: int, batch: int, *, pool=None,
                    participation: Optional[Callable] = None):
        """Sample ``n_rounds`` consecutive rounds for the superstep engine.

        Returns (cids [K, C], batches {k: [K, C, steps, B, ...]},
        sizes [K, C]).  The per-round draw order (sample_clients, then
        round_batch) is IDENTICAL to the one-round-at-a-time server loop,
        so the rng stream — and therefore every sampled batch — matches the
        reference loop bit for bit.

        ``pool`` (a ``repro.engine.pipeline.StagingPool``): the stacked
        output arrays are written into reusable staging buffers instead of
        freshly allocated memory — steady-state chunk staging then touches
        no new host pages.  The caller must not re-enter with the same
        pool while the previous chunk's buffers are still being
        transferred.

        ``participation`` (optional): a host callable
        ``draws -> RoundParticipation`` (see ``repro.fl.participation``)
        invoked once per round with that round's :class:`ChaosDraws`
        (``None`` when chaos is off).  When given, a fourth element is
        returned: ``{"mask" [K, C], "staleness" [K, C], "weight" [K, C],
        "round_time" [K], "n_arrived" [K]}``.  Chaos draws are consumed
        iff ``self.chaos`` is set, *independent* of ``participation``,
        so the rng stream position never depends on who is reading it.
        """
        cids_l, batch_l, size_l, part_l = [], [], [], []
        for _ in range(n_rounds):
            cids = self.sample_clients(clients_per_round)
            b, s = self.round_batch(cids, local_steps, batch)
            draws = self.chaos_round(cids)
            cids_l.append(cids)
            batch_l.append(b)
            size_l.append(s)
            if participation is not None:
                part_l.append((participation(draws), draws))

        def _stack(name, parts, dtype=None):
            dtype = dtype or parts[0].dtype
            shape = (len(parts),) + parts[0].shape
            out = pool.take(name, shape, dtype) if pool is not None else \
                np.empty(shape, dtype)
            for i, p in enumerate(parts):
                out[i] = p
            return out

        stacked = {k: _stack(f"batch/{k}", [b[k] for b in batch_l])
                   for k in batch_l[0]}
        out = (_stack("cids", cids_l, np.int32), stacked,
               _stack("sizes", size_l, np.float32))
        if participation is None:
            return out
        f32 = np.float32
        part = {
            "mask": _stack("part/mask", [p.mask for p, _ in part_l], f32),
            "staleness": _stack("part/staleness",
                                [p.staleness for p, _ in part_l], f32),
            "weight": _stack("part/weight",
                             [p.weight for p, _ in part_l], f32),
            # truncated clients complete a fraction of their local work;
            # simulate as a proportional example-weight cut (host-side)
            "work": _stack("part/work",
                           [np.ones_like(p.mask) if d is None else d.work
                            for p, d in part_l], f32),
            "round_time": np.array([p.round_time for p, _ in part_l], f32),
            "n_arrived": np.array([p.n_arrived for p, _ in part_l],
                                  np.int32),
        }
        return out + (part,)

    def skip_round_sampling(self, n_rounds: int, clients_per_round: int,
                            local_steps: int, batch: int) -> None:
        """Re-seed the sampling rng and consume exactly the draws the
        first ``n_rounds`` rounds make (``sample_clients`` +
        ``round_batch``, same order) WITHOUT materializing batches.

        Resume-from-checkpoint replays the stream with this, so a resumed
        run samples for round r exactly what an uninterrupted run would
        have — ``fit`` interrupted + resumed lands bitwise on the
        uninterrupted result (pinned by tests/test_api.py).  Re-seeding
        (rather than advancing in place) makes that hold from a fresh
        dataset AND from the same in-process instance, whose rng may
        already sit past the checkpointed round (the prefetcher stages
        chunks ahead of the training front).  Only round sampling is
        replayed: interleave explicit ``test_batch(n)`` draws and the
        stream diverges — the server loops never do.
        """
        self._rng = np.random.default_rng(self._seed)
        key = "x" if "x" in self.clients[0] else "tokens"
        for _ in range(n_rounds):
            cids = self.sample_clients(clients_per_round)
            for cid in cids:
                size = len(self.clients[cid][key])
                for _ in range(local_steps):
                    self._rng.choice(size, size=batch, replace=size < batch)
            if self.chaos is not None:
                self._consume_chaos_round(len(cids))

    def test_batch(self, n: Optional[int] = None) -> Dict[str, np.ndarray]:
        if n is None:
            return _to_batch(dict(self.test))
        key = "x" if "x" in self.test else "tokens"
        idx = self._rng.choice(len(self.test[key]), size=n, replace=False)
        return _to_batch({k: v[idx] for k, v in self.test.items()})


def _to_batch(d: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Map raw arrays to model-batch keys (tokens -> tokens+labels)."""
    if "tokens" in d:
        toks = d.pop("tokens")
        d["tokens"] = toks[..., :-1]
        d["labels"] = toks[..., 1:]
    return d
