"""Federated data loader: samples clients per round and builds the stacked
round batch the round-fn consumes ([n_clients, local_steps, B, ...])."""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class FederatedDataset:
    """Holds per-client datasets + a held-out test set."""

    def __init__(self, clients: List[Dict[str, np.ndarray]],
                 test: Dict[str, np.ndarray], *, seed: int = 0):
        self.clients = clients
        self.test = test
        self._seed = seed
        self._rng = np.random.default_rng(seed)

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    def client_sizes(self) -> np.ndarray:
        key = "x" if "x" in self.clients[0] else "tokens"
        return np.array([len(c[key]) for c in self.clients], np.float32)

    def sample_clients(self, n: int) -> np.ndarray:
        """Sample n distinct client ids.  Uniqueness is load-bearing: the
        server scatters per-client EF state back by cid (``dst[cids] =
        src`` / ``table.at[cids].set``), which silently keeps only the
        LAST write for a duplicated cid — one client's residual would be
        lost every round."""
        n = min(n, self.n_clients)
        cids = self._rng.choice(self.n_clients, size=n, replace=False)
        assert len(np.unique(cids)) == len(cids), \
            f"sample_clients returned duplicate cids: {cids}"
        return cids

    def _draw(self, client: Dict[str, np.ndarray], n: int) -> Dict[str, np.ndarray]:
        key = "x" if "x" in client else "tokens"
        size = len(client[key])
        idx = self._rng.choice(size, size=n, replace=size < n)
        return {k: v[idx] for k, v in client.items() if k != "perm"}

    def round_batch(self, client_ids, local_steps: int, batch: int):
        """Returns (batches, n_examples):
        batches: dict of arrays [n_clients, local_steps, batch, ...]
        n_examples: [n_clients] (n_t for weighting).
        """
        per_client = []
        for cid in client_ids:
            steps = [self._draw(self.clients[cid], batch)
                     for _ in range(local_steps)]
            per_client.append({k: np.stack([s[k] for s in steps])
                               for k in steps[0]})
        stacked = {k: np.stack([pc[k] for pc in per_client])
                   for k in per_client[0]}
        sizes = self.client_sizes()[np.asarray(client_ids)]
        return _to_batch(stacked), sizes

    def round_chunk(self, n_rounds: int, clients_per_round: int,
                    local_steps: int, batch: int, *, pool=None):
        """Sample ``n_rounds`` consecutive rounds for the superstep engine.

        Returns (cids [K, C], batches {k: [K, C, steps, B, ...]},
        sizes [K, C]).  The per-round draw order (sample_clients, then
        round_batch) is IDENTICAL to the one-round-at-a-time server loop,
        so the rng stream — and therefore every sampled batch — matches the
        reference loop bit for bit.

        ``pool`` (a ``repro.engine.pipeline.StagingPool``): the stacked
        output arrays are written into reusable staging buffers instead of
        freshly allocated memory — steady-state chunk staging then touches
        no new host pages.  The caller must not re-enter with the same
        pool while the previous chunk's buffers are still being
        transferred.
        """
        cids_l, batch_l, size_l = [], [], []
        for _ in range(n_rounds):
            cids = self.sample_clients(clients_per_round)
            b, s = self.round_batch(cids, local_steps, batch)
            cids_l.append(cids)
            batch_l.append(b)
            size_l.append(s)

        def _stack(name, parts, dtype=None):
            dtype = dtype or parts[0].dtype
            shape = (len(parts),) + parts[0].shape
            out = pool.take(name, shape, dtype) if pool is not None else \
                np.empty(shape, dtype)
            for i, p in enumerate(parts):
                out[i] = p
            return out

        stacked = {k: _stack(f"batch/{k}", [b[k] for b in batch_l])
                   for k in batch_l[0]}
        return (_stack("cids", cids_l, np.int32), stacked,
                _stack("sizes", size_l, np.float32))

    def skip_round_sampling(self, n_rounds: int, clients_per_round: int,
                            local_steps: int, batch: int) -> None:
        """Re-seed the sampling rng and consume exactly the draws the
        first ``n_rounds`` rounds make (``sample_clients`` +
        ``round_batch``, same order) WITHOUT materializing batches.

        Resume-from-checkpoint replays the stream with this, so a resumed
        run samples for round r exactly what an uninterrupted run would
        have — ``fit`` interrupted + resumed lands bitwise on the
        uninterrupted result (pinned by tests/test_api.py).  Re-seeding
        (rather than advancing in place) makes that hold from a fresh
        dataset AND from the same in-process instance, whose rng may
        already sit past the checkpointed round (the prefetcher stages
        chunks ahead of the training front).  Only round sampling is
        replayed: interleave explicit ``test_batch(n)`` draws and the
        stream diverges — the server loops never do.
        """
        self._rng = np.random.default_rng(self._seed)
        key = "x" if "x" in self.clients[0] else "tokens"
        for _ in range(n_rounds):
            cids = self.sample_clients(clients_per_round)
            for cid in cids:
                size = len(self.clients[cid][key])
                for _ in range(local_steps):
                    self._rng.choice(size, size=batch, replace=size < batch)

    def test_batch(self, n: Optional[int] = None) -> Dict[str, np.ndarray]:
        if n is None:
            return _to_batch(dict(self.test))
        key = "x" if "x" in self.test else "tokens"
        idx = self._rng.choice(len(self.test[key]), size=n, replace=False)
        return _to_batch({k: v[idx] for k, v in self.test.items()})


def _to_batch(d: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Map raw arrays to model-batch keys (tokens -> tokens+labels)."""
    if "tokens" in d:
        toks = d.pop("tokens")
        d["tokens"] = toks[..., :-1]
        d["labels"] = toks[..., 1:]
    return d
