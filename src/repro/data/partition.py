"""The paper's three client-partition schemes (§4.1)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(x, y, n_clients, *, seed=0) -> List[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    splits = np.array_split(perm, n_clients)
    return [{"x": x[s], "y": y[s]} for s in splits]


def artificial_noniid_partition(x, y, n_clients, *, shards_per_client=2,
                                seed=0) -> List[Dict[str, np.ndarray]]:
    """Sort by label, split into shards, deal ``shards_per_client`` to each
    client (paper: 200 shards of 300 -> 100 clients x 2 shards; and the
    2-client binary split = 1 shard of 5 classes each)."""
    rng = np.random.default_rng(seed)
    order = np.argsort(y, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    shard_ids = rng.permutation(n_shards)
    out = []
    for c in range(n_clients):
        ids = shard_ids[c * shards_per_client:(c + 1) * shards_per_client]
        idx = np.concatenate([shards[i] for i in ids])
        out.append({"x": x[idx], "y": y[idx]})
    return out


def class_split_partition(x, y, n_clients, *, n_classes=10
                          ) -> List[Dict[str, np.ndarray]]:
    """Paper §4.2.1: split the classes into ``n_clients`` disjoint sets
    (e.g. CIFAR-10 5+5 for two clients)."""
    classes = np.array_split(np.arange(n_classes), n_clients)
    out = []
    for cs in classes:
        idx = np.isin(y, cs)
        out.append({"x": x[idx], "y": y[idx]})
    return out


def permuted_partition(x, y, n_clients, *, seed=0
                       ) -> List[Dict[str, np.ndarray]]:
    """User-specific non-IID (§4.3.2): each client sees the same data under
    a fixed client-specific pixel permutation (Permuted MNIST)."""
    rng = np.random.default_rng(seed)
    base = iid_partition(x, y, n_clients, seed=seed)
    H, W, C = x.shape[1:]
    out = []
    for c, part in enumerate(base):
        perm = rng.permutation(H * W * C)
        xf = part["x"].reshape(len(part["x"]), -1)[:, perm]
        out.append({"x": xf.reshape(part["x"].shape), "y": part["y"],
                    "perm": perm})
    return out


def source_partition(tokens, src, n_clients, *, sources_per_client=1,
                     seed=0) -> List[Dict[str, np.ndarray]]:
    """Non-IID LM partition: each client gets sequences from a subset of
    sources (analogue of the class-shard split for token data)."""
    rng = np.random.default_rng(seed)
    n_sources = int(src.max()) + 1
    src_ids = rng.permutation(n_sources)
    out = []
    for c in range(n_clients):
        take = src_ids[(c * sources_per_client) % n_sources:
                       (c * sources_per_client) % n_sources
                       + sources_per_client]
        idx = np.isin(src, take)
        out.append({"tokens": tokens[idx]})
    return out
