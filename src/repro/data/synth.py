"""Synthetic datasets (offline container — no MNIST/CIFAR downloads).

The generators preserve what the paper's experiments actually probe:
class-conditional structure (so CNNs learn and accuracy curves are
meaningful) and controllable client heterogeneity via the partitioners.

* ``class_images``: K Gaussian-blob class templates + pixel noise, shaped
  like MNIST (28x28x1) or CIFAR (32x32x3).  A 2-conv CNN separates them in a
  few hundred steps, mirroring the paper's convergence-rate experiments.
* ``token_stream``: per-source skewed unigram/bigram token distributions for
  the LM architectures (non-IID = clients see different source mixes).
"""
from __future__ import annotations

import numpy as np


def class_images(n_per_class, *, n_classes=10, shape=(28, 28, 1), seed=0,
                 noise=0.35, blobs_per_class=3, template_seed=None):
    """Returns x [N,H,W,C] float32 in [0,1]-ish, y [N] int32.

    ``template_seed`` fixes the class templates independently of the
    noise/shuffle seed, so a train split (seed=0) and a test split (seed=1)
    sample the SAME class-conditional distribution — pass the same
    template_seed to both.  Defaults to ``seed`` (templates follow seed).
    """
    t_rng = np.random.default_rng(
        seed if template_seed is None else template_seed)
    rng = np.random.default_rng(seed)
    H, W, C = shape
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    templates = np.zeros((n_classes, H, W, C), np.float32)
    my, mx = min(4, H // 4), min(4, W // 4)  # margin, small-image safe
    for c in range(n_classes):
        for _ in range(blobs_per_class):
            cy, cx = t_rng.uniform(my, H - my), t_rng.uniform(mx, W - mx)
            sig = t_rng.uniform(1.5, 3.5)
            amp = t_rng.uniform(0.6, 1.0)
            blob = amp * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2)
                                / (2 * sig ** 2))
            ch = t_rng.integers(0, C)
            templates[c, :, :, ch] += blob
    templates = np.clip(templates, 0, 1.5)

    xs, ys = [], []
    for c in range(n_classes):
        imgs = templates[c][None] + noise * rng.standard_normal(
            (n_per_class, H, W, C)).astype(np.float32)
        xs.append(imgs)
        ys.append(np.full(n_per_class, c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return x[perm].astype(np.float32), y[perm]


def token_stream(n_seqs, seq_len, *, vocab, n_sources=10, seed=0, alpha=0.3):
    """Returns tokens [N, seq_len+1] int32, source [N] int32.

    Each source s has a Dirichlet-skewed unigram distribution over a
    source-specific vocab slice, plus a shared bigram "grammar" so there's
    real next-token signal to learn.
    """
    rng = np.random.default_rng(seed)
    vocab_eff = min(vocab, 4096)  # keep the generator cheap; ids < vocab
    probs = rng.dirichlet(np.full(vocab_eff, alpha), size=n_sources)
    shift = rng.integers(1, vocab_eff, size=n_sources)

    toks = np.zeros((n_seqs, seq_len + 1), np.int64)
    src = rng.integers(0, n_sources, size=n_seqs)
    for i in range(n_seqs):
        s = src[i]
        draws = rng.choice(vocab_eff, size=seq_len + 1, p=probs[s])
        # deterministic bigram twist: every even position continues the
        # previous token's "phrase" (strong learnable structure)
        for t in range(1, seq_len + 1, 2):
            draws[t] = (draws[t - 1] + shift[s]) % vocab_eff
        toks[i] = draws
    return toks.astype(np.int32), src.astype(np.int32)
