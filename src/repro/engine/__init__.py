"""Device-resident federated training engine.

Replaces the Python-per-round server loop with a jitted K-round superstep
(``lax.scan`` over the round fn, donated buffers, on-device error-feedback
scatter), a double-buffered host prefetch pipeline, and deferred metrics
so the host never blocks except at checkpoint boundaries — boundary
evaluation dispatches on a state snapshot and overlaps the next chunk.
On a mesh whose ``pod``/``data`` axes multiply past 1 the superstep runs
client-parallel under ``shard_map`` with the EF table row-sharded by
client id (``repro.engine.sharded``).

    run_federated_engine   — drop-in engine behind ``repro.fl.server``
    make_plain_superstep / make_compressed_superstep — jit-able supersteps
    make_sharded_superstep / client_sharding — shard_map-wrapped variants
    HostPrefetcher / StagingPool — background chunk staging
    MetricsPump            — async device->host metric fetch + CommLog
    make_eval_fn / pad_eval_batch — fixed-shape jit-able evaluation
"""
from repro.engine.engine import (ServerResult,  # noqa: F401
                                 chunk_schedule, run_federated_engine)
from repro.engine.evaljit import make_eval_fn, pad_eval_batch  # noqa: F401
from repro.engine.metrics import MetricsPump  # noqa: F401
from repro.engine.pipeline import HostPrefetcher, StagingPool  # noqa: F401
from repro.engine.sharded import (client_sharding,  # noqa: F401
                                  make_sharded_eval, make_sharded_superstep)
from repro.engine.superstep import (make_compressed_superstep,  # noqa: F401
                                    make_plain_superstep)
