"""Device-resident federated training engine.

Replaces the Python-per-round server loop with a jitted K-round superstep
(``lax.scan`` over the round fn, donated buffers, on-device error-feedback
scatter), a double-buffered host prefetch pipeline, and deferred metrics
so the host never blocks except at eval/checkpoint boundaries.

    run_federated_engine   — drop-in engine behind ``repro.fl.server``
    make_plain_superstep / make_compressed_superstep — jit-able supersteps
    HostPrefetcher         — background chunk staging thread
    MetricsPump            — async device->host metric fetch + CommLog
    make_eval_fn / pad_eval_batch — fixed-shape jit-able evaluation
"""
from repro.engine.engine import (ServerResult,  # noqa: F401
                                 chunk_schedule, run_federated_engine)
from repro.engine.evaljit import make_eval_fn, pad_eval_batch  # noqa: F401
from repro.engine.metrics import MetricsPump  # noqa: F401
from repro.engine.pipeline import HostPrefetcher  # noqa: F401
from repro.engine.superstep import (make_compressed_superstep,  # noqa: F401
                                    make_plain_superstep)
