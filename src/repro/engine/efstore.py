"""Cohort-paged error-feedback store: O(C·n) device memory at any N.

The engine's compressed path keeps one error-feedback residual row per
client.  The dense backing (``[N, n]`` on device, row-sharded with
resident scratch rows on a mesh) caps federation size at what fits in
HBM — a 1M-client × 1M-param federation is 4 TB.  This module replaces
the *backing store* without touching the jitted round math, which is
already cohort-shaped (per-round ``ef_gather``/``ef_scatter`` by cid,
one fused psum per round):

* :class:`HostEFStore` — host-resident rows keyed by client id.  An
  absent key IS the all-zero row (EF state initializes to zeros), so
  memory is O(touched-clients · n), not O(N · n), and a fresh store is
  bitwise-identical to a fresh dense table.
* :func:`plan_chunk_static` — pure function from a chunk's sampled
  ``cids [K, C]`` to a :class:`PagePlan`: every unique client gets one
  *virtual cid* (a page slot), so the superstep's gather/scatter/match
  logic runs unchanged on a ``[P, n]`` page (``P = K*C`` slots) instead
  of the ``[N, n]`` table.  The mapping is injective within the chunk,
  which is all the round math ever relied on; on a mesh a client's slot
  lives on its *owner* shard (``cid % S`` — any fixed map works) and
  the page keeps the resident scratch-row layout ``[(P_loc+1)*S, n]``,
  so the sharded ownership arithmetic (``n_loc = table.shape[0] - 1``)
  is also unchanged.
* :class:`EFPager` — the pipeline glue.  ``stage`` (prefetch thread)
  gathers the next chunk's rows from the store into a zeroed page while
  the current chunk trains; ``complete`` (dispatch thread) hands the
  chunk's output page to a :class:`repro.engine.pipeline.WritebackLane`
  that copies the updated rows back to the store off-thread; ``patch``
  (dispatch thread) overwrites, ON DEVICE, the rows of the incoming page
  whose clients were updated by the immediately-previous chunk — staging
  only waits for write-backs through chunk j-2, so gather/write-back/
  train all overlap, and the j-1 overlap window is closed by the patch
  instead of a host sync.  The patch also launders the host-staged page
  into a jit-output buffer, keeping the superstep's unconditional EF
  donation safe on every backend.

Bitwise contract: a paged run equals the dense run bit for bit.  Page
rows hold the exact dense-row values (gathered, or patched from the
previous chunk's output); virtual cids preserve the match/ownership
structure; and the fused psum of {one shard's row, zeros elsewhere} is
bitwise position-independent (0 + x == x exactly, including the
signed-zero corner where (-0.) + (+0.) == +0. regardless of operand
order).  ``tests/test_efstore.py`` pins this per mode × codec, single-
device and sharded, across checkpoint-resume.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.engine.pipeline import WritebackLane
from repro.kernels import ops

__all__ = ["HostEFStore", "PagePlan", "plan_chunk_static", "EFPager"]


class HostEFStore:
    """Host-resident per-client EF rows, keyed by client id.

    ``template`` is the per-client row pytree (``uplink.init_state()`` —
    leaf shapes WITHOUT the leading client axis).  Rows are stored as
    per-leaf numpy copies; an absent cid means the all-zero row, so
    ``from_dense`` drops zero rows and a never-trained federation costs
    no host memory at all.
    """

    def __init__(self, template):
        leaves, treedef = jax.tree_util.tree_flatten(template)
        self._treedef = treedef
        self._shapes = [tuple(np.shape(z)) for z in leaves]
        self._dtypes = [np.dtype(jnp.asarray(z).dtype) for z in leaves]
        self._rows: Dict[int, List[np.ndarray]] = {}
        self.hits = 0            # page rows served from a stored row
        self.misses = 0          # page rows that were implicit zeros
        self.writeback_rows = 0  # rows written back across the run

    @property
    def n_rows(self) -> int:
        return len(self._rows)

    @property
    def n_leaves(self) -> int:
        return len(self._shapes)

    def row_nbytes(self) -> int:
        """Bytes of ONE client's row across all leaves (the O(C·n) unit)."""
        return sum(int(np.prod(s, dtype=np.int64)) * d.itemsize
                   for s, d in zip(self._shapes, self._dtypes))

    def gather(self, cids, buffers: List[np.ndarray], rows) -> None:
        """Fill row ``rows[i]`` of every (pre-zeroed) leaf buffer with
        client ``cids[i]``'s stored row; a miss leaves the zeros."""
        for cid, ri in zip(np.asarray(cids).tolist(), np.asarray(rows).tolist()):
            stored = self._rows.get(cid)
            if stored is None:
                self.misses += 1
                continue
            self.hits += 1
            for buf, leaf in zip(buffers, stored):
                buf[ri] = leaf

    def update(self, cids, buffers: List[np.ndarray], rows) -> None:
        """Store client ``cids[i]``'s row from row ``rows[i]`` of every
        leaf buffer.  Rows are COPIED — views would pin the whole page."""
        for cid, ri in zip(np.asarray(cids).tolist(), np.asarray(rows).tolist()):
            self._rows[cid] = [np.array(buf[ri]) for buf in buffers]
        self.writeback_rows += len(cids)

    def to_dense(self, n_clients: int):
        """The compact ``[N, ...]`` numpy tree (the ef.npz disk layout)."""
        leaves = [np.zeros((n_clients,) + s, d)
                  for s, d in zip(self._shapes, self._dtypes)]
        for cid, stored in self._rows.items():
            for arr, leaf in zip(leaves, stored):
                arr[cid] = leaf
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def from_dense(self, dense) -> None:
        """Load from a compact ``[N, ...]`` tree, keeping only non-zero
        rows (a zero row is bitwise-identical to an absent one)."""
        leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(dense)]
        nonzero = np.zeros(leaves[0].shape[0], bool)
        for arr in leaves:
            nonzero |= arr.reshape(arr.shape[0], -1).any(axis=1)
        self._rows.clear()
        for cid in np.nonzero(nonzero)[0].tolist():
            self._rows[cid] = [np.array(arr[cid]) for arr in leaves]


@dataclass(frozen=True)
class PagePlan:
    """One chunk's cid -> page-slot assignment (host-side, static).

    ``vcids [K, C]`` replace the real cids as the superstep's ``cids``
    input; ``uniq``/``slots``/``rows`` describe, per unique client, its
    block-local slot and its physical row in the staged page arrays.
    ``p_loc`` is the per-shard slot capacity (``K*C`` — in the worst
    case every sampled client is owned by one shard), ``page_rows`` the
    staged leading dim: ``p_loc`` unsharded, ``(p_loc+1)*S`` sharded
    (one resident scratch row per shard block, exactly like the dense
    resident layout).
    """

    index: int            # chunk sequence number (-1: calibration)
    cids: np.ndarray      # [K, C] real client ids
    vcids: np.ndarray     # [K, C] int32 virtual (page-relative) ids
    uniq: np.ndarray      # unique real cids (sorted)
    slots: np.ndarray     # block-local slot of each uniq entry
    rows: np.ndarray      # physical page row of each uniq entry
    p_loc: int
    n_shards: int
    page_rows: int


def plan_chunk_static(cids, n_shards: int = 1, *, index: int = -1) -> PagePlan:
    """Assign every unique client in ``cids [K, C]`` a page slot.

    Pure function of (cids, n_shards) — the engine's chunk-size
    calibration builds throwaway plans through it without touching any
    store or pager state.  A client sampled in several rounds of the
    chunk keeps ONE slot (the scan's cross-round EF match logic relies
    on cid identity); distinct clients get distinct slots (within-round
    uniqueness is what the scatter relies on).  Sharded, a client's slot
    lives on shard ``cid % n_shards`` — stable across chunks, so the
    cross-chunk device patch never crosses a shard boundary.
    """
    cids = np.asarray(cids)
    k, c = cids.shape
    p_loc = k * c
    flat = cids.reshape(-1)
    uniq = np.unique(flat)
    if n_shards == 1:
        slots = np.arange(len(uniq), dtype=np.int64)
        v = slots
        rows = slots
        page_rows = p_loc
    else:
        owner = uniq % n_shards
        slots = np.empty(len(uniq), np.int64)
        v = np.empty(len(uniq), np.int64)
        rows = np.empty(len(uniq), np.int64)
        for s in range(n_shards):
            idx = np.nonzero(owner == s)[0]
            slots[idx] = np.arange(len(idx))
            v[idx] = s * p_loc + slots[idx]
            rows[idx] = s * (p_loc + 1) + slots[idx]
        page_rows = (p_loc + 1) * n_shards
    # uniq is sorted, so searchsorted maps every sampled cid to its entry
    vcids = v[np.searchsorted(uniq, flat)].reshape(k, c).astype(np.int32)
    return PagePlan(index=index, cids=cids, vcids=vcids, uniq=uniq,
                    slots=slots, rows=rows, p_loc=p_loc, n_shards=n_shards,
                    page_rows=page_rows)


def _patch_map(prev: PagePlan, cur: PagePlan):
    """use/src arrays patching ``cur``'s page from ``prev``'s output page.

    ``use [page_rows]`` marks rows whose client was updated by the
    previous chunk; ``src`` holds that client's BLOCK-LOCAL slot in the
    previous page (owner shards are chunk-stable, so source and
    destination live in the same shard block).
    """
    use = np.zeros(cur.page_rows, bool)
    src = np.zeros(cur.page_rows, np.int32)
    prev_slot = dict(zip(prev.uniq.tolist(), prev.slots.tolist()))
    for cid, row in zip(cur.uniq.tolist(), cur.rows.tolist()):
        j = prev_slot.get(cid)
        if j is not None:
            use[row] = True
            src[row] = j
    return use, src


class EFPager:
    """Prefetch-ahead staging + async write-back of cohort EF pages.

    Overlap protocol (chunk index j, all indices in dispatch order):

    * ``stage(j)`` — prefetch thread — waits until write-backs through
      chunk j-2 completed (a :class:`WritebackLane` completion counter),
      then gathers chunk j's rows from the store into a zeroed host
      page.  Rows updated by chunk j-1 may be stale or torn here; every
      one of them is in the patch set below, so the staleness window is
      exactly the rows the device overwrites anyway.
    * ``patch(j)`` — dispatch thread — jitted per-row select: rows of
      the staged page whose client trained in chunk j-1 are replaced
      from chunk j-1's OUTPUT page (still on device; never donated), the
      rest keep their staged values.  Runs unconditionally (chunk 0
      patches against zeros), so the superstep always donates a
      jit-output buffer, not a host-staged one.
    * ``complete(j)`` — dispatch thread — records chunk j's output page
      as the next patch source and submits the write-back (one
      ``jax.device_get`` of the page + ``store.update`` of the used
      slots) to the lane.  The worker's device_get blocks until the
      chunk's compute finishes — off the dispatch thread, which is the
      point.

    ``close()`` wakes any stage waiter (which aborts with a
    RuntimeError, surfaced through the prefetcher's error path) and
    drains pending write-backs, so a final ``flush`` + checkpoint after
    close still sees a consistent store.
    """

    def __init__(self, store: HostEFStore, *, mesh=None, impl: str = "auto",
                 runlog=None):
        from repro.obs.runlog import as_runlog
        self._store = store
        self._mesh = mesh
        self._impl = impl
        self._rl = as_runlog(runlog)
        self._shard = None
        self._ef_sh = None
        if mesh is not None:
            from repro.engine.sharded import client_sharding
            from repro.launch.sharding import ef_table_sharding
            self._shard = client_sharding(mesh)
            self._ef_sh = ef_table_sharding(mesh)
        self.n_shards = self._shard.n_shards if self._shard is not None else 1
        self._lane = WritebackLane(name="engine-ef-writeback", runlog=runlog)
        self._patch_cache: Dict = {}
        self._prev = None          # (PagePlan, device output page)
        self._stage_count = 0
        self.patched_rows = 0
        self.page_rows_max = 0

    @property
    def store(self) -> HostEFStore:
        return self._store

    @property
    def stall_s(self) -> float:
        return self._lane.stall_s

    # -- staging (prefetch thread) -------------------------------------
    def zero_page(self, plan: PagePlan, *, pool=None) -> List[np.ndarray]:
        """Zeroed host page leaf buffers for ``plan`` (pool-reusable)."""
        bufs = []
        for li, (s, d) in enumerate(zip(self._store._shapes,
                                        self._store._dtypes)):
            shape = (plan.page_rows,) + s
            buf = (pool.take(f"ef_page/{li}", shape, d) if pool is not None
                   else np.empty(shape, d))
            buf[...] = 0
            bufs.append(buf)
        return bufs

    def stage(self, cids, *, pool=None):
        """Build chunk ``cids``'s (plan, host page tree); orders itself
        after the write-backs it depends on (see class docstring)."""
        index = self._stage_count
        self._stage_count += 1
        if index >= 2 and not self._lane.wait_done(index - 1):
            raise RuntimeError(
                "EF pager closed while staging chunk "
                f"{index} (run shutting down)")
        with self._rl.span("ef.page.gather", chunk=index,
                           rows=int(np.asarray(cids).size)):
            plan = plan_chunk_static(cids, self.n_shards, index=index)
            bufs = self.zero_page(plan, pool=pool)
            self._store.gather(plan.uniq, bufs, plan.rows)
        self.page_rows_max = max(self.page_rows_max, plan.page_rows)
        page = jax.tree_util.tree_unflatten(self._store._treedef, bufs)
        return plan, page

    # -- device patch (dispatch thread) --------------------------------
    def _patch_fn(self, cur_rows: int, prev_rows: int):
        key = (cur_rows, prev_rows)
        fn = self._patch_cache.get(key)
        if fn is None:
            impl = self._impl

            def body(prev, staged, use, src):
                def one(p, s):
                    m = use.reshape((-1,) + (1,) * (s.ndim - 1))
                    return jnp.where(m, ops.ef_gather(p, src, impl=impl), s)
                return jax.tree.map(one, prev, staged)

            if self._shard is not None:
                from repro.engine.sharded import _unchecked_shard_map
                ax = self._shard.axis_name
                body = _unchecked_shard_map(
                    body, self._mesh, in_specs=(P(ax), P(ax), P(ax), P(ax)),
                    out_specs=P(ax))
            # donate only the staged page: prev is the previous chunk's
            # output, still being read by its in-flight write-back.  On
            # CPU the staged arrays alias host memory and XLA would
            # refuse (warning per dispatch) — there the patch is a pure
            # launder into a donation-safe jit-output buffer.
            donate = (1,) if jax.default_backend() != "cpu" else ()
            fn = jax.jit(body, donate_argnums=donate)
            self._patch_cache[key] = fn
        return fn

    def _put_rows(self, x):
        """Stage a per-page-row host array (row-sharded on a mesh)."""
        if self._ef_sh is not None:
            return jax.device_put(x, self._ef_sh)
        return jnp.asarray(x)

    def patch(self, plan: PagePlan, staged_page):
        """The device page the superstep consumes: staged rows, with the
        previous chunk's fresh updates selected in (see class docstring)."""
        leaves = jax.tree_util.tree_leaves(staged_page)
        cur_rows = leaves[0].shape[0]
        if self._prev is None:
            prev_page = jax.tree.map(jnp.zeros_like, staged_page)
            prev_rows = cur_rows
            use = np.zeros(cur_rows, bool)
            src = np.zeros(cur_rows, np.int32)
        else:
            prev_plan, prev_page = self._prev
            prev_rows = jax.tree_util.tree_leaves(prev_page)[0].shape[0]
            use, src = _patch_map(prev_plan, plan)
        self.patched_rows += int(use.sum())
        return self._patch_fn(cur_rows, prev_rows)(
            prev_page, staged_page, self._put_rows(use), self._put_rows(src))

    # -- write-back (dispatch thread submits, lane worker runs) --------
    def complete(self, plan: PagePlan, out_page) -> None:
        """Record chunk ``plan``'s output page and write its rows back."""
        self._prev = (plan, out_page)
        store, rl = self._store, self._rl

        def writeback():
            with rl.span("ef.page.writeback", chunk=plan.index,
                         rows=len(plan.uniq)):
                host = [np.asarray(x) for x in
                        jax.device_get(jax.tree_util.tree_leaves(out_page))]
                store.update(plan.uniq, host, plan.rows)

        self._lane.submit(writeback)

    def flush(self) -> None:
        """Wait until every submitted write-back landed in the store."""
        self._lane.flush()

    def close(self) -> None:
        self._lane.close()
