"""Device-resident federated training engine (the server loop, replaced).

``run_federated_engine`` drives training as a sequence of jitted K-round
supersteps instead of one Python-dispatched round at a time:

* chunk schedule — the round range is cut at eval / checkpoint boundaries
  (host-visible state is only needed there) and otherwise into
  ``superstep_rounds``-sized chunks; when evaluation happens every round
  it is folded into the scan so the chunk size survives;
* buffers — ``global_state`` (and for compressed runs the full-federation
  EF tree + broadcast mirror) are donated into every superstep call, so
  steady-state chunks mutate device buffers in place;
* host pipeline — a prefetch thread stages the next chunk's client sample,
  batches and lr slice to device while the current chunk trains
  (``HostPrefetcher``), and metrics come back through ``MetricsPump``
  futures, so the host blocks only at eval/checkpoint boundaries and at
  the end of the run;
* equivalence — the rng streams (data sampling on the host, per-round
  ``fold_in`` on device) and the per-round math are exactly those of the
  preserved reference loop (``repro.fl.server.run_federated_reference``);
  at chunk size 1 the final model is bitwise-identical to it.

Semantics (checkpoint/resume layout, CommLog history, callback contract)
match the reference loop; a non-None ``callback`` forces one-round chunks
since it observes per-round state by contract.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import make_codec
from repro.configs.base import FLConfig
from repro.core.rounds import init_global_state
from repro.engine.evaljit import make_eval_fn, pad_eval_batch
from repro.engine.metrics import MetricsPump
from repro.engine.pipeline import HostPrefetcher
from repro.engine.superstep import (make_compressed_superstep,
                                    make_plain_superstep)
from repro.models.registry import ModelBundle
from repro.optim import exp_decay_per_round

# repro.fl.comm is imported lazily inside run_federated_engine:
# repro.fl.server imports this module, so the reverse edge would cycle.

_NON_METRIC_KEYS = frozenset(
    ("round", "bytes_up", "bytes_down", "bytes_up_ideal", "cum_bytes_up"))


@dataclass
class ServerResult:
    global_state: Dict
    comm: "repro.fl.comm.CommLog"  # noqa: F821 — lazy import, see above


def chunk_schedule(start: int, rounds: int, chunk: int, *,
                   eval_every: Optional[int] = None,
                   ckpt_every: Optional[int] = None,
                   per_round: bool = False) -> List[Tuple[int, int]]:
    """Cut [start, rounds) into superstep chunks.

    Boundaries land exactly where the host must observe state: after round
    r when ``(r+1) % eval_every == 0`` (eval) or ``(r+1) % ckpt_every == 0``
    (checkpoint).  ``per_round=True`` (callback users) degenerates to
    one-round chunks.  Pass ``eval_every=None`` when evaluation is folded
    into the scan body — eval then imposes no boundary at all.
    """
    bounds = []
    r = start
    while r < rounds:
        if per_round:
            end = r + 1
        else:
            end = min(r + max(1, chunk), rounds)
            for every in (eval_every, ckpt_every):
                if every:
                    end = min(end, (r // every + 1) * every)
        bounds.append((r, end))
        r = end
    return bounds


def run_federated_engine(bundle: ModelBundle, fl: FLConfig, data, *,
                         rounds: int, seed: int = 0,
                         mode: str = "client_parallel",
                         eval_every: int = 1, eval_examples: int = 2048,
                         verbose: bool = False,
                         checkpoint_dir: Optional[str] = None,
                         checkpoint_every: int = 10,
                         callback: Optional[Callable] = None,
                         superstep_rounds: int = 8, prefetch: bool = True,
                         impl: str = "auto") -> ServerResult:
    """Engine-backed server loop (see module docstring).

    Drop-in for the reference loop: same arguments, same ServerResult,
    same checkpoint layout and resume behaviour, plus ``superstep_rounds``
    (max rounds per jitted chunk), ``prefetch`` (background host staging)
    and ``impl`` (kernel dispatch for the EF gather/scatter and codecs).
    """
    from repro.checkpoint.io import (load_tree, restore_server_state,
                                     save_server_state, save_tree)
    from repro.fl.comm import CommLog

    key = jax.random.PRNGKey(seed)
    global_state = init_global_state(bundle, fl, key)
    start_round = 0
    if checkpoint_dir and os.path.exists(
            os.path.join(checkpoint_dir, "meta.json")):
        global_state, start_round = restore_server_state(checkpoint_dir,
                                                         global_state)
        global_state = jax.tree.map(jnp.asarray, global_state)
    lr_at = exp_decay_per_round(fl.lr, fl.lr_decay)
    comm = CommLog().bind_sizes(global_state)
    n_sampled = min(fl.clients_per_round, data.n_clients)

    # --- wire codecs: device-resident EF + mirror --------------------------
    compressed = fl.compressed
    wire_up = wire_down = None
    ef_all = down_mirror = round_key = None
    uplink = downlink = None
    ef_path = None
    if compressed:
        uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac,
                            quant_bits=fl.quant_bits, impl=impl)
        downlink = make_codec(fl.downlink_codec, topk_frac=fl.topk_frac,
                              quant_bits=fl.quant_bits, impl=impl)
        uplink.bind(global_state["model"])
        downlink.bind(global_state["model"])
        wire_up = uplink.wire_bytes()
        wire_down = downlink.wire_bytes()
        ef_template = uplink.init_state()
        ef_all = jax.tree.map(
            lambda z: jnp.zeros((data.n_clients,) + z.shape, z.dtype),
            ef_template)
        # a copy, not an alias: the model and the mirror are both donated
        # into the superstep, and a shared buffer cannot be donated twice.
        down_mirror = jax.tree.map(jnp.array, global_state["model"])
        ef_path = (os.path.join(checkpoint_dir, "ef.npz")
                   if checkpoint_dir else None)
        if start_round and ef_path and os.path.exists(ef_path):
            ef_all, down_mirror = jax.tree.map(
                jnp.asarray, load_tree(ef_path, (ef_all, down_mirror)))
        round_key = jax.random.fold_in(key, 0x636f6d70)  # "comp"

    # --- fixed-shape evaluation -------------------------------------------
    test_batch, test_mask = pad_eval_batch(data.test_batch(), eval_examples)
    eval_fn = make_eval_fn(bundle, fl)
    eval_in_scan = eval_every == 1 and callback is None
    jit_eval = None if eval_in_scan else jax.jit(eval_fn)

    # --- chunk schedule + prefetch pipeline -------------------------------
    schedule = chunk_schedule(
        start_round, rounds, superstep_rounds,
        eval_every=None if eval_in_scan else eval_every,
        ckpt_every=checkpoint_every if checkpoint_dir else None,
        per_round=callback is not None)

    def build_chunk(r0, r1):
        cids, batches, sizes = data.round_chunk(
            r1 - r0, fl.clients_per_round, fl.local_steps, fl.local_batch)
        staged = {
            "batches": {k: jax.device_put(v) for k, v in batches.items()},
            "sizes": jax.device_put(sizes),
            # one vectorized schedule op, not K scalar dispatches — the
            # elementwise pow gives the same float32 values as the
            # reference loop's per-round lr_at(r)
            "lrs": lr_at(jnp.arange(r0, r1)),
        }
        if compressed:   # only the compressed superstep consumes these
            staged["cids"] = jax.device_put(cids)
            staged["ridx"] = jax.device_put(
                np.arange(r0, r1, dtype=np.int32))
        return staged

    prefetcher = HostPrefetcher(build_chunk, schedule, enabled=prefetch)

    # --- jitted supersteps, cached per chunk length -----------------------
    steps: Dict[int, Callable] = {}

    def get_step(n_rounds):
        if n_rounds not in steps:
            in_scan = eval_fn if eval_in_scan else None
            if compressed:
                fn = make_compressed_superstep(
                    bundle, fl, mode, n_rounds, uplink, downlink,
                    eval_fn=in_scan, impl=impl)
                steps[n_rounds] = jax.jit(fn, donate_argnums=(0, 1, 2))
            else:
                fn = make_plain_superstep(bundle, fl, mode, n_rounds,
                                          eval_fn=in_scan, impl=impl)
                steps[n_rounds] = jax.jit(fn, donate_argnums=(0,))
        return steps[n_rounds]

    pump = MetricsPump(comm, n_sampled, wire_up=wire_up,
                       wire_down=wire_down,
                       n_down=(data.n_clients
                               if fl.downlink_codec != "identity" else None),
                       verbose=verbose)
    test_args = (test_batch, test_mask) if eval_in_scan else ()

    try:
        for r0, r1, staged in prefetcher:
            step = get_step(r1 - r0)
            if compressed:
                global_state, mstack, ef_all, down_mirror = step(
                    global_state, ef_all, down_mirror, staged["batches"],
                    staged["sizes"], staged["lrs"], staged["cids"],
                    staged["ridx"], round_key, *test_args)
            else:
                global_state, mstack = step(
                    global_state, staged["batches"], staged["sizes"],
                    staged["lrs"], *test_args)
            eval_metrics = None
            if jit_eval is not None and eval_every and r1 % eval_every == 0:
                eval_metrics = jit_eval(global_state, test_batch, test_mask)
            pump.submit(mstack, eval_metrics)
            if callback is not None:        # per-round chunks by contract
                pump.drain()
                metrics = {k: v for k, v in comm.history[-1].items()
                           if k not in _NON_METRIC_KEYS}
                callback(r0, global_state, metrics)
            if checkpoint_dir and r1 % checkpoint_every == 0:
                save_server_state(checkpoint_dir, global_state, r1,
                                  extra={"algorithm": fl.algorithm})
                if compressed:
                    save_tree(ef_path, (ef_all, down_mirror))
    finally:
        prefetcher.close()
        pump.close()

    if checkpoint_dir:
        save_server_state(checkpoint_dir, global_state, rounds,
                          extra={"algorithm": fl.algorithm})
        if compressed:
            save_tree(ef_path, (ef_all, down_mirror))
    return ServerResult(global_state=global_state, comm=comm)
