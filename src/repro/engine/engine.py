"""Device-resident federated training engine (the server loop, replaced).

``run_federated_engine`` drives training as a sequence of jitted K-round
supersteps instead of one Python-dispatched round at a time:

* chunk schedule — the round range is cut at eval / checkpoint boundaries
  (host-visible state is only needed there) and otherwise into
  ``superstep_rounds``-sized chunks; when evaluation happens every round
  it is folded into the scan so the chunk size survives;
  ``superstep_rounds="auto"`` picks the chunk size from measured dispatch
  overhead (see :func:`_auto_chunk_rounds`);
* buffers — ``global_state`` (and for compressed runs the full-federation
  EF tree + broadcast mirror) are donated into every superstep call, and
  so are the staged chunk arrays (batches/sizes/lrs/cids), so steady-state
  chunks mutate device buffers in place and staging never leaks buffers;
* host pipeline — a prefetch thread stages the next chunk's client sample,
  batches and lr slice to device while the current chunk trains
  (``HostPrefetcher``, re-filling a ``StagingPool`` of pinned host
  buffers), and metrics come back through ``MetricsPump`` futures, so the
  host blocks only at checkpoint boundaries and at the end of the run;
* eval overlap — at an eval boundary the evaluator is dispatched on a
  device-side SNAPSHOT of the post-chunk state (``jnp.copy`` under jit),
  taken before that state is donated into the next chunk: chunk r+1
  starts while eval(r) runs, and the ``MetricsPump`` merges the eval
  future into the chunk's last round when it resolves (metrics therefore
  lag the training front by up to one chunk — same contract as every
  other engine metric);
* mesh — with ``mesh`` whose client axes (``pod``/``data``) multiply to
  S > 1, the superstep runs under ``shard_map`` (``repro.engine.sharded``):
  the chunk's client axis is split positionally over the S shards, the
  full-federation EF table is row-sharded by client id in the resident
  scratch-row layout (``[(N_loc+1)*S, ...]``, in-place per-round scatter;
  ``ef.npz`` stays the compact format), the compressed round's traffic is
  ONE packed psum (``fused_collective=True``, the default — EF exchange,
  aggregate and pipelined weight totals ride a single flat buffer;
  ``False`` keeps the bitwise-equal three-collective oracle), and
  evaluation splits the padded test batch over the shards with a
  masked-sum psum (``sharded_eval=True``; ``False`` evaluates
  replicated).  The results are allclose (not bitwise: aggregation order
  changes) to the single-device engine; ``mesh=None`` or S == 1 keeps the
  exact single-device program;
* equivalence — the rng streams (data sampling on the host, per-round
  ``fold_in`` on device) and the per-round math are exactly those of the
  preserved reference loop (``repro.fl.server.run_federated_reference``);
  at chunk size 1 the single-device final model is bitwise-identical to
  it;
* EF store — ``ef_store="device"`` keeps the dense ``[N, n]`` table (the
  bitwise oracle); ``"host"`` swaps in the cohort-paged store
  (``repro.engine.efstore``): only a ``[K*C, n]`` page of the sampled
  cohort's rows ever touches the device, staged one chunk ahead through
  the prefetch pipeline and written back asynchronously at chunk
  boundaries, with a device-side patch closing the one-chunk overlap
  window — device memory for EF becomes O(C·n), independent of the
  federation size, and the paged run stays bitwise-equal to the dense
  one.  ``"auto"`` (default) flips to the host store when the projected
  dense table exceeds ``_EF_STORE_AUTO_BYTES``.  ``ef.npz`` keeps the
  compact ``[N, n]`` format either way, so checkpoints resume across
  store layouts.

Semantics (checkpoint/resume layout, CommLog history, callback contract)
match the reference loop; a non-None ``callback`` forces one-round chunks
since it observes per-round state by contract.
"""
from __future__ import annotations

import contextlib
import copy
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import make_codec
from repro.configs.base import FLConfig
from repro.control import (LadderSpec, ladder_kind, ladder_values,
                           make_controller)
from repro.core.rounds import init_global_state
from repro.engine.efstore import EFPager, HostEFStore, plan_chunk_static
from repro.engine.evaljit import make_eval_fn, pad_eval_batch
from repro.engine.metrics import MetricsPump
from repro.engine.pipeline import HostPrefetcher, StagingPool
from repro.engine.sharded import (client_sharding, chunk_shardings,
                                  ef_table_sharding, eval_batch_sharding,
                                  make_sharded_eval, make_sharded_superstep)
from repro.engine.superstep import (donation_argnums,
                                    make_compressed_superstep,
                                    make_plain_superstep)
from repro.models.registry import ModelBundle
from repro.obs.runlog import as_runlog
from repro.obs.telemetry import Telemetry, make_telemetry
from repro.optim import exp_decay_per_round

# repro.fl.comm is imported lazily inside run_federated_engine:
# repro.fl.server imports this module, so the reverse edge would cycle.

_NON_METRIC_KEYS = frozenset(
    ("round", "bytes_up", "bytes_down", "bytes_up_ideal", "cum_bytes_up"))

# adaptive chunk sizing: pick K so the per-chunk dispatch overhead is at
# most this fraction of the chunk's device time, within [lo, hi]
_AUTO_TARGET_OVERHEAD = 0.05
_AUTO_BOUNDS = (8, 256)

# ef_store="auto": keep the dense device table while the projected
# [n_clients, n] EF footprint stays under this, page past it (1 GiB — a
# dense table that size is already >10% of small-accelerator HBM, while
# the paged path's per-chunk page is K*C rows regardless of N)
_EF_STORE_AUTO_BYTES = 1 << 30

# donate-safety without a host mirror: the broadcast mirror starts as a
# device-side COPY of the staged model (both are donated into the
# superstep; a shared buffer cannot be donated twice).  jnp.copy under
# jit preserves the input's sharding, and no host-side np.asarray
# duplicate of the model is retained for the lifetime of the run.
_device_copy = jax.jit(lambda t: jax.tree.map(jnp.copy, t))


@dataclass
class ServerResult:
    global_state: Dict
    comm: "repro.fl.comm.CommLog"  # noqa: F821 — lazy import, see above
    stats: Optional[Dict] = field(default=None, compare=False)


def chunk_schedule(start: int, rounds: int, chunk: int, *,
                   eval_every: Optional[int] = None,
                   ckpt_every: Optional[int] = None,
                   per_round: bool = False) -> List[Tuple[int, int]]:
    """Cut [start, rounds) into superstep chunks.

    Boundaries land exactly where the host must observe state: after round
    r when ``(r+1) % eval_every == 0`` (eval) or ``(r+1) % ckpt_every == 0``
    (checkpoint).  ``per_round=True`` (callback users) degenerates to
    one-round chunks.  Pass ``eval_every=None`` when evaluation is folded
    into the scan body — eval then imposes no boundary at all.
    """
    bounds = []
    r = start
    while r < rounds:
        if per_round:
            end = r + 1
        else:
            end = min(r + max(1, chunk), rounds)
            for every in (eval_every, ckpt_every):
                if every:
                    end = min(end, (r // every + 1) * every)
        bounds.append((r, end))
        r = end
    return bounds


def _calibration_source(data, seed: int):
    """A shallow clone of ``data`` with an independent rng stream.

    Adaptive chunk sizing times real supersteps on real-shaped chunks —
    but drawing those from ``data`` itself would advance the sampling rng
    and break bit-equivalence with the reference loop.  The clone shares
    the (read-only) client arrays and replaces only the stream.
    """
    clone = copy.copy(data)
    clone._rng = np.random.default_rng(seed ^ 0xCA11B)
    return clone


def _auto_chunk_rounds(get_step, build_calib, run_step, *,
                       target=_AUTO_TARGET_OVERHEAD, bounds=_AUTO_BOUNDS):
    """Pick the superstep chunk size from measured dispatch overhead.

    Times a compiled 1-round and an 8-round chunk on throwaway state
    (donation-safe: every measurement rebuilds its arguments).  With
    ``t_K ≈ overhead + K * per_round``, the two lengths identify both
    terms, and K is chosen so overhead amortizes below ``target`` of the
    chunk's device time.  The returned K is a throughput knob only —
    results are chunk-size-invariant (pinned by tests/test_engine.py).
    """
    def timed(n_rounds):
        step = get_step(n_rounds)
        jax.block_until_ready(run_step(step, build_calib(n_rounds)))
        t0 = time.perf_counter()
        jax.block_until_ready(run_step(step, build_calib(n_rounds)))
        return time.perf_counter() - t0

    t1, t8 = timed(1), timed(8)
    per_round = max((t8 - t1) / 7.0, 1e-7)
    overhead = max(t1 - per_round, 0.0)
    lo, hi = bounds
    return int(np.clip(round(overhead / (per_round * target)), lo, hi))


def run_federated_engine(bundle: ModelBundle, fl: FLConfig, data, *,
                         rounds: int, seed: int = 0,
                         mode: str = "client_parallel",
                         eval_every: int = 1, eval_examples: int = 2048,
                         verbose: bool = False,
                         checkpoint_dir: Optional[str] = None,
                         checkpoint_every: int = 10,
                         callback: Optional[Callable] = None,
                         superstep_rounds=8, prefetch: bool = True,
                         impl: str = "auto", mesh=None,
                         overlap_eval: bool = True,
                         fused_collective: bool = True,
                         sharded_eval: bool = True,
                         ef_store: str = "auto",
                         telemetry=False, runlog=None,
                         halt_on_nonfinite: bool = False,
                         profile_dir: Optional[str] = None) -> ServerResult:
    """Engine-backed server loop (see module docstring).

    Drop-in for the reference loop: same arguments, same ServerResult,
    same checkpoint layout and resume behaviour, plus ``superstep_rounds``
    (max rounds per jitted chunk, or ``"auto"`` to calibrate),
    ``prefetch`` (background host staging), ``impl`` (kernel dispatch for
    the EF gather/scatter and codecs), ``mesh`` (client-parallel
    ``shard_map`` execution when its pod/data axes multiply past 1),
    ``overlap_eval`` (snapshot-based eval dispatch; False reproduces the
    pre-overlap behaviour of evaluating the to-be-donated state),
    ``fused_collective`` (mesh only: ONE packed psum per round instead of
    the three-collective layout — bitwise-equal, False keeps the oracle),
    ``sharded_eval`` (mesh only: split the eval batch over the client
    shards with a masked-sum psum; False evaluates replicated) and
    ``ef_store`` (compressed only: ``"device"`` dense ``[N, n]`` EF
    table — the bitwise oracle; ``"host"`` the cohort-paged
    ``repro.engine.efstore`` store, O(C·n) device memory at any
    federation size, bitwise-equal to dense; ``"auto"`` pages once the
    projected dense table passes ``_EF_STORE_AUTO_BYTES``).

    Observability (``repro.obs``, all off by default):

    * ``telemetry`` — True (every applicable registered tap), a sequence
      of tap names, or a prebuilt :class:`repro.obs.telemetry.Telemetry`:
      on-device tap signals (``tele/...`` keys) ride the existing metrics
      stack and the round's existing psum — zero extra collectives, zero
      extra host syncs, and the trained model stays bitwise-equal to a
      telemetry-off run;
    * ``runlog`` — None | JSONL path | :class:`repro.obs.runlog.RunLog`:
      host span tracing (chunk dispatch, eval dispatch, prefetch staging,
      checkpoint saves) plus counters and non-finite-metric warnings; a
      path given here is opened, streamed and closed by the engine;
    * ``profile_dir`` — start a ``jax.profiler`` trace into the directory
      for the whole run, with one ``StepTraceAnnotation`` per chunk.

    Robustness (both off by default — the defaults keep every traced code
    path byte-identical to the pre-robustness engine):

    * partial participation — ``fl.participation`` names a policy from
      ``repro.fl.participation`` (``full_sync`` / ``deadline`` /
      ``buffered_async``) and ``data`` may carry a
      :class:`repro.data.federated.ChaosConfig`.  When either deviates
      from the default, the engine samples the policy's (possibly
      over-provisioned) cohort, folds the host-decided mask / staleness
      weight / work fraction into the staged example weights (so dropped
      or late clients are zeroed INSIDE the existing one-psum — no shape
      changes, no extra collectives), carries masked clients' EF state
      forward untouched, and accounts per-round ``sim_time`` plus the
      partial uplink (``n_up``) in the CommLog;
    * ``halt_on_nonfinite`` — drain metrics at every chunk boundary and,
      on the first non-finite metric value, checkpoint the current state
      (if ``checkpoint_dir`` is set) and stop cleanly instead of training
      onward on garbage; ``stats["halted_at"]`` records the boundary.
    """
    from repro.checkpoint.io import (ef_disk_layout, insert_scratch_rows,
                                     load_tree, restore_server_state,
                                     save_server_state, save_tree)
    from repro.fl.comm import CommLog
    from repro.fl.participation import make_policy

    shard = client_sharding(mesh) if mesh is not None else None
    n_sampled = min(fl.clients_per_round, data.n_clients)

    # --- participation: who lands in each round, at what weight ------------
    # part_active=False (full_sync policy, no chaos) takes the exact
    # pre-participation code path everywhere: no extra round_chunk outputs,
    # no pmask/pstale superstep args, byte-identical traced programs.
    policy = make_policy(fl.participation)
    part_active = (getattr(data, "chaos", None) is not None
                   or policy.name != "full_sync")
    c_round = policy.cohort_size(n_sampled, fl) if part_active else n_sampled
    select_fn = None
    if part_active:
        def select_fn(draws):
            if draws is None:     # chaos off: everyone reports at t=1.0
                arrival = np.ones(c_round, np.float32)
                dropped = np.zeros(c_round, bool)
            else:
                arrival, dropped = draws.arrival, draws.dropped
            return policy.select(arrival, dropped, fl, n_sampled)

    if ef_store not in ("auto", "device", "host"):
        raise ValueError(f"ef_store={ef_store!r} not in "
                         "('auto', 'device', 'host')")
    if shard is not None:
        if c_round % shard.n_shards:
            raise ValueError(
                f"round cohort {c_round} (clients_per_round={n_sampled}, "
                f"policy {policy.name!r}) must divide over the mesh's "
                f"{shard.n_shards} client shards {shard.axes}")
        shard_batch, shard_repl = chunk_shardings(mesh)

    def _stage(x, sharded_like=False):
        if shard is None:
            return jax.device_put(x)
        return jax.device_put(x, shard_batch if sharded_like else shard_repl)

    key = jax.random.PRNGKey(seed)
    global_state = init_global_state(bundle, fl, key)
    start_round = 0
    if checkpoint_dir and os.path.exists(
            os.path.join(checkpoint_dir, "meta.json")):
        global_state, start_round = restore_server_state(checkpoint_dir,
                                                         global_state)
        # replay the consumed sampling stream (and, with chaos on, the
        # fault-schedule draws) so resumed rounds draw the exact
        # clients/batches/faults an uninterrupted run would have
        data.skip_round_sampling(start_round, c_round,
                                 fl.local_steps, fl.local_batch)
    global_state = jax.tree.map(lambda x: _stage(jnp.asarray(x)),
                                global_state)
    lr_at = exp_decay_per_round(fl.lr, fl.lr_decay)
    comm = CommLog().bind_sizes(global_state)

    # host span tracing opens early: the EF pager threads its staging /
    # write-back spans through the same sink.  A path here means the
    # engine owns the sink's lifetime (stream + close).
    owns_runlog = runlog is not None and not hasattr(runlog, "span")
    rl = as_runlog(runlog)

    # --- wire codecs: EF store (dense device table | cohort-paged) + mirror
    compressed = fl.compressed
    # adaptive compression controller (repro.control): "static" is the
    # bitwise oracle — controller stays None, no ladder is bound, no ctrl
    # state enters any carry, and every traced program is byte-identical
    # to the pre-controller engine.
    ctrl_active = compressed and fl.controller != "static"
    controller = ctrl_spec = ctrl_state = None
    wire_up = wire_down = None
    ef_all = down_mirror = round_key = None
    uplink = downlink = None
    ef_path = None
    ef_paged = False
    pager = None
    if compressed:
        uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac,
                            quant_bits=fl.quant_bits, impl=impl)
        downlink = make_codec(fl.downlink_codec, topk_frac=fl.topk_frac,
                              quant_bits=fl.quant_bits, impl=impl)
        uplink.bind(global_state["model"])
        downlink.bind(global_state["model"])
        wire_up = uplink.wire_bytes()
        wire_down = downlink.wire_bytes()
        if ctrl_active:
            # bind the ladder at the codec's capacity (= the configured
            # static level, enforced by ladder_values); the traced level
            # scalar masks the payload down to the effective rung
            ladder = ladder_values(fl)
            uplink.set_ladder(ladder)
            ctrl_spec = LadderSpec(kind=ladder_kind(fl.uplink_codec),
                                   values=ladder,
                                   bytes_up=uplink.level_bytes())
            controller = make_controller(fl.controller).setup(ctrl_spec, fl)
        ef_template = uplink.init_state()
        store = HostEFStore(ef_template)
        if store.n_leaves == 0:
            ef_paged = False   # stateless uplink (e.g. int8): nothing to page
        elif ef_store == "auto":
            ef_paged = (data.n_clients * store.row_nbytes()
                        > _EF_STORE_AUTO_BYTES)
        else:
            ef_paged = ef_store == "host"
        if shard is not None and not ef_paged \
                and data.n_clients % shard.n_shards:
            raise ValueError(
                f"n_clients={data.n_clients} must divide over the mesh's "
                f"{shard.n_shards} client shards (row-sharded EF table); "
                "ef_store='host' lifts the constraint")
        ef_path = (os.path.join(checkpoint_dir, "ef.npz")
                   if checkpoint_dir else None)
        resume_ef = bool(start_round and ef_path
                         and os.path.exists(ef_path))
        if shard is not None:
            ef_sh = ef_table_sharding(mesh)
        if ef_paged:
            pager = EFPager(store, mesh=mesh, impl=impl, runlog=rl)
            if resume_ef:
                # ef.npz is always the compact [n_clients, ...] layout;
                # the store keeps only the non-zero rows of it
                ef_dense = jax.tree.map(
                    lambda z: np.zeros((data.n_clients,) + z.shape,
                                       np.dtype(z.dtype)), ef_template)
                ef_dense, down_host = load_tree(
                    ef_path, (ef_dense, global_state["model"]))
                store.from_dense(ef_dense)
                down_mirror = jax.tree.map(
                    lambda z: _stage(jnp.asarray(z)), down_host)
            else:
                down_mirror = _device_copy(global_state["model"])
        else:
            ef_all = jax.tree.map(
                lambda z: np.zeros((data.n_clients,) + z.shape,
                                   np.dtype(z.dtype)), ef_template)
            if resume_ef:
                # ef.npz is always the compact [n_clients, ...] layout
                ef_all, down_host = load_tree(
                    ef_path, (ef_all, global_state["model"]))
                down_mirror = jax.tree.map(
                    lambda z: _stage(jnp.asarray(z)), down_host)
            else:
                down_mirror = _device_copy(global_state["model"])
            if shard is not None:
                # resident scratch-row layout: one permanent write-sink
                # row per shard block, so the per-round scatter is in place
                ef_all = insert_scratch_rows(ef_all, shard.n_shards)
            ef_all = jax.tree.map(
                lambda z: (jax.device_put(z, ef_sh) if shard is not None
                           else jnp.asarray(z)), ef_all)
        round_key = jax.random.fold_in(key, 0x636f6d70)  # "comp"

    # --- observability: telemetry taps + host span tracing ----------------
    # tele=None keeps every traced code path byte-identical to the
    # pre-observability engine (the bitwise contract tests/test_obs.py pins)
    tele = None
    if telemetry or ctrl_active:
        if isinstance(telemetry, Telemetry):
            tele = telemetry
        else:
            # a controller's decision signals ride telemetry: force its
            # required taps (plus the schedule-exporting "controller" tap)
            # into the selection even when the user left telemetry off
            tap_names = (None if telemetry is True
                         else tuple(telemetry) if telemetry else ())
            if ctrl_active and tap_names is not None:
                tap_names = tuple(dict.fromkeys(
                    tap_names + tuple(controller.requires_taps)
                    + ("controller",)))
            tele = make_telemetry(
                "compressed" if compressed else "plain",
                n_clients=c_round,
                n_shards=shard.n_shards if shard is not None else 1,
                available=frozenset(
                    (("ef",) if compressed and uplink.stateful else ())
                    + (("pmask", "staleness") if part_active else ())
                    + (("level", "eff_bytes") if ctrl_active else ())),
                taps=tap_names)
        if ctrl_active:
            have = {t.name for t in tele.taps} if tele is not None else set()
            missing = [n for n in controller.requires_taps
                       if n not in have]
            if missing:
                raise ValueError(
                    f"controller {fl.controller!r} needs telemetry taps "
                    f"{missing}, unavailable for uplink codec "
                    f"{fl.uplink_codec!r} (e.g. the 'ef' tap needs a "
                    "stateful error-feedback uplink)")

    # controller state: staged replicated scalars; ctrl.npz sits next to
    # ef.npz so interrupt+resume replays the schedule bitwise
    ctrl_path = (os.path.join(checkpoint_dir, "ctrl.npz")
                 if checkpoint_dir else None)
    if ctrl_active:
        ctrl_host = jax.tree.map(np.asarray, controller.init_state())
        if start_round and ctrl_path and os.path.exists(ctrl_path):
            ctrl_host = load_tree(ctrl_path, ctrl_host)
        ctrl_state = jax.tree.map(lambda x: _stage(jnp.asarray(x)),
                                  ctrl_host)

    def save_ef():
        """ef.npz keeps the compact [n_clients, ...] layout, whatever the
        live backing (dense, sharded-resident, or paged store)."""
        if ef_paged:
            pager.flush()   # every submitted write-back is in the store
            ef_src = store
        else:
            ef_src = ef_all
        ef_disk = ef_disk_layout(
            ef_src, n_shards=shard.n_shards if shard is not None else 1,
            n_clients=data.n_clients)
        save_tree(ef_path, (ef_disk, down_mirror), runlog=rl)
        if ctrl_active:
            save_tree(ctrl_path, ctrl_state, runlog=rl)

    # --- fixed-shape evaluation -------------------------------------------
    # on a mesh the eval batch splits positionally over the client shards
    # and the masked metric sums cross one psum (S× less eval compute per
    # device — the paper's workload evaluates every round);
    # sharded_eval=False keeps the replicated-evaluator oracle.
    eval_shard = shard if (shard is not None and sharded_eval) else None
    test_batch = test_mask = None
    eval_fn = jit_eval = snap = None
    eval_in_scan = False
    if eval_every:
        test_batch, test_mask = pad_eval_batch(
            data.test_batch(), eval_examples,
            sharding=(eval_batch_sharding(mesh) if eval_shard is not None
                      else shard_repl if shard is not None else None),
            shard=eval_shard)
        eval_fn = make_eval_fn(bundle, fl, shard=eval_shard)
        eval_in_scan = eval_every == 1 and callback is None
        if not eval_in_scan:
            jit_eval = jax.jit(make_sharded_eval(eval_fn, mesh)
                               if eval_shard is not None else eval_fn)
        # eval overlap: the evaluator reads a device-side copy, never the
        # buffers the next chunk is about to consume by donation
        snap = (jax.jit(lambda t: jax.tree.map(jnp.copy, t))
                if (jit_eval is not None and overlap_eval) else None)

    # --- chunk staging -----------------------------------------------------
    # pinned-buffer reuse is an accelerator optimization: there device_put
    # is a real host->device DMA and block_until_ready fences it.  The CPU
    # backend may alias or lazily read the numpy buffer past that fence
    # (the "device" IS the host), so reuse would corrupt staged chunks —
    # CPU stages into fresh arrays, exactly the pre-pool behaviour.
    pool = StagingPool() if jax.default_backend() != "cpu" else None

    def build_chunk(r0, r1, src=None, staging_pool=None):
        out = (src or data).round_chunk(
            r1 - r0, c_round, fl.local_steps, fl.local_batch,
            pool=staging_pool, participation=select_fn)
        if select_fn is not None:
            cids, batches, sizes, part = out
            # the whole participation outcome is weight-borne: dropped /
            # late clients are zeroed (mask), staleness-discounted
            # (weight) and truncation-scaled (work) HERE, on the host, so
            # the staged example weights drive the unchanged normalized
            # weighted mean — the fused one-psum never learns masking
            # exists.  pmask/pstale only reach the round fns for EF
            # preservation, the masked loss lanes and telemetry.
            sizes = sizes * part["mask"] * part["weight"] * part["work"]
        else:
            cids, batches, sizes, part = out + (None,)
        staged = {
            "batches": {k: _stage(v, sharded_like=True)
                        for k, v in batches.items()},
            "sizes": _stage(sizes, sharded_like=True),
            # one vectorized schedule op, not K scalar dispatches — the
            # elementwise pow gives the same float32 values as the
            # reference loop's per-round lr_at(r)
            "lrs": lr_at(jnp.arange(r0, r1)),
        }
        if compressed:   # only the compressed superstep consumes these
            if ef_paged:
                # the superstep addresses EF rows by VIRTUAL cid — a slot
                # in the chunk's [K*C, ...] page.  Real training chunks
                # gather the page from the store (ordered after the
                # write-backs they depend on); calibration chunks get a
                # throwaway zero page and never touch store or pager.
                if src is None:
                    plan, page = pager.stage(cids, pool=staging_pool)
                else:
                    plan = plan_chunk_static(
                        cids, shard.n_shards if shard is not None else 1)
                    page = jax.tree_util.tree_unflatten(
                        store._treedef, pager.zero_page(plan))
                staged["cids"] = _stage(plan.vcids)
                staged["ef_page"] = jax.tree.map(
                    lambda z: (jax.device_put(z, ef_sh)
                               if shard is not None else jnp.asarray(z)),
                    page)
                staged["ef_plan"] = plan
            else:
                staged["cids"] = _stage(cids)
            staged["ridx"] = _stage(np.arange(r0, r1, dtype=np.int32))
        if part is not None:
            staged["pmask"] = _stage(part["mask"], sharded_like=True)
            staged["pstale"] = _stage(part["staleness"], sharded_like=True)
            # host-only accounting: simulated round wall-clock and the
            # partial uplink count ride the MetricsPump alongside the
            # device fetch — no device round-trip involved
            staged["host"] = {
                "metrics": {"sim_time": part["round_time"],
                            "arrived": part["n_arrived"].astype(np.float32)},
                "n_up": part["n_arrived"],
            }
        if staging_pool is not None:
            # free the pool's host buffers for the next chunk: the wait
            # lands on the PREFETCH thread, never the dispatch thread
            # (ef_plan is host metadata, not an array)
            jax.block_until_ready(
                {k: v for k, v in staged.items() if k != "ef_plan"})
        return staged

    # --- jitted supersteps, cached per chunk length -----------------------
    steps: Dict[int, Callable] = {}

    def get_step(n_rounds):
        if n_rounds not in steps:
            in_scan = eval_fn if eval_in_scan else None
            if shard is not None:
                fn = make_sharded_superstep(
                    bundle, fl, mode, n_rounds, mesh, uplink=uplink,
                    downlink=downlink, eval_fn=in_scan, impl=impl,
                    fused_collective=fused_collective,
                    eval_sharded=eval_shard is not None, telemetry=tele,
                    participation=part_active, controller=controller)
            elif compressed:
                fn = make_compressed_superstep(
                    bundle, fl, mode, n_rounds, uplink, downlink,
                    eval_fn=in_scan, impl=impl, telemetry=tele,
                    participation=part_active, controller=controller)
            else:
                fn = make_plain_superstep(bundle, fl, mode, n_rounds,
                                          eval_fn=in_scan, impl=impl,
                                          telemetry=tele,
                                          participation=part_active)
            # donate the carried state AND the staged chunk — batches /
            # sizes / lrs (/cids/ridx/pmask/pstale) are consumed exactly
            # once.  The host-staged arrays are only donatable on
            # accelerator backends (on CPU their buffers alias host numpy
            # memory and XLA refuses, warning on every dispatch); the lr
            # slice is device-native and always donates.
            donate = donation_argnums(
                compressed=compressed, participation=part_active,
                controller=ctrl_active,
                host_staged=jax.default_backend() != "cpu")
            steps[n_rounds] = jax.jit(fn, donate_argnums=donate)
        return steps[n_rounds]

    test_args = (test_batch, test_mask) if eval_in_scan else ()

    def run_step(step, staged, state=None, ef=None, mirror=None, ctrl=None):
        """Dispatch one superstep on (state, staged); None -> throwaway
        zero trees (calibration — the real carries must not be donated)."""
        state = jax.tree.map(jnp.zeros_like, global_state) \
            if state is None else state
        part_args = ((staged["pmask"], staged["pstale"])
                     if part_active else ())
        if compressed:
            if ef is None:   # device-native zeros: donation-safe anywhere
                ef = jax.tree.map(jnp.zeros_like,
                                  staged["ef_page"] if ef_paged else ef_all)
            ctrl_args = ()
            if ctrl_active:
                ctrl_args = (jax.tree.map(jnp.zeros_like, ctrl_state)
                             if ctrl is None else ctrl,)
            mirror = jax.tree.map(jnp.zeros_like, down_mirror) \
                if mirror is None else mirror
            return step(state, ef, mirror, staged["batches"],
                        staged["sizes"], staged["lrs"], staged["cids"],
                        staged["ridx"], round_key, *part_args, *ctrl_args,
                        *test_args)
        return step(state, staged["batches"], staged["sizes"],
                    staged["lrs"], *part_args, *test_args)

    # --- chunk size: fixed or calibrated ----------------------------------
    chunk_rounds = superstep_rounds
    if superstep_rounds == "auto":
        calib = _calibration_source(data, seed)
        chunk_rounds = _auto_chunk_rounds(
            get_step, lambda n: build_chunk(0, n, src=calib), run_step)
        if verbose:
            print(f"engine: auto chunk size -> {chunk_rounds} rounds")

    # --- schedule + prefetch pipeline -------------------------------------
    schedule = chunk_schedule(
        start_round, rounds, chunk_rounds,
        eval_every=None if eval_in_scan else eval_every,
        ckpt_every=checkpoint_every if checkpoint_dir else None,
        per_round=callback is not None)

    prefetcher = HostPrefetcher(
        lambda r0, r1: build_chunk(r0, r1, staging_pool=pool),
        schedule, enabled=prefetch, runlog=rl)

    ctrl_schedule = None
    if ctrl_active:
        # per-round CommLog accounting: the level metric indexes these
        # host-side tables, so effective bytes replace the capacity
        # wire_up in every round record (schema v2, repro.fl.comm)
        eff_key = ("eff_topk_frac" if ctrl_spec.kind == "topk_frac"
                   else "eff_quant_bits")
        ctrl_schedule = {
            "bytes": [float(b) for b in ctrl_spec.bytes_up],
            "effective": [
                {"level": i,
                 eff_key: (float(v) if ctrl_spec.kind == "topk_frac"
                           else int(v))}
                for i, v in enumerate(ctrl_spec.values)],
        }
    pump = MetricsPump(comm, c_round, wire_up=wire_up,
                       wire_down=wire_down,
                       n_down=(data.n_clients
                               if fl.downlink_codec != "identity" else None),
                       verbose=verbose, runlog=rl,
                       schedule=ctrl_schedule)

    def step_annotation(i):
        """jax.profiler chunk marker; a no-op without --profile."""
        if profile_dir and hasattr(jax.profiler, "StepTraceAnnotation"):
            return jax.profiler.StepTraceAnnotation("superstep", step_num=i)
        return contextlib.nullcontext()

    rl.event("run.start", rounds=rounds, start_round=start_round,
             chunk_rounds=chunk_rounds, compressed=compressed,
             client_shards=shard.n_shards if shard is not None else 1,
             telemetry=tele is not None,
             participation=policy.name if part_active else None,
             controller=fl.controller if ctrl_active else None,
             ef_store=("host" if ef_paged else "device") if compressed
                      else None)
    if profile_dir:
        jax.profiler.start_trace(profile_dir)
    halted_at = None
    try:
        # the pump context drains into the CommLog on a clean exit and
        # ABORTS (cancel + non-blocking shutdown) when unwinding an
        # exception — a mid-run error no longer leaks the worker thread
        with pump:
            for ci, (r0, r1, staged) in enumerate(prefetcher):
                with step_annotation(ci):
                    with rl.span("chunk.dispatch", r0=r0, r1=r1,
                                 compile=(r1 - r0) not in steps):
                        step = get_step(r1 - r0)
                        if compressed and ef_paged:
                            # device patch closes the one-chunk write-back
                            # window, then the page rides the superstep in
                            # ef_all's place; the output page goes back to
                            # the store off-thread
                            ef_page = pager.patch(staged["ef_plan"],
                                                  staged["ef_page"])
                            out = run_step(step, staged, global_state,
                                           ef_page, down_mirror, ctrl_state)
                            if ctrl_active:
                                (global_state, mstack, ef_out, down_mirror,
                                 ctrl_state) = out
                            else:
                                (global_state, mstack, ef_out,
                                 down_mirror) = out
                            pager.complete(staged["ef_plan"], ef_out)
                        elif compressed:
                            out = run_step(step, staged, global_state,
                                           ef_all, down_mirror, ctrl_state)
                            if ctrl_active:
                                (global_state, mstack, ef_all, down_mirror,
                                 ctrl_state) = out
                            else:
                                (global_state, mstack, ef_all,
                                 down_mirror) = out
                        else:
                            global_state, mstack = run_step(step, staged,
                                                            global_state)
                    eval_metrics = None
                    if jit_eval is not None and eval_every \
                            and r1 % eval_every == 0:
                        with rl.span("eval.dispatch", round=r1,
                                     overlap=snap is not None):
                            eval_state = snap(global_state) \
                                if snap is not None else global_state
                            eval_metrics = jit_eval(eval_state, test_batch,
                                                    test_mask)
                pump.submit(mstack, eval_metrics,
                            host=staged.get("host"))
                if callback is not None:    # per-round chunks by contract
                    pump.drain()
                    metrics = {k: v for k, v in comm.history[-1].items()
                               if k not in _NON_METRIC_KEYS}
                    callback(r0, global_state, metrics)
                if halt_on_nonfinite:
                    # the drain costs the metrics overlap — that is the
                    # documented price of the option (off by default)
                    pump.drain()
                    if pump.nonfinite_round is not None:
                        rl.event("run.halt", reason="metrics.nonfinite",
                                 round=pump.nonfinite_round, boundary=r1)
                        if checkpoint_dir:
                            with rl.span("checkpoint.save", round=r1,
                                         halt=True):
                                save_server_state(
                                    checkpoint_dir, global_state, r1,
                                    extra={"algorithm": fl.algorithm,
                                           "halted": True}, runlog=rl)
                                if compressed:
                                    save_ef()
                        halted_at = r1
                        break
                if checkpoint_dir and r1 % checkpoint_every == 0:
                    with rl.span("checkpoint.save", round=r1):
                        save_server_state(checkpoint_dir, global_state, r1,
                                          extra={"algorithm": fl.algorithm},
                                          runlog=rl)
                        if compressed:
                            save_ef()
    finally:
        if pager is not None:
            # wakes a prefetch thread blocked in pager.stage (it aborts
            # through the prefetcher's error path) and drains pending
            # write-backs, so the final save below reads a settled store
            pager.close()
        prefetcher.close()
        if profile_dir:
            jax.profiler.stop_trace()

    if checkpoint_dir and halted_at is None:
        with rl.span("checkpoint.save", round=rounds, final=True):
            save_server_state(checkpoint_dir, global_state, rounds,
                              extra={"algorithm": fl.algorithm},
                              runlog=rl)
            if compressed:
                save_ef()
    stats = {
        "chunk_rounds": chunk_rounds,
        "client_shards": shard.n_shards if shard is not None else 1,
        "fused_collective": bool(shard is not None and fused_collective),
        "sharded_eval": eval_fn is not None and eval_shard is not None,
        "eval_overlap": snap is not None,
        "host_wait_s": round(prefetcher.wait_s, 4),
        "metrics_wait_s": round(pump.wait_s, 4),
        "telemetry": tele is not None,
        "staging_pool_hits": pool.hits if pool is not None else 0,
        "staging_pool_misses": pool.misses if pool is not None else 0,
        "participation": policy.name if part_active else None,
        "round_cohort": c_round,
        "halted_at": halted_at,
        "controller": fl.controller if ctrl_active else None,
        "ladder": list(ctrl_spec.values) if ctrl_active else None,
        "ef_store": ("host" if ef_paged else "device") if compressed
                    else None,
    }
    if ef_paged:
        # O(C·n) headline: peak device bytes the EF pages ever occupied —
        # a function of chunk size and cohort, never of n_clients
        stats["ef_page_bytes"] = pager.page_rows_max * store.row_nbytes()
        stats["ef_store_rows"] = store.n_rows
        stats["ef_stall_s"] = round(pager.stall_s, 4)
        rl.counter("ef.page.hits", store.hits)
        rl.counter("ef.page.misses", store.misses)
        rl.counter("ef.page.writeback_rows", store.writeback_rows)
        rl.counter("ef.page.patched_rows", pager.patched_rows)
        rl.counter("ef.page.stall_s", stats["ef_stall_s"])
    rl.counter("prefetch.wait_s", stats["host_wait_s"])
    rl.counter("metrics.wait_s", stats["metrics_wait_s"])
    if pool is not None:
        rl.counter("staging.pool_hits", pool.hits)
        rl.counter("staging.pool_misses", pool.misses)
    rl.event("run.end", rounds=rounds)
    if owns_runlog:
        rl.close()
    if rl.path:
        stats["runlog"] = rl.path
    return ServerResult(global_state=global_state, comm=comm, stats=stats)
