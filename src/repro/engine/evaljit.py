"""Jit-able global-model evaluation with fixed (pad-and-mask) shapes.

The pre-engine evaluator ran ``bundle.apply`` uncompiled on the raw test
batch every ``eval_every`` rounds — op-by-op Python dispatch on what the
paper plots every single round (Fig. 4-7 are accuracy-per-round curves).
Here the metrics are a traceable function of ``(global_state, batch,
mask)`` so they can be jitted standalone, or folded straight into the
superstep's ``lax.scan`` body when evaluation happens every round.

Shapes are stabilised by padding the test batch to a power-of-two bucket
(capped at ``max_examples``) with a per-example validity mask: one
compiled evaluator serves any test-set size, and the masked means are
numerically identical to the unpadded ones (pad rows carry zero weight,
the divisor is the true example count).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import masked_accuracy, masked_cross_entropy


def make_eval_fn(bundle, fl):
    """Traceable ``eval_metrics(global_state, batch, mask) -> {acc, loss}``.

    Deployment-time logits come from the algorithm plugin's
    ``deploy_logits`` hook — for FedFusion the deployed global model
    fuses its own features with itself through the aggregated fusion
    module (E_g = E_l = global), exactly as the pre-engine evaluator did.
    """
    from repro.fl.api import make_algorithm   # lazy: fl sits above engine
    algo = make_algorithm(fl.algorithm)

    def eval_metrics(global_state, batch, mask) -> Dict:
        out = bundle.apply(global_state["model"], batch)
        logits = algo.deploy_logits(bundle, fl, global_state, out)
        labels = bundle.labels(batch)
        return {"acc": masked_accuracy(logits, labels, mask),
                "loss": masked_cross_entropy(logits, labels, mask)}

    return eval_metrics


def pad_eval_batch(batch, max_examples: int = 2048,
                   sharding=None) -> Tuple[Dict, jnp.ndarray]:
    """Truncate to ``max_examples``, zero-pad to a power-of-two bucket.

    Returns (padded device batch, [bucket] bool mask).  Bucketing keeps the
    compiled-shape count logarithmic in the test-set sizes seen by one
    process while never evaluating more than ~2x the requested examples.

    ``sharding`` (a ``NamedSharding``) places the padded batch and mask
    explicitly — the sharded engine passes its replicated sharding so the
    eval arguments are laid out once at staging time instead of being
    re-replicated by GSPMD on the first eval dispatch.
    """
    key = "x" if "x" in batch else "tokens"
    n = min(len(batch[key]), max_examples)
    bucket = 1
    while bucket < n:
        bucket *= 2
    bucket = min(bucket, max_examples)

    def put(v):
        return jnp.asarray(v) if sharding is None else \
            jax.device_put(v, sharding)

    padded = {}
    for k, v in batch.items():
        v = np.asarray(v[:n])
        if bucket > n:
            v = np.pad(v, ((0, bucket - n),) + ((0, 0),) * (v.ndim - 1))
        padded[k] = put(v)
    mask = put(np.arange(bucket) < n)
    return padded, mask
