"""Jit-able global-model evaluation with fixed (pad-and-mask) shapes.

The pre-engine evaluator ran ``bundle.apply`` uncompiled on the raw test
batch every ``eval_every`` rounds — op-by-op Python dispatch on what the
paper plots every single round (Fig. 4-7 are accuracy-per-round curves).
Here the metrics are a traceable function of ``(global_state, batch,
mask)`` so they can be jitted standalone, or folded straight into the
superstep's ``lax.scan`` body when evaluation happens every round.

Shapes are stabilised by padding the test batch to a power-of-two bucket
(capped at ``max_examples``) with a per-example validity mask: one
compiled evaluator serves any test-set size, and the masked means are
numerically identical to the unpadded ones (pad rows carry zero weight,
the divisor is the true example count).

Sharded evaluation (``make_eval_fn(shard=)`` + ``pad_eval_batch(shard=)``)
splits the padded batch POSITIONALLY over the mesh's client axes: each
shard forwards only ``bucket / S`` examples and reduces masked metric
*sums* (``repro.core.losses``), one psum adds the numerators and the true
example count, and the quotient equals the replicated masked mean — pad
rows carry zero weight on every shard and the divisor psums to the true
example count, so eval-every-round costs S× less compute per device at
the price of one tiny (3-scalar) collective.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import ClientSharding, fused_psum
from repro.core.losses import (masked_accuracy, masked_accuracy_sum,
                               masked_cross_entropy,
                               masked_cross_entropy_sum)


def make_eval_fn(bundle, fl, shard: Optional[ClientSharding] = None):
    """Traceable ``eval_metrics(global_state, batch, mask) -> {acc, loss}``.

    Deployment-time logits come from the algorithm plugin's
    ``deploy_logits`` hook — for FedFusion the deployed global model
    fuses its own features with itself through the aggregated fusion
    module (E_g = E_l = global), exactly as the pre-engine evaluator did.

    With ``shard`` the function is a ``shard_map`` body over the client
    axes: ``batch``/``mask`` carry this shard's positional slice of the
    padded eval batch (stage with ``pad_eval_batch(shard=...)`` so the
    bucket divides), the masked sums cross shards through one psum, and
    the returned metrics are replicated-identical on every shard.
    """
    from repro.fl.api import make_algorithm   # lazy: fl sits above engine
    algo = make_algorithm(fl.algorithm)

    def eval_metrics(global_state, batch, mask) -> Dict:
        out = bundle.apply(global_state["model"], batch)
        logits = algo.deploy_logits(bundle, fl, global_state, out)
        labels = bundle.labels(batch)
        if shard is None:
            return {"acc": masked_accuracy(logits, labels, mask),
                    "loss": masked_cross_entropy(logits, labels, mask)}
        correct, w = masked_accuracy_sum(logits, labels, mask)
        ce, _ = masked_cross_entropy_sum(logits, labels, mask)
        sums = fused_psum({"correct": correct, "ce": ce, "w": w}, shard)
        denom = jnp.maximum(sums["w"], 1.0)
        return {"acc": sums["correct"] / denom, "loss": sums["ce"] / denom}

    return eval_metrics


def pad_eval_batch(batch, max_examples: int = 2048, sharding=None,
                   shard: Optional[int] = None) -> Tuple[Dict, jnp.ndarray]:
    """Truncate to ``max_examples``, zero-pad to a power-of-two bucket.

    Returns (padded device batch, [bucket] bool mask).  Bucketing keeps the
    compiled-shape count logarithmic in the test-set sizes seen by one
    process while never evaluating more than ~2x the requested examples.

    ``sharding`` (a ``NamedSharding``) places the padded batch and mask
    explicitly — the sharded engine passes its layout so the eval
    arguments land once at staging time instead of being re-laid-out by
    GSPMD on the first eval dispatch.

    ``shard`` (an int shard count or a ``ClientSharding``) rounds the
    bucket up so it divides evenly over the mesh's client shards — the
    positional split sharded evaluation needs; the extra rows are masked
    pad like any other.

    An empty test batch is rejected: zero valid examples make every
    masked metric an arbitrary 0/… sentinel, and silently streaming that
    into the paper's accuracy-per-round curves would be a bug, not a
    number.
    """
    key = "x" if "x" in batch else "tokens"
    n = min(len(batch[key]), max_examples)
    if n == 0:
        raise ValueError(
            "pad_eval_batch: the evaluation batch has 0 examples — masked "
            "metrics would be undefined; supply a non-empty test set or "
            "disable evaluation (eval_every=0)")
    bucket = 1
    while bucket < n:
        bucket *= 2
    bucket = min(bucket, max_examples)
    if shard is not None:
        n_shards = getattr(shard, "n_shards", shard)
        bucket = -(-bucket // n_shards) * n_shards

    def put(v):
        return jnp.asarray(v) if sharding is None else \
            jax.device_put(v, sharding)

    padded = {}
    for k, v in batch.items():
        v = np.asarray(v[:n])
        if bucket > n:
            v = np.pad(v, ((0, bucket - n),) + ((0, 0),) * (v.ndim - 1))
        padded[k] = put(v)
    mask = put(np.arange(bucket) < n)
    return padded, mask
