"""Deferred metrics: device->host fetches ride a worker thread.

The old loop ran ``float(v)`` on every round's metrics — a host sync that
stalled the dispatch pipeline once per round.  The engine instead hands
each chunk's stacked ``[K]`` metrics (and the chunk-end eval metrics, if
any) to a single-worker executor: ``jax.device_get`` blocks *that* thread
until the superstep producing the values has finished, while the main
thread keeps dispatching the next chunk.  ``CommLog`` rounds are logged in
order when futures are drained — bounded by ``max_pending`` chunks so a
long run cannot pile up unfetched device buffers.

``MetricsPump`` is a context manager: a clean exit drains every pending
chunk into the CommLog, an exceptional one ABORTS — pending futures are
cancelled and the executor is shut down without blocking the raising
thread — so a mid-run error never leaks the worker thread or queued
device buffers (the engine enters the pump around its dispatch loop).

A ``repro.obs.runlog`` sink (optional) receives a structured warning
event for every non-finite metric value as rounds land in the history —
the value still enters ``CommLog.history`` untouched (history equality
with the reference loop is a pinned contract), but the divergence is now
visible with its round index instead of silently riding the curves.
"""
from __future__ import annotations

import math
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import jax
import numpy as np

from repro.obs.runlog import as_runlog

# NOTE: nothing in repro.engine imports repro.fl at module scope —
# repro.fl.server imports the engine, and the reverse edge would cycle.
# (repro.obs sits below everything and imports no repro package.)


class MetricsPump:
    """Feed per-round metrics into a ``repro.fl.comm.CommLog`` without
    blocking.

    ``comm`` must have wire sizes bound (``comm.bind_sizes``) — the pump
    logs with ``global_state=None``.  ``wire_up`` / ``wire_down`` /
    ``n_down`` are the per-run constants the server loop previously passed
    to every ``log_round`` call.  ``runlog`` (None | RunLog) receives
    non-finite metric warnings.
    """

    def __init__(self, comm, n_clients: int, *,
                 wire_up: Optional[int] = None,
                 wire_down: Optional[int] = None,
                 n_down: Optional[int] = None,
                 verbose: bool = False, max_pending: int = 4,
                 runlog=None, schedule: Optional[dict] = None):
        self._comm = comm
        self._n_clients = n_clients
        self._wire = dict(wire_up=wire_up, wire_down=wire_down,
                          n_down=n_down)
        # adaptive-compression ladder (repro.control): per-level effective
        # uplink bytes + effective codec fields, indexed by the round's
        # tele/level metric so CommLog charges what a real wire would
        # carry instead of the capacity wire_up
        self._schedule = schedule
        self._verbose = verbose
        self._max_pending = max_pending
        self._runlog = as_runlog(runlog)
        self._pool = ThreadPoolExecutor(1, thread_name_prefix="engine-metrics")
        self._pending: deque = deque()
        self.wait_s = 0.0    # dispatch-thread time blocked on metric sync
        # first round whose metrics contained a non-finite value (1-based),
        # or None — the engine's halt_on_nonfinite option polls this
        self.nonfinite_round: Optional[int] = None

    def __enter__(self) -> "MetricsPump":
        return self

    def __exit__(self, exc_type, exc, tb):
        # clean exit: every queued chunk must land in the CommLog; an
        # exception mid-run: do NOT block the raising thread on device
        # fetches that may never resolve — drop the queue and retire the
        # worker.
        if exc_type is None:
            self.close()
        else:
            self.abort()
        return False

    def submit(self, metrics_stack, eval_metrics=None, host=None):
        """Queue one chunk: ``metrics_stack`` leaves are [K] device arrays;
        ``eval_metrics`` (scalar device dict or None) merges into the
        chunk's LAST round — chunk boundaries are aligned to eval rounds
        by the engine's schedule.

        ``eval_metrics`` may still be executing when submitted (the
        engine's eval-overlap path dispatches it on a snapshot and moves
        straight on to the next chunk); the worker's ``device_get`` is
        what waits for the future, so the merge happens when it resolves
        and the dispatch thread never blocks here unless ``max_pending``
        chunks have piled up (accounted in ``wait_s``).

        ``host`` (optional) carries host-computed per-round values that
        never touched the device: ``host["metrics"]`` maps metric name to
        a [K] array merged into each round, and ``host["n_up"]`` ([K] int)
        overrides the uplink client count per round (partial-participation
        accounting).  Host values need no fetch, so they ride alongside
        the future and merge at log time.
        """
        self._pending.append((self._pool.submit(
            jax.device_get, (metrics_stack, eval_metrics)), host))
        while len(self._pending) > self._max_pending:
            t0 = time.perf_counter()
            fut, h = self._pending.popleft()
            fetched = fut.result()
            self.wait_s += time.perf_counter() - t0
            self._log(fetched, h)

    def drain(self):
        """Resolve every pending chunk into the CommLog (host blocks)."""
        t0 = time.perf_counter()
        while self._pending:
            fut, h = self._pending.popleft()
            self._log(fut.result(), h)
        self.wait_s += time.perf_counter() - t0

    def close(self):
        self.drain()
        self._pool.shutdown(wait=True)

    def abort(self):
        """Exception path: cancel queued fetches and retire the worker
        without draining — never blocks on device state mid-unwind."""
        while self._pending:
            fut, _ = self._pending.popleft()
            fut.cancel()
        self._pool.shutdown(wait=False, cancel_futures=True)

    @staticmethod
    def _scalar(v):
        """Host-ify one metric value; non-scalar leaves (e.g. a per-class
        vector) pass through as numpy instead of crashing ``float()``."""
        try:
            return float(v)
        except (TypeError, ValueError):
            return np.asarray(v)

    @staticmethod
    def _fmt(v):
        """Verbose formatting that tolerates non-float metric values."""
        try:
            return f"{v:.4f}"
        except (TypeError, ValueError):
            return str(v)

    def _log(self, fetched, host=None):
        stack, ev = fetched
        # an empty metrics stack is legal (a round fn with no scalar
        # metrics); eval-only chunks still log their single round
        n_rounds = (len(next(iter(stack.values()))) if stack
                    else (1 if ev is not None else 0))
        host_metrics = host.get("metrics", {}) if host else {}
        n_up = host.get("n_up") if host else None
        for k in range(n_rounds):
            metrics = {key: self._scalar(v[k]) for key, v in stack.items()}
            metrics.update({key: float(v[k])
                            for key, v in host_metrics.items()})
            if ev is not None and k == n_rounds - 1:
                metrics.update({key: self._scalar(v)
                                for key, v in ev.items()})
            bad = [key for key, v in metrics.items()
                   if isinstance(v, float) and not math.isfinite(v)]
            if bad:
                # the value still lands in history (equality with the
                # reference loop is pinned); the event makes it findable
                self._runlog.warning("metrics.nonfinite",
                                     round=self._comm.rounds + 1, keys=bad)
                if self.nonfinite_round is None:
                    self.nonfinite_round = self._comm.rounds + 1
            wire, effective = self._wire, None
            if self._schedule is not None and "tele/level" in metrics:
                lvl = int(round(metrics["tele/level"]))
                lvl = max(0, min(lvl, len(self._schedule["bytes"]) - 1))
                wire = dict(self._wire,
                            wire_up=int(round(self._schedule["bytes"][lvl])))
                effective = self._schedule["effective"][lvl]
            self._comm.log_round(None, self._n_clients, metrics,
                                 n_up=(None if n_up is None
                                       else int(n_up[k])),
                                 effective=effective, **wire)
            if self._verbose:
                print(f"round {self._comm.rounds:4d} " +
                      " ".join(f"{k2}={self._fmt(v2)}"
                               for k2, v2 in metrics.items()))
