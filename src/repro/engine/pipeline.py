"""Double-buffered host pipeline: stage chunk i+1 while chunk i trains.

The old server loop serialized host work (numpy batch assembly, rng draws,
host->device transfer) with device work every round.  ``HostPrefetcher``
moves all of it onto one background thread that walks the chunk schedule
in order — a single thread, because the data rng stream must advance in
exactly the per-round order of the reference loop for sampled clients and
batches to match it bit for bit — and hands staged, device-resident chunks
to the consumer through a bounded queue (default depth 2: one chunk being
consumed, one in flight).

Exceptions raised inside the builder are re-raised at the consuming
``__iter__`` site; ``close()`` unblocks and retires the worker if the
consumer stops early.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Tuple


class HostPrefetcher:
    """Iterate ``(r0, r1, build_chunk(r0, r1))`` over ``schedule``.

    With ``enabled=False`` the chunks are built synchronously on the
    consumer thread (same iteration contract, no overlap) — the debugging
    / fallback path.
    """

    def __init__(self, build_chunk: Callable, schedule: Iterable[Tuple[int,
                 int]], *, depth: int = 2, enabled: bool = True):
        self._build = build_chunk
        self._schedule = list(schedule)
        self._enabled = enabled
        if enabled:
            self._q: queue.Queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._worker, name="engine-prefetch", daemon=True)
            self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that stays responsive to close()."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        try:
            for r0, r1 in self._schedule:
                if self._stop.is_set():
                    return
                if not self._put((r0, r1, self._build(r0, r1))):
                    return
            self._put(None)
        except BaseException as e:  # surfaced at the consumer
            self._put(e)

    def __iter__(self) -> Iterator:
        if not self._enabled:
            for r0, r1 in self._schedule:
                yield r0, r1, self._build(r0, r1)
            return
        while True:
            item = self._q.get()
            if item is None:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    def close(self):
        """Stop the worker and drop any staged chunks (idempotent)."""
        if not self._enabled:
            return
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=1.0)
