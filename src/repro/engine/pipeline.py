"""Double-buffered host pipeline: stage chunk i+1 while chunk i trains.

The old server loop serialized host work (numpy batch assembly, rng draws,
host->device transfer) with device work every round.  ``HostPrefetcher``
moves all of it onto one background thread that walks the chunk schedule
in order — a single thread, because the data rng stream must advance in
exactly the per-round order of the reference loop for sampled clients and
batches to match it bit for bit — and hands staged, device-resident chunks
to the consumer through a bounded queue (default depth 2: one chunk being
consumed, one in flight).

``StagingPool`` keeps the big stacked host arrays a chunk builder fills
(batches/cids/sizes) alive across chunks: steady-state staging re-fills
the same buffers instead of re-allocating tens of MB per chunk, which is
what makes them pinnable on accelerator backends.  The builder must
guarantee the previous transfer out of a buffer has completed before
re-filling it — the engine does so by blocking the PREFETCH thread (never
the dispatch thread) on the staged device arrays before handing the chunk
over.

Exceptions raised inside the builder are re-raised at the consuming
``__iter__`` site; ``close()`` unblocks and retires the worker if the
consumer stops early.  ``wait_s`` accumulates the time the CONSUMER spent
blocked on the queue — the host-side stall the pipeline exists to remove;
the engine surfaces it in ``ServerResult.stats``.

``WritebackLane`` is the pipeline's reverse direction: a single serialized
worker draining device results back to host state (the cohort-paged EF
store writes each chunk's updated rows back through one — see
``repro.engine.efstore``).  A completion counter + condition variable let
producers wait for a PREFIX of the submitted work ("writebacks through
chunk j-2 done") without ever blocking on the device themselves.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterable, Iterator, Tuple

import numpy as np


class StagingPool:
    """Reusable host staging buffers, keyed by name, matched on shape/dtype.

    ``take(name, shape, dtype)`` returns a writable ndarray; the same name
    returns the SAME memory as long as shape/dtype are stable (chunk
    shapes only change at schedule tails).  Callers own the discipline of
    not re-taking a name while its previous contents are still being
    transferred.

    Accelerator backends only: ``jax.device_put`` there is a real
    host->device DMA and ``block_until_ready`` fences it, after which the
    buffer is refillable.  The CPU backend may alias or lazily read the
    numpy buffer PAST that fence (the "device" is the host), so the engine
    disables reuse on CPU — refilling would corrupt in-flight chunks.
    """

    def __init__(self):
        self._bufs: Dict[str, np.ndarray] = {}
        self.hits = 0       # takes served from an existing buffer
        self.misses = 0     # takes that had to allocate

    def take(self, name: str, shape, dtype) -> np.ndarray:
        buf = self._bufs.get(name)
        if buf is None or buf.shape != tuple(shape) \
                or buf.dtype != np.dtype(dtype):
            buf = np.empty(shape, dtype)
            self._bufs[name] = buf
            self.misses += 1
        else:
            self.hits += 1
        return buf


class WritebackLane:
    """Single-worker serialized write-back queue with a completion counter.

    ``submit(fn)`` enqueues a thunk; one daemon worker runs them strictly
    in submission order (the thunks typically ``jax.device_get`` a chunk
    result and fold it into host state, so ordering IS the consistency
    model).  ``wait_done(n)`` blocks the CALLING thread until at least
    ``n`` submitted thunks have completed — the EF pager's staging thread
    uses it to order host gathers after the write-backs they depend on —
    and returns False instead of blocking forever once ``close()`` has
    been called.  ``stall_s`` accumulates producer time spent inside
    ``wait_done``.

    A thunk exception is captured (the worker keeps counting so waiters
    never deadlock) and re-raised at the next ``wait_done``/``flush``;
    ``close()`` drains the remaining queue through the worker before
    joining, so a post-close ``flush`` still sees everything completed.
    """

    def __init__(self, *, name: str = "engine-writeback", runlog=None):
        from repro.obs.runlog import as_runlog
        self._runlog = as_runlog(runlog)
        self._q: queue.Queue = queue.Queue()
        self._cv = threading.Condition()
        self._done = 0
        self._submitted = 0
        self._stop = False
        self._closed = False
        self.error = None
        self.stall_s = 0.0      # producer time blocked in wait_done
        self._thread = threading.Thread(target=self._worker, name=name,
                                        daemon=True)
        self._thread.start()

    @property
    def submitted(self) -> int:
        return self._submitted

    @property
    def done(self) -> int:
        with self._cv:
            return self._done

    def submit(self, fn: Callable) -> None:
        self._submitted += 1
        self._q.put(fn)

    def _worker(self):
        while True:
            fn = self._q.get()
            if fn is None:
                return
            try:
                fn()
            except BaseException as e:   # surfaced at the next wait/flush
                with self._cv:
                    if self.error is None:
                        self.error = e
            finally:
                # count even a failed thunk: waiters must wake either way
                with self._cv:
                    self._done += 1
                    self._cv.notify_all()

    def _raise_error(self):
        if self.error is not None:
            err, self.error = self.error, None
            raise err

    def wait_done(self, n: int) -> bool:
        """Block until ``n`` submitted thunks completed; False if the lane
        was closed first (the shutdown path — callers abort their work)."""
        t0 = time.perf_counter()
        with self._cv:
            while self._done < n and not self._stop:
                self._cv.wait(0.05)
            ok = self._done >= n
        self.stall_s += time.perf_counter() - t0
        self._raise_error()
        return ok

    def flush(self) -> None:
        """Wait for everything submitted so far to complete."""
        self.wait_done(self._submitted)

    def close(self) -> None:
        """Drain the queue through the worker, then retire it (idempotent).

        Pending thunks still RUN (a checkpoint's final flush may follow),
        but ``wait_done`` callers blocked on never-submitted work are woken
        immediately.  Never raises — shutdown runs from ``finally`` blocks;
        a captured error is emitted as a runlog warning instead.
        """
        if self._closed:
            return
        self._closed = True
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._q.put(None)
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():
            self._runlog.warning("writeback.join_timeout")
        if self.error is not None:
            self._runlog.warning("writeback.error", error=repr(self.error))


class HostPrefetcher:
    """Iterate ``(r0, r1, build_chunk(r0, r1))`` over ``schedule``.

    With ``enabled=False`` the chunks are built synchronously on the
    consumer thread (same iteration contract, no overlap) — the debugging
    / fallback path.
    """

    def __init__(self, build_chunk: Callable, schedule: Iterable[Tuple[int,
                 int]], *, depth: int = 2, enabled: bool = True,
                 runlog=None):
        from repro.obs.runlog import as_runlog
        self._build = build_chunk
        self._schedule = list(schedule)
        self._enabled = enabled
        self._runlog = as_runlog(runlog)
        self.wait_s = 0.0       # consumer time blocked on staging
        self.error = None       # builder exception the consumer never saw
        self._closed = False
        if enabled:
            self._q: queue.Queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._worker, name="engine-prefetch", daemon=True)
            self._thread.start()

    def _put(self, item) -> bool:
        """Blocking put that stays responsive to close()."""
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _worker(self):
        try:
            for r0, r1 in self._schedule:
                if self._stop.is_set():
                    return
                # the span runs on THIS thread — RunLog's nesting stacks
                # are thread-local, so staging intervals interleave
                # correctly with the dispatch thread's chunk spans
                with self._runlog.span("prefetch.stage", r0=r0, r1=r1):
                    staged = self._build(r0, r1)
                if not self._put((r0, r1, staged)):
                    return
            self._put(None)
        except BaseException as e:  # surfaced at the consumer
            if not self._put(e):
                # the consumer is already gone (stopped early / closing):
                # the queue put was refused, so park the exception on the
                # prefetcher for close() to surface instead of letting it
                # die silently with this daemon thread
                self.error = e

    def __iter__(self) -> Iterator:
        if not self._enabled:
            for r0, r1 in self._schedule:
                t0 = time.perf_counter()
                with self._runlog.span("prefetch.stage", r0=r0, r1=r1):
                    staged = self._build(r0, r1)
                self.wait_s += time.perf_counter() - t0
                yield r0, r1, staged
            return
        while True:
            t0 = time.perf_counter()
            item = self._q.get()
            self.wait_s += time.perf_counter() - t0
            if item is None:
                return
            if isinstance(item, BaseException):
                raise item
            yield item

    def _drain_queue(self):
        """Drop staged chunks; keep the FIRST builder exception found."""
        try:
            while True:
                item = self._q.get_nowait()
                if isinstance(item, BaseException) and self.error is None:
                    self.error = item
        except queue.Empty:
            pass

    def close(self):
        """Stop the worker and drop any staged chunks (idempotent).

        A builder exception the consumer never iterated far enough to see
        — it stopped early, or the failure raced the shutdown — is
        captured on ``self.error`` and emitted as a structured runlog
        warning rather than dying silently with the daemon thread.
        ``close`` never raises it: the engine closes from a ``finally``
        block, where raising would mask the error already unwinding.
        """
        if not self._enabled or self._closed:
            return
        self._closed = True
        self._stop.set()
        self._drain_queue()             # unblock a worker stuck in _put
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():
            self._runlog.warning("prefetch.join_timeout")
        self._drain_queue()             # anything parked while joining
        if self.error is not None:
            self._runlog.warning("prefetch.error", error=repr(self.error))
