"""Client-parallel ``shard_map`` wrapper for the K-round superstep.

``repro.engine`` runs one jitted superstep per chunk; this module maps
that superstep over the launch mesh so the chunk's client axis — the
embarrassingly-parallel dimension of federated learning — actually runs
in parallel across devices:

* ``batches [K, C, ...]`` / ``sizes [K, C]`` are sharded over the client
  mesh axes (``pod`` then ``data``): shard ``s`` trains sampled positions
  ``[s*C_loc, (s+1)*C_loc)`` of every round in the chunk;
* the full-federation EF table is row-sharded by client id in the
  RESIDENT scratch-row layout (shard ``s`` holds its ``N_loc`` owned rows
  plus one permanent write-sink row — ``repro.launch.sharding.
  ef_table_sharding``), so the per-round scatter is one in-place aliased
  row write instead of a concatenate/slice pair.  Under the cohort-paged
  store (``ef_store="host"``) the same specs carry a chunk-local PAGE
  (``[(K*C+1)*S, ...]`` — per-shard slot blocks with the identical
  scratch row) and ``cids`` carries page-relative virtual ids whose
  shard assignment is ``cid % S``; the superstep body is unchanged;
* global state, broadcast mirror, lr schedule, round keys and ``cids``
  are replicated — every shard computes the identical server-side update
  from the psum'd aggregate, so the replicated outputs agree bitwise
  across shards;
* the eval batch is split positionally over the client axes when the
  evaluator is shard-aware (``eval_sharded=True``, the engine default —
  eval-every-round then costs S× less compute), or replicated for a
  plain evaluator;
* cross-device traffic per round is ONE packed psum with
  ``fused_collective=True`` (the default: FedAvg aggregate + EF exchange
  + pipelined weight totals in a single flat-buffer all-reduce — see
  ``repro.engine.superstep``), or the three-collective oracle layout with
  ``fused_collective=False``.

The mesh's ``model`` axis (if any) is treated as replicated: the engine's
CNN-scale federated workloads are client-bound, and tensor parallelism
inside a client step remains the territory of ``repro.launch.steps``.

Everything here is layout only — the math lives in the shard-aware round
fns (``repro.core.rounds``) and superstep bodies.  A mesh whose client
axes multiply to 1 must NOT go through this wrapper: the engine keeps the
plain superstep there so single-device runs stay bitwise-equal to the
reference loop.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.core.aggregate import ClientSharding
from repro.engine.superstep import (make_compressed_superstep,
                                    make_plain_superstep)
from repro.launch.mesh import client_axes
from repro.launch.sharding import (chunk_shardings,  # noqa: F401 (re-export)
                                   ef_table_sharding, eval_batch_sharding)

if hasattr(jax, "shard_map"):          # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def client_sharding(mesh) -> Optional[ClientSharding]:
    """The mesh's client-axis split, or None when it degenerates to 1."""
    axes = client_axes(mesh)
    sizes = tuple(mesh.shape[a] for a in axes)
    n = 1
    for s in sizes:
        n *= s
    if n <= 1:
        return None
    return ClientSharding(axes=axes, sizes=sizes)


def _unchecked_shard_map(fn, mesh, in_specs, out_specs):
    # check_rep/check_vma off: outputs marked replicated are made identical
    # on every shard by construction (they are functions of replicated
    # inputs and psum results), which the static replication checker
    # cannot see through the scan carry.
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_CHECK_KW: False})


def make_sharded_superstep(bundle, fl, mode, n_rounds, mesh, *,
                           uplink=None, downlink=None, eval_fn=None,
                           impl="auto", fused_collective=True,
                           eval_sharded=True, telemetry=None,
                           participation=False, controller=None,
                           inner_wrap=None):
    """``shard_map``-wrapped superstep on ``mesh`` (client axes size > 1).

    Same call signature as the unsharded supersteps; the plain variant is
    built when ``uplink`` is None, the codec-routed one otherwise.  The
    caller stages batches/sizes with
    :func:`repro.launch.sharding.chunk_shardings` and the EF table with
    :func:`repro.launch.sharding.ef_table_sharding` (resident scratch-row
    layout); jit with the same donations as the unsharded path.

    ``eval_fn`` must match ``eval_sharded``: a shard-aware evaluator
    (``make_eval_fn(shard=client_sharding(mesh))`` fed a batch padded
    with ``pad_eval_batch(shard=...)`` and staged with
    :func:`repro.launch.sharding.eval_batch_sharding`) when True, a
    replicated one when False.

    ``inner_wrap`` is an analyzer hook (``repro.analysis``): a callable
    applied to the superstep BODY before the ``shard_map`` wrap, i.e.
    inside the mesh context but outside jit.  The invariant analyzer's
    mutation tests use it to seed deliberate violations (a second psum,
    an f64 cast, a host callback) and prove the passes catch them; it
    must preserve the superstep signature.  Production callers leave it
    None.
    """
    shard = client_sharding(mesh)
    if shard is None:
        raise ValueError("use the plain superstep on a 1-shard mesh "
                         "(client axes multiply to 1)")
    ax = shard.axis_name
    test_spec = P(ax) if eval_sharded else P()
    n_test = 2 if eval_fn is not None else 0
    # pmask/pstale [K, C] split over the client axes, exactly like sizes
    part_specs = (P(None, ax), P(None, ax)) if participation else ()

    if uplink is None:
        inner = make_plain_superstep(bundle, fl, mode, n_rounds,
                                     eval_fn=eval_fn, impl=impl,
                                     shard=shard, fused=fused_collective,
                                     telemetry=telemetry,
                                     participation=participation)
        in_specs = (P(), P(None, ax), P(None, ax), P()) \
            + part_specs + (test_spec,) * n_test
        out_specs = (P(), P())
    else:
        inner = make_compressed_superstep(bundle, fl, mode, n_rounds,
                                          uplink, downlink, eval_fn=eval_fn,
                                          impl=impl, shard=shard,
                                          fused=fused_collective,
                                          telemetry=telemetry,
                                          participation=participation,
                                          controller=controller)
        # controller state: replicated scalars in, replicated scalars out
        # (the decision is a function of psum'd taps, identical per shard)
        ctrl_specs = (P(),) if controller is not None else ()
        in_specs = (P(), P(ax), P(), P(None, ax), P(None, ax),
                    P(), P(), P(), P()) + part_specs + ctrl_specs \
            + (test_spec,) * n_test
        out_specs = (P(), P(), P(ax), P()) + ctrl_specs

    if inner_wrap is not None:
        inner = inner_wrap(inner)
    return _unchecked_shard_map(inner, mesh, in_specs, out_specs)


def make_sharded_eval(eval_fn, mesh):
    """``shard_map``-wrap a shard-aware evaluator for boundary dispatch.

    ``eval_fn`` is a :func:`repro.engine.make_eval_fn` built with
    ``shard=client_sharding(mesh)``; the state is replicated, the padded
    batch/mask arrive positionally split over the client axes, and the
    psum'd metrics come back replicated.  The caller jits the result.
    """
    shard = client_sharding(mesh)
    if shard is None:
        raise ValueError("sharded eval needs client axes > 1")
    ax = shard.axis_name
    return _unchecked_shard_map(eval_fn, mesh,
                                in_specs=(P(), P(ax), P(ax)),
                                out_specs=P())
