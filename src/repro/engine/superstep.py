"""Jitted K-round supersteps: ``lax.scan`` over the federated round fn.

One superstep call turns K pre-staged rounds entirely on device:

* client sampling arrives as a pre-sampled ``cids [K, C]`` array (drawn on
  the host by the prefetch pipeline with the exact rng stream of the
  one-round-at-a-time loop);
* the lr schedule arrives as a ``lrs [K]`` array;
* the compressed path's full-federation error-feedback tree and broadcast
  mirror ride the scan carry: each round gathers the sampled clients' EF
  rows (``ops.ef_gather``), runs the compressed round fn, and scatters the
  new residuals back with a fused in-place row scatter (``ops.ef_scatter``
  — ``.at[cids].set`` under donation on the jnp path, an aliased Pallas
  kernel on TPU).  The per-round device->host->device NumPy round-trip of
  the old server loop is gone;
* per-round metrics come back stacked ``[K]`` so the host never has to
  block mid-chunk, and when evaluation happens every round (the paper's
  accuracy-per-round curves) the fixed-shape evaluator is folded straight
  into the scan body.

``K == 1`` bypasses ``lax.scan`` and applies the round body to the leading
slice directly, so a chunk-size-1 engine run compiles the same per-round
computation as the reference loop — that is what makes the K=1 final model
bitwise-equal to the pre-engine loop (the equivalence contract
``tests/test_engine.py`` pins down).

Sharded mode (``shard`` = a :class:`repro.core.aggregate.ClientSharding`):
the superstep becomes a ``shard_map`` BODY (see ``repro.engine.sharded``).
Batches/sizes then carry only this shard's positional client slice, the
EF table argument is this shard's row block PLUS ONE RESIDENT SCRATCH ROW
(``[N_loc+1, ...]`` — rows ``[pos*N_loc, (pos+1)*N_loc)`` of the full
federation sharded by client id, row ``N_loc`` a write sink for non-owned
scatter rows), and ``cids`` stays the FULL round sample (replicated —
ownership of an EF row is decided by cid, not by which shard trains the
client).  The scratch row lives in the table layout permanently
(:func:`repro.launch.sharding.ef_table_sharding` allocates it at staging;
``repro.checkpoint.io`` drops it at save and re-appends it on restore),
so the per-round scatter is a single in-place ``ops.ef_scatter`` on the
donated block instead of a concatenate + slice pair copying the whole
block twice per round.  With ``shard=None`` nothing changes.

Collectives (sharded only):

* ``fused=False`` — the three-collective oracle: FedAvg aggregation psum
  inside the round fn, plus one compact ``[C, ...]`` psum exchange per
  direction for the EF rows (``ef_gather_exchange`` /
  ``ef_scatter_exchange``);
* ``fused=True`` (the engine default) — ONE psum per round: the round's
  local contribution sums (delta / extras / loss via
  ``repro.core.rounds.make_*_round_parts``), the EF scatter placement,
  the NEXT round's EF gather contributions and the next round's example
  -count total are packed into one flat buffer and exchanged with a
  single ``psum`` (:func:`repro.core.aggregate.fused_psum`; pack offsets
  are trace-time statics, unpack is static slices).  Quantities a round
  needs BEFORE training — its gathered EF rows and its weight
  total — are pipelined one collective ahead: they ride the previous
  round's psum (a per-chunk prologue psum seeds round 0), which is
  possible because ``cids``/``sizes`` are pre-staged inputs and a
  just-trained row's fresh value is known to the shard that trained it
  before the scatter lands.  Every packed element equals its standalone
  -psum value bitwise, so fused and unfused rounds agree bit for bit.

Cohort-paged EF (``ef_store="host"``, see ``repro.engine.efstore``): the
superstep itself is layout-agnostic — every row access goes through
``cids`` and ``table.shape[0]``.  The engine exploits that by passing a
chunk-local PAGE as ``ef_all`` (``[K*C, ...]`` unsharded, or per-shard
blocks ``[P_loc+1, ...]`` with the same resident scratch row) and
page-relative VIRTUAL ids as ``cids``: the ownership math below
(``n_loc = table.shape[0] - 1``; ``owned = (cids >= lo) & (cids < lo +
n_loc)``) and the cross-round match in ``_ef_gather_next_contrib`` only
require that equal ids mean the same row and distinct ids mean distinct
rows within the chunk — which the paging plan guarantees (one slot per
distinct client per chunk).  Nothing in this module special-cases paging.

The caller jits the returned function; donate ``global_state`` (and for
the compressed path ``ef_all`` + ``mirror``) so steady-state chunks update
those buffers in place instead of reallocating them every call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aggregate import fused_psum
from repro.core.rounds import (init_global_state, make_compressed_round_fn,
                               make_compressed_round_parts, make_round_fn,
                               make_round_parts)
from repro.kernels import ops


def donation_argnums(*, compressed, participation=False, controller=False,
                     host_staged=True):
    """The engine's ``donate_argnums`` for a superstep signature.

    One source of truth shared by ``repro.engine.engine`` (which jits the
    supersteps) and ``repro.analysis`` (whose donation pass verifies the
    donated buffers are actually aliased in the compiled executable):

    * carried device state always donates — ``global_state`` (and for the
      compressed path ``ef_all`` + ``mirror``, plus the controller
      scalars) are consumed exactly once per chunk;
    * the staged chunk arrays (batches / sizes / cids / round_idx and
      the participation mask/staleness) donate only when
      ``host_staged=True`` — on CPU their buffers alias host numpy
      memory and XLA refuses the donation;
    * the lr slice is device-native and always donates.
    """
    if compressed:
        donate = (0, 1, 2, 5) + (
            ((3, 4, 6, 7) + ((9, 10) if participation else ()))
            if host_staged else ())
        if controller:
            donate = donate + ((11,) if participation else (9,))
    else:
        donate = (0, 3) + (
            ((1, 2) + ((4, 5) if participation else ()))
            if host_staged else ())
    return donate


def abstract_superstep_args(bundle, fl, n_rounds, *, cohort, uplink=None,
                            ef_rows=None, participation=False,
                            controller=None, input_shape=None):
    """ShapeDtypeStruct argument tuple matching a superstep's signature.

    The invariant analyzer (``repro.analysis``) and the jaxpr-level tests
    trace supersteps abstractly; this helper is the single place the
    argument layout is spelled out, so signature changes break one
    builder instead of five hand-rolled copies.

    ``cohort`` is the round's client count C (already policy-expanded
    for partial participation); ``ef_rows`` is the leading row count of
    the EF table argument — ``n_clients`` dense unsharded,
    ``(n_loc + 1) * n_shards`` resident sharded, ``K*C`` /
    ``(K*C + 1) * n_shards`` for the cohort-paged layouts — required
    exactly when ``uplink`` is a bound codec.  ``controller`` is a
    set-up :class:`repro.control.Controller` (its ``init_state()``
    shapes the ctrl arg).  ``input_shape`` defaults to the bundle
    config's ``input_shape``.
    """
    K, C = n_rounds, cohort
    S, B = fl.local_steps, fl.local_batch
    if input_shape is None:
        input_shape = tuple(bundle.config.input_shape)
    state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                           jax.random.PRNGKey(0))
    batches = {"x": jax.ShapeDtypeStruct((K, C, S, B) + input_shape,
                                         jnp.float32),
               "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32)}
    sizes = jax.ShapeDtypeStruct((K, C), jnp.float32)
    lrs = jax.ShapeDtypeStruct((K,), jnp.float32)
    part = ((jax.ShapeDtypeStruct((K, C), jnp.float32),
             jax.ShapeDtypeStruct((K, C), jnp.float32))
            if participation else ())
    ctrl = ((jax.eval_shape(controller.init_state),)
            if controller is not None else ())
    if uplink is None:
        return (state, batches, sizes, lrs) + part
    if ef_rows is None:
        raise ValueError("abstract_superstep_args needs ef_rows for a "
                         "compressed superstep (the EF table's leading "
                         "row count)")
    ef = jax.tree.map(
        lambda z: jax.ShapeDtypeStruct((ef_rows,) + z.shape, z.dtype),
        jax.eval_shape(uplink.init_state))
    cids = jax.ShapeDtypeStruct((K, C), jnp.int32)
    ridx = jax.ShapeDtypeStruct((K,), jnp.int32)
    round_key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return (state, ef, state["model"], batches, sizes, lrs, cids, ridx,
            round_key) + part + ctrl


def _stack1(tree):
    """Metrics of a single round -> the [1]-stacked layout scan returns."""
    return jax.tree.map(lambda v: jnp.asarray(v)[None], tree)


def _size_total(n_examples):
    """This shard's term of a round's example-count total — the local half
    of ``normalize_weights`` (psum completes it, one round ahead)."""
    return jnp.sum(jnp.asarray(n_examples, jnp.float32))


def make_plain_superstep(bundle, fl, mode, n_rounds, *, eval_fn=None,
                         impl="auto", shard=None, fused=False,
                         telemetry=None, participation=False):
    """Uncompressed K-round superstep.

    Returns ``superstep(global_state, batches, sizes, lrs[, test_batch,
    test_mask]) -> (new_global_state, metrics stacked [K])`` with leading
    dims ``batches [K, C, steps, B, ...]``, ``sizes [K, C]``, ``lrs [K]``.
    ``eval_fn`` (traceable, from :func:`repro.engine.make_eval_fn`) folds
    per-round evaluation of the post-round state into the scan.  Under
    ``shard`` the batch/size client axis is this shard's slice and
    ``eval_fn`` must match how the test args are laid out (replicated, or
    positionally sharded for a shard-aware evaluator).  ``fused=True``
    (sharded only) runs the round's aggregation as ONE packed psum with
    the weight total pipelined one round ahead (see module docstring).

    ``participation=True`` inserts ``pmask [K, C]`` / ``pstale [K, C]``
    after ``lrs`` (this shard's positional slice under ``shard``, like
    sizes) and scans them through the participation-aware round fn; the
    sizes arriving here are already mask-and-staleness-weighted by the
    engine, so weight plumbing — including the fused pipelined total — is
    untouched.
    """
    if fused:
        if shard is None:
            raise ValueError("fused collectives require a shard "
                             "(fused=True is sharded-only)")
        return _make_fused_plain_superstep(bundle, fl, mode, n_rounds,
                                           eval_fn=eval_fn, impl=impl,
                                           shard=shard, telemetry=telemetry,
                                           participation=participation)
    round_fn = make_round_fn(bundle, fl, mode, impl=impl, shard=shard,
                             telemetry=telemetry,
                             participation=participation)

    def one_round(state, xs, test):
        state, metrics = round_fn(state, *xs)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, metrics

    if participation:
        if n_rounds == 1:
            def superstep(global_state, batches, sizes, lrs, pmask, pstale,
                          *test):
                b0 = jax.tree.map(lambda a: a[0], batches)
                state, m = one_round(
                    global_state,
                    (b0, sizes[0], lrs[0], pmask[0], pstale[0]), test)
                return state, _stack1(m)
            return superstep

        def superstep(global_state, batches, sizes, lrs, pmask, pstale,
                      *test):
            def body(state, xs):
                return one_round(state, xs, test)

            return jax.lax.scan(body, global_state,
                                (batches, sizes, lrs, pmask, pstale))

        return superstep

    if n_rounds == 1:
        def superstep(global_state, batches, sizes, lrs, *test):
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, m = one_round(global_state, (b0, sizes[0], lrs[0]), test)
            return state, _stack1(m)
        return superstep

    def superstep(global_state, batches, sizes, lrs, *test):
        def body(state, xs):
            return one_round(state, xs, test)

        return jax.lax.scan(body, global_state, (batches, sizes, lrs))

    return superstep


def _make_fused_plain_superstep(bundle, fl, mode, n_rounds, *, eval_fn,
                                impl, shard, telemetry=None,
                                participation=False):
    """One-psum-per-round uncompressed superstep (shard_map body)."""
    local_fn, finish_fn = make_round_parts(bundle, fl, mode, impl=impl,
                                           shard=shard, telemetry=telemetry,
                                           participation=participation)

    def one_round(state, total, b, n, lr, n_next, test, pm=None, ps=None):
        if participation:
            contribs = local_fn(state, b, total, n, lr, pm, ps)
        else:
            contribs = local_fn(state, b, total, n, lr)
        summed = fused_psum({"round": contribs,
                             "total": _size_total(n_next)}, shard)
        state, metrics = finish_fn(state, summed["round"])
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, summed["total"], metrics

    if participation:
        def superstep(global_state, batches, sizes, lrs, pmask, pstale,
                      *test):
            total = fused_psum({"total": _size_total(sizes[0])},
                               shard)["total"]
            if n_rounds == 1:
                b0 = jax.tree.map(lambda a: a[0], batches)
                state, _, m = one_round(global_state, total, b0, sizes[0],
                                        lrs[0], sizes[0], test,
                                        pmask[0], pstale[0])
                return state, _stack1(m)
            sizes_next = jnp.roll(sizes, -1, axis=0)

            def body(carry, xs):
                state, total = carry
                b, n, lr, n_next, pm, ps = xs
                state, total, m = one_round(state, total, b, n, lr, n_next,
                                            test, pm, ps)
                return (state, total), m

            (state, _), mstack = jax.lax.scan(
                body, (global_state, total),
                (batches, sizes, lrs, sizes_next, pmask, pstale))
            return state, mstack

        return superstep

    def superstep(global_state, batches, sizes, lrs, *test):
        # prologue: round 0's weight total (later rounds' ride the scan)
        total = fused_psum({"total": _size_total(sizes[0])},
                           shard)["total"]
        if n_rounds == 1:
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, _, m = one_round(global_state, total, b0, sizes[0],
                                    lrs[0], sizes[0], test)
            return state, _stack1(m)
        sizes_next = jnp.roll(sizes, -1, axis=0)

        def body(carry, xs):
            state, total = carry
            b, n, lr, n_next = xs
            state, total, m = one_round(state, total, b, n, lr, n_next,
                                        test)
            return (state, total), m

        (state, _), mstack = jax.lax.scan(
            body, (global_state, total), (batches, sizes, lrs, sizes_next))
        return state, mstack

    return superstep


# ---------------------------------------------------------------------------
# Row-sharded EF exchange (shard_map body helpers)
# ---------------------------------------------------------------------------
# The sharded EF table block is ALWAYS the resident scratch-row layout
# ``[N_loc+1, ...]``: row ``N_loc`` is a permanent write sink, so the
# exchanges below treat ``table.shape[0] - 1`` as the owned-row count.

def _ef_gather_contrib(table, cids, shard, *, impl="auto"):
    """This shard's masked term of the round's [C, ...] gather psum."""
    n_loc = table.shape[0] - 1
    lo = shard.position() * n_loc
    owned = (cids >= lo) & (cids < lo + n_loc)
    local_idx = jnp.clip(cids - lo, 0, n_loc - 1).astype(jnp.int32)
    rows = ops.ef_gather(table, local_idx, impl=impl)
    mask = owned.reshape((-1,) + (1,) * (rows.ndim - 1))
    return jnp.where(mask, rows, jnp.zeros_like(rows))


def ef_gather_exchange(table, cids, shard, *, impl="auto"):
    """Assemble the round's full [C, ...] EF rows from row-sharded blocks.

    ``table`` is this shard's LOCAL row block ``[N_loc+1, ...]`` of the
    federation table (shard ``s`` owns client ids ``[s*N_loc,
    (s+1)*N_loc)``; the trailing scratch row is never read); ``cids [C]``
    is the full round sample (replicated).  Each shard gathers the sampled
    rows it owns — a shard-local ``ops.ef_gather`` with clipped indices —
    masks the rest to zero, and one ``psum`` over the client axes gives
    every shard the complete [C, ...] matrix.  Rows are disjointly owned,
    so the sum is exact.
    """
    return jax.lax.psum(_ef_gather_contrib(table, cids, shard, impl=impl),
                        shard.axis_name)


def _ef_place_positional(new_rows, shard):
    """Place this shard's [C_loc, ...] rows at their positional offset in
    a zero [C, ...] buffer (the scatter exchange's psum operand)."""
    c_loc = new_rows.shape[0]
    full = jnp.zeros((c_loc * shard.n_shards,) + new_rows.shape[1:],
                     new_rows.dtype)
    return jax.lax.dynamic_update_slice_in_dim(
        full, new_rows, (shard.position() * c_loc).astype(jnp.int32),
        axis=0)


def _ef_scatter_local(table, cids, full, shard, *, impl="auto"):
    """Scatter the psum-completed [C, ...] rows this shard owns into its
    resident block, routing non-owned rows to the scratch row (``N_loc``)
    so the in-place ``ops.ef_scatter`` never sees a colliding index — a
    clipped index could alias a genuinely-owned row and ``.at[].set`` with
    duplicate indices keeps an arbitrary write."""
    n_loc = table.shape[0] - 1
    lo = shard.position() * n_loc
    owned = (cids >= lo) & (cids < lo + n_loc)
    safe_idx = jnp.where(owned, cids - lo, n_loc).astype(jnp.int32)
    return ops.ef_scatter(table, safe_idx, full, impl=impl)


def ef_scatter_exchange(table, cids, new_rows, shard, *, impl="auto"):
    """Write this shard's freshly-trained EF rows back to their owners.

    ``new_rows [C_loc, ...]`` are the residuals of this shard's POSITIONAL
    clients; their cids may be owned by any shard.  The rows are placed at
    their positional offset in a zero [C, ...] buffer, one ``psum``
    broadcasts the complete set, and each shard scatters the rows it owns
    into its resident ``[N_loc+1, ...]`` block IN PLACE (under donation) —
    the permanent scratch row absorbs non-owned rows, so no concatenate /
    slice copies the block.
    """
    full = jax.lax.psum(_ef_place_positional(new_rows, shard),
                        shard.axis_name)
    return _ef_scatter_local(table, cids, full, shard, impl=impl)


def _ef_gather_next_contrib(table, cids_prev, cids_next, new_rows, shard,
                            *, impl="auto"):
    """This shard's term of the NEXT round's gather psum, computable
    BEFORE the current round's scatter lands (the fused-path pipelining).

    For next-round position ``j`` with client ``c = cids_next[j]``:

    * ``c`` trained this round on THIS shard -> contribute the fresh row
      straight from ``new_rows`` (the post-scatter table value, known here
      first);
    * ``c`` trained on another shard -> contribute nothing (that shard
      has the fresh row);
    * ``c`` not trained this round -> the owner shard contributes its
      table row, which the pending scatter leaves untouched.

    Within-round cids are unique (``sample_clients`` asserts it), so
    exactly one shard contributes per row and the psum is exact — every
    summed row equals what ``ef_gather_exchange`` on the post-scatter
    table would produce.
    """
    n_loc = table.shape[0] - 1
    pos = shard.position()
    lo = pos * n_loc
    c_loc = new_rows.shape[0]
    prev_local = jax.lax.dynamic_slice_in_dim(
        cids_prev, (pos * c_loc).astype(jnp.int32), c_loc, axis=0)
    match = cids_next[:, None] == prev_local[None, :]        # [C, C_loc]
    trained_here = jnp.any(match, axis=1)
    local_pos = jnp.argmax(match, axis=1).astype(jnp.int32)
    from_train = jnp.take(new_rows, local_pos, axis=0)
    trained_any = jnp.any(cids_next[:, None] == cids_prev[None, :], axis=1)
    owned = (cids_next >= lo) & (cids_next < lo + n_loc)
    local_idx = jnp.clip(cids_next - lo, 0, n_loc - 1).astype(jnp.int32)
    from_table = ops.ef_gather(table, local_idx, impl=impl)
    mt = trained_here.reshape((-1,) + (1,) * (from_train.ndim - 1))
    mo = (owned & ~trained_any).reshape(
        (-1,) + (1,) * (from_table.ndim - 1))
    return jnp.where(mt, from_train,
                     jnp.where(mo, from_table, jnp.zeros_like(from_table)))


def _slice_positional(full_tree, shard, c_loc):
    """This shard's positional [C_loc, ...] block of full [C, ...] rows."""
    start = (shard.position() * c_loc).astype(jnp.int32)
    return jax.tree.map(
        lambda g: jax.lax.dynamic_slice_in_dim(g, start, c_loc, axis=0),
        full_tree)


def make_compressed_superstep(bundle, fl, mode, n_rounds, uplink, downlink,
                              *, eval_fn=None, impl="auto", shard=None,
                              fused=False, telemetry=None,
                              participation=False, controller=None):
    """Compressed (codec-routed) K-round superstep.

    Returns ``superstep(global_state, ef_all, mirror, batches, sizes, lrs,
    cids, round_idx, round_key[, test_batch, test_mask]) ->
    (new_global_state, metrics [K], new_ef_all, new_mirror)``.

    ``ef_all`` holds the FULL federation's per-client uplink EF residuals
    (leaves ``[n_clients, n]``) on device; ``cids [K, C]`` selects each
    round's rows.  ``round_idx [K]`` feeds ``fold_in(round_key, r)`` inside
    the scan, reproducing the reference loop's per-round key derivation
    bit for bit (fold_in is a pure function of the key data and r).

    Under ``shard``, ``ef_all`` is this shard's resident scratch-row block
    ``[N_loc+1, n]`` and the row movement goes through
    :func:`ef_gather_exchange` / :func:`ef_scatter_exchange` (three
    collectives per round) or, with ``fused=True``, one packed psum per
    round (see module docstring); ``cids`` stays the full round sample.

    ``participation=True`` inserts ``pmask [K, C]`` / ``pstale [K, C]``
    after ``round_key`` (this shard's positional slice under ``shard``).
    A masked client's EF row comes back equal to its incoming value (the
    round fn rolls the update back), so the unchanged scatter path writes
    the residual forward untouched.

    ``controller`` (``repro.control``) appends a ``ctrl_state`` argument
    after ``pmask``/``pstale`` (before the test args) and a 5th output:
    the controller's scalar state rides the scan carry exactly like the
    EF table and the mirror, so the level schedule advances across the
    whole chunk — and across chunks, since the engine threads the
    returned state into the next superstep call — without a single host
    round-trip.  With ``controller=None`` every traced code path is
    byte-identical to before this axis existed.
    """
    if fused:
        if shard is None:
            raise ValueError("fused collectives require a shard "
                             "(fused=True is sharded-only)")
        return _make_fused_compressed_superstep(
            bundle, fl, mode, n_rounds, uplink, downlink, eval_fn=eval_fn,
            impl=impl, shard=shard, telemetry=telemetry,
            participation=participation, controller=controller)
    round_fn = make_compressed_round_fn(bundle, fl, mode, uplink, downlink,
                                        impl=impl, shard=shard,
                                        telemetry=telemetry,
                                        participation=participation,
                                        controller=controller)

    def gather_rows(ef_all, cids, c_loc):
        if shard is None:
            return jax.tree.map(
                lambda t: ops.ef_gather(t, cids, impl=impl), ef_all)
        return _slice_positional(
            jax.tree.map(
                lambda t: ef_gather_exchange(t, cids, shard, impl=impl),
                ef_all),
            shard, c_loc)

    def scatter_rows(ef_all, cids, new_ef):
        if shard is None:
            return jax.tree.map(
                lambda t, rows: ops.ef_scatter(t, cids, rows, impl=impl),
                ef_all, new_ef)
        return jax.tree.map(
            lambda t, rows: ef_scatter_exchange(t, cids, rows, shard,
                                                impl=impl),
            ef_all, new_ef)

    def one_round(state, ef_all, mirror, b, n, lr, cids, r, round_key, test,
                  pm=None, ps=None):
        ef_round = gather_rows(ef_all, cids, n.shape[0])
        key_r = jax.random.fold_in(round_key, r)
        if participation:
            state, metrics, new_ef, mirror = round_fn(
                state, b, n, lr, ef_round, mirror, key_r, pm, ps)
        else:
            state, metrics, new_ef, mirror = round_fn(
                state, b, n, lr, ef_round, mirror, key_r)
        ef_all = scatter_rows(ef_all, cids, new_ef)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, ef_all, mirror, metrics

    if controller is not None:
        def one_round_ctrl(state, ef_all, mirror, ctrl, b, n, lr, cids, r,
                           round_key, test, pm=None, ps=None):
            ef_round = gather_rows(ef_all, cids, n.shape[0])
            key_r = jax.random.fold_in(round_key, r)
            if participation:
                state, metrics, new_ef, mirror, ctrl = round_fn(
                    state, b, n, lr, ef_round, mirror, key_r, pm, ps, ctrl)
            else:
                state, metrics, new_ef, mirror, ctrl = round_fn(
                    state, b, n, lr, ef_round, mirror, key_r, ctrl)
            ef_all = scatter_rows(ef_all, cids, new_ef)
            if eval_fn is not None:
                metrics = {**metrics, **eval_fn(state, test[0], test[1])}
            return state, ef_all, mirror, ctrl, metrics

        if participation:
            if n_rounds == 1:
                def superstep(global_state, ef_all, mirror, batches, sizes,
                              lrs, cids, round_idx, round_key, pmask, pstale,
                              ctrl_state, *test):
                    b0 = jax.tree.map(lambda a: a[0], batches)
                    state, ef_all, mirror, ctrl, m = one_round_ctrl(
                        global_state, ef_all, mirror, ctrl_state, b0,
                        sizes[0], lrs[0], cids[0], round_idx[0], round_key,
                        test, pmask[0], pstale[0])
                    return state, _stack1(m), ef_all, mirror, ctrl
                return superstep

            def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                          cids, round_idx, round_key, pmask, pstale,
                          ctrl_state, *test):
                def body(carry, xs):
                    state, ef_all, mirror, ctrl = carry
                    b, n, lr, cid, r, pm, ps = xs
                    state, ef_all, mirror, ctrl, m = one_round_ctrl(
                        state, ef_all, mirror, ctrl, b, n, lr, cid, r,
                        round_key, test, pm, ps)
                    return (state, ef_all, mirror, ctrl), m

                (state, ef_all, mirror, ctrl), mstack = jax.lax.scan(
                    body, (global_state, ef_all, mirror, ctrl_state),
                    (batches, sizes, lrs, cids, round_idx, pmask, pstale))
                return state, mstack, ef_all, mirror, ctrl

            return superstep

        if n_rounds == 1:
            def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                          cids, round_idx, round_key, ctrl_state, *test):
                b0 = jax.tree.map(lambda a: a[0], batches)
                state, ef_all, mirror, ctrl, m = one_round_ctrl(
                    global_state, ef_all, mirror, ctrl_state, b0, sizes[0],
                    lrs[0], cids[0], round_idx[0], round_key, test)
                return state, _stack1(m), ef_all, mirror, ctrl
            return superstep

        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, ctrl_state, *test):
            def body(carry, xs):
                state, ef_all, mirror, ctrl = carry
                b, n, lr, cid, r = xs
                state, ef_all, mirror, ctrl, m = one_round_ctrl(
                    state, ef_all, mirror, ctrl, b, n, lr, cid, r,
                    round_key, test)
                return (state, ef_all, mirror, ctrl), m

            (state, ef_all, mirror, ctrl), mstack = jax.lax.scan(
                body, (global_state, ef_all, mirror, ctrl_state),
                (batches, sizes, lrs, cids, round_idx))
            return state, mstack, ef_all, mirror, ctrl

        return superstep

    if participation:
        if n_rounds == 1:
            def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                          cids, round_idx, round_key, pmask, pstale, *test):
                b0 = jax.tree.map(lambda a: a[0], batches)
                state, ef_all, mirror, m = one_round(
                    global_state, ef_all, mirror, b0, sizes[0], lrs[0],
                    cids[0], round_idx[0], round_key, test,
                    pmask[0], pstale[0])
                return state, _stack1(m), ef_all, mirror
            return superstep

        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, pmask, pstale, *test):
            def body(carry, xs):
                state, ef_all, mirror = carry
                b, n, lr, cid, r, pm, ps = xs
                state, ef_all, mirror, m = one_round(
                    state, ef_all, mirror, b, n, lr, cid, r, round_key,
                    test, pm, ps)
                return (state, ef_all, mirror), m

            (state, ef_all, mirror), mstack = jax.lax.scan(
                body, (global_state, ef_all, mirror),
                (batches, sizes, lrs, cids, round_idx, pmask, pstale))
            return state, mstack, ef_all, mirror

        return superstep

    if n_rounds == 1:
        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, *test):
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, ef_all, mirror, m = one_round(
                global_state, ef_all, mirror, b0, sizes[0], lrs[0], cids[0],
                round_idx[0], round_key, test)
            return state, _stack1(m), ef_all, mirror
        return superstep

    def superstep(global_state, ef_all, mirror, batches, sizes, lrs, cids,
                  round_idx, round_key, *test):
        def body(carry, xs):
            state, ef_all, mirror = carry
            b, n, lr, cid, r = xs
            state, ef_all, mirror, m = one_round(
                state, ef_all, mirror, b, n, lr, cid, r, round_key, test)
            return (state, ef_all, mirror), m

        (state, ef_all, mirror), mstack = jax.lax.scan(
            body, (global_state, ef_all, mirror),
            (batches, sizes, lrs, cids, round_idx))
        return state, mstack, ef_all, mirror

    return superstep


def _make_fused_compressed_superstep(bundle, fl, mode, n_rounds, uplink,
                                     downlink, *, eval_fn, impl, shard,
                                     telemetry=None, participation=False,
                                     controller=None):
    """One-psum-per-round compressed superstep (shard_map body).

    Pipelining layout: a per-chunk prologue psum seeds round 0's gathered
    EF rows and weight total; thereafter round r's single psum carries its
    contribution sums, its scatter placement, round r+1's gather
    contributions and round r+1's weight total.  The last round's
    next-round slots are computed from rolled inputs and discarded —
    keeping the scan body uniform costs one dead [C, n] lane in the final
    psum of each chunk.

    Participation keeps this layout intact: masked clients are zeroed by
    the pre-weighted sizes (so the pipelined totals need no change), a
    masked client's ``new_ef`` equals its incoming row (the round fn
    rolls the update back), and the mask-weighted loss sums are two f32
    lanes in the same packed psum — still exactly ONE psum per round.
    """
    local_fn, finish_fn = make_compressed_round_parts(
        bundle, fl, mode, uplink, downlink, impl=impl, shard=shard,
        telemetry=telemetry, participation=participation,
        controller=controller)

    def one_round(state, ef_all, mirror, ef_rows, total, b, n, lr, cid,
                  cid_next, n_next, r, round_key, test, pm=None, ps=None):
        key_r = jax.random.fold_in(round_key, r)
        if participation:
            contribs, aux = local_fn(state, b, total, n, lr, ef_rows,
                                     mirror, key_r, pm, ps)
        else:
            contribs, aux = local_fn(state, b, total, n, lr, ef_rows,
                                     mirror, key_r)
        summed = fused_psum({
            "round": contribs,
            "scat": jax.tree.map(
                lambda rows: _ef_place_positional(rows, shard),
                aux["new_ef"]),
            "gath": jax.tree.map(
                lambda t, rows: _ef_gather_next_contrib(
                    t, cid, cid_next, rows, shard, impl=impl),
                ef_all, aux["new_ef"]),
            "total": _size_total(n_next),
        }, shard)
        state, metrics = finish_fn(state, summed["round"])
        ef_all = jax.tree.map(
            lambda t, full: _ef_scatter_local(t, cid, full, shard,
                                              impl=impl),
            ef_all, summed["scat"])
        ef_next = _slice_positional(summed["gath"], shard, n.shape[0])
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, ef_all, aux["bcast"], ef_next, summed["total"], metrics

    def one_round_ctrl(state, ef_all, mirror, ef_rows, total, ctrl, b, n,
                       lr, cid, cid_next, n_next, r, round_key, test,
                       pm=None, ps=None):
        # Identical psum layout to one_round: the controller reads the
        # round's summed tap metrics AFTER the single collective and its
        # state transition is pure replicated scalar math, so adaptivity
        # adds zero collectives to the round.
        key_r = jax.random.fold_in(round_key, r)
        if participation:
            contribs, aux = local_fn(state, b, total, n, lr, ef_rows,
                                     mirror, key_r, pm, ps, ctrl)
        else:
            contribs, aux = local_fn(state, b, total, n, lr, ef_rows,
                                     mirror, key_r, ctrl)
        summed = fused_psum({
            "round": contribs,
            "scat": jax.tree.map(
                lambda rows: _ef_place_positional(rows, shard),
                aux["new_ef"]),
            "gath": jax.tree.map(
                lambda t, rows: _ef_gather_next_contrib(
                    t, cid, cid_next, rows, shard, impl=impl),
                ef_all, aux["new_ef"]),
            "total": _size_total(n_next),
        }, shard)
        state, metrics, ctrl = finish_fn(state, summed["round"], ctrl)
        ef_all = jax.tree.map(
            lambda t, full: _ef_scatter_local(t, cid, full, shard,
                                              impl=impl),
            ef_all, summed["scat"])
        ef_next = _slice_positional(summed["gath"], shard, n.shape[0])
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return (state, ef_all, aux["bcast"], ef_next, summed["total"],
                ctrl, metrics)

    def _prologue(ef_all, cids, sizes):
        # round 0's EF rows + weight total in one psum
        seed = fused_psum({
            "gather": jax.tree.map(
                lambda t: _ef_gather_contrib(t, cids[0], shard, impl=impl),
                ef_all),
            "total": _size_total(sizes[0]),
        }, shard)
        return _slice_positional(seed["gather"], shard,
                                 sizes.shape[1]), seed["total"]

    if controller is not None:
        if participation:
            def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                          cids, round_idx, round_key, pmask, pstale,
                          ctrl_state, *test):
                ef_rows, total = _prologue(ef_all, cids, sizes)
                if n_rounds == 1:
                    b0 = jax.tree.map(lambda a: a[0], batches)
                    state, ef_all, mirror, _, _, ctrl, m = one_round_ctrl(
                        global_state, ef_all, mirror, ef_rows, total,
                        ctrl_state, b0, sizes[0], lrs[0], cids[0], cids[0],
                        sizes[0], round_idx[0], round_key, test,
                        pmask[0], pstale[0])
                    return state, _stack1(m), ef_all, mirror, ctrl

                cids_next = jnp.roll(cids, -1, axis=0)
                sizes_next = jnp.roll(sizes, -1, axis=0)

                def body(carry, xs):
                    state, ef_all, mirror, ef_rows, total, ctrl = carry
                    b, n, lr, cid, cid_next, n_next, r, pm, ps = xs
                    (state, ef_all, mirror, ef_rows, total, ctrl,
                     m) = one_round_ctrl(
                        state, ef_all, mirror, ef_rows, total, ctrl, b, n,
                        lr, cid, cid_next, n_next, r, round_key, test,
                        pm, ps)
                    return (state, ef_all, mirror, ef_rows, total, ctrl), m

                (state, ef_all, mirror, _, _, ctrl), mstack = jax.lax.scan(
                    body,
                    (global_state, ef_all, mirror, ef_rows, total,
                     ctrl_state),
                    (batches, sizes, lrs, cids, cids_next, sizes_next,
                     round_idx, pmask, pstale))
                return state, mstack, ef_all, mirror, ctrl

            return superstep

        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, ctrl_state, *test):
            ef_rows, total = _prologue(ef_all, cids, sizes)
            if n_rounds == 1:
                b0 = jax.tree.map(lambda a: a[0], batches)
                state, ef_all, mirror, _, _, ctrl, m = one_round_ctrl(
                    global_state, ef_all, mirror, ef_rows, total,
                    ctrl_state, b0, sizes[0], lrs[0], cids[0], cids[0],
                    sizes[0], round_idx[0], round_key, test)
                return state, _stack1(m), ef_all, mirror, ctrl

            cids_next = jnp.roll(cids, -1, axis=0)
            sizes_next = jnp.roll(sizes, -1, axis=0)

            def body(carry, xs):
                state, ef_all, mirror, ef_rows, total, ctrl = carry
                b, n, lr, cid, cid_next, n_next, r = xs
                (state, ef_all, mirror, ef_rows, total, ctrl,
                 m) = one_round_ctrl(
                    state, ef_all, mirror, ef_rows, total, ctrl, b, n, lr,
                    cid, cid_next, n_next, r, round_key, test)
                return (state, ef_all, mirror, ef_rows, total, ctrl), m

            (state, ef_all, mirror, _, _, ctrl), mstack = jax.lax.scan(
                body,
                (global_state, ef_all, mirror, ef_rows, total, ctrl_state),
                (batches, sizes, lrs, cids, cids_next, sizes_next,
                 round_idx))
            return state, mstack, ef_all, mirror, ctrl

        return superstep

    if participation:
        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, pmask, pstale, *test):
            ef_rows, total = _prologue(ef_all, cids, sizes)
            if n_rounds == 1:
                b0 = jax.tree.map(lambda a: a[0], batches)
                state, ef_all, mirror, _, _, m = one_round(
                    global_state, ef_all, mirror, ef_rows, total, b0,
                    sizes[0], lrs[0], cids[0], cids[0], sizes[0],
                    round_idx[0], round_key, test, pmask[0], pstale[0])
                return state, _stack1(m), ef_all, mirror

            cids_next = jnp.roll(cids, -1, axis=0)
            sizes_next = jnp.roll(sizes, -1, axis=0)

            def body(carry, xs):
                state, ef_all, mirror, ef_rows, total = carry
                b, n, lr, cid, cid_next, n_next, r, pm, ps = xs
                state, ef_all, mirror, ef_rows, total, m = one_round(
                    state, ef_all, mirror, ef_rows, total, b, n, lr, cid,
                    cid_next, n_next, r, round_key, test, pm, ps)
                return (state, ef_all, mirror, ef_rows, total), m

            (state, ef_all, mirror, _, _), mstack = jax.lax.scan(
                body, (global_state, ef_all, mirror, ef_rows, total),
                (batches, sizes, lrs, cids, cids_next, sizes_next,
                 round_idx, pmask, pstale))
            return state, mstack, ef_all, mirror

        return superstep

    def superstep(global_state, ef_all, mirror, batches, sizes, lrs, cids,
                  round_idx, round_key, *test):
        ef_rows, total = _prologue(ef_all, cids, sizes)
        if n_rounds == 1:
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, ef_all, mirror, _, _, m = one_round(
                global_state, ef_all, mirror, ef_rows, total, b0,
                sizes[0], lrs[0], cids[0], cids[0], sizes[0], round_idx[0],
                round_key, test)
            return state, _stack1(m), ef_all, mirror

        cids_next = jnp.roll(cids, -1, axis=0)
        sizes_next = jnp.roll(sizes, -1, axis=0)

        def body(carry, xs):
            state, ef_all, mirror, ef_rows, total = carry
            b, n, lr, cid, cid_next, n_next, r = xs
            state, ef_all, mirror, ef_rows, total, m = one_round(
                state, ef_all, mirror, ef_rows, total, b, n, lr, cid,
                cid_next, n_next, r, round_key, test)
            return (state, ef_all, mirror, ef_rows, total), m

        (state, ef_all, mirror, _, _), mstack = jax.lax.scan(
            body, (global_state, ef_all, mirror, ef_rows, total),
            (batches, sizes, lrs, cids, cids_next, sizes_next, round_idx))
        return state, mstack, ef_all, mirror

    return superstep
