"""Jitted K-round supersteps: ``lax.scan`` over the federated round fn.

One superstep call turns K pre-staged rounds entirely on device:

* client sampling arrives as a pre-sampled ``cids [K, C]`` array (drawn on
  the host by the prefetch pipeline with the exact rng stream of the
  one-round-at-a-time loop);
* the lr schedule arrives as a ``lrs [K]`` array;
* the compressed path's full-federation error-feedback tree and broadcast
  mirror ride the scan carry: each round gathers the sampled clients' EF
  rows (``ops.ef_gather``), runs the compressed round fn, and scatters the
  new residuals back with a fused in-place row scatter (``ops.ef_scatter``
  — ``.at[cids].set`` under donation on the jnp path, an aliased Pallas
  kernel on TPU).  The per-round device->host->device NumPy round-trip of
  the old server loop is gone;
* per-round metrics come back stacked ``[K]`` so the host never has to
  block mid-chunk, and when evaluation happens every round (the paper's
  accuracy-per-round curves) the fixed-shape evaluator is folded straight
  into the scan body.

``K == 1`` bypasses ``lax.scan`` and applies the round body to the leading
slice directly, so a chunk-size-1 engine run compiles the same per-round
computation as the reference loop — that is what makes the K=1 final model
bitwise-equal to the pre-engine loop (the equivalence contract
``tests/test_engine.py`` pins down).

Sharded mode (``shard`` = a :class:`repro.core.aggregate.ClientSharding`):
the superstep becomes a ``shard_map`` BODY (see ``repro.engine.sharded``).
Batches/sizes then carry only this shard's positional client slice, the
EF table argument is this shard's row block (rows ``[pos*N_loc,
(pos+1)*N_loc)`` of the full federation, sharded by client id), and
``cids`` stays the FULL round sample (replicated — ownership of an EF row
is decided by cid, not by which shard trains the client).  Each round the
sampled rows cross shards through one compact ``psum`` exchange in each
direction (``[C, n]`` — the same order as the FedAvg delta psum); the
``ef_gather``/``ef_scatter`` kernels themselves only ever index the LOCAL
row block.  With ``shard=None`` nothing changes.

The caller jits the returned function; donate ``global_state`` (and for
the compressed path ``ef_all`` + ``mirror``) so steady-state chunks update
those buffers in place instead of reallocating them every call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.rounds import make_compressed_round_fn, make_round_fn
from repro.kernels import ops


def _stack1(tree):
    """Metrics of a single round -> the [1]-stacked layout scan returns."""
    return jax.tree.map(lambda v: jnp.asarray(v)[None], tree)


def make_plain_superstep(bundle, fl, mode, n_rounds, *, eval_fn=None,
                         impl="auto", shard=None):
    """Uncompressed K-round superstep.

    Returns ``superstep(global_state, batches, sizes, lrs[, test_batch,
    test_mask]) -> (new_global_state, metrics stacked [K])`` with leading
    dims ``batches [K, C, steps, B, ...]``, ``sizes [K, C]``, ``lrs [K]``.
    ``eval_fn`` (traceable, from :func:`repro.engine.make_eval_fn`) folds
    per-round evaluation of the post-round state into the scan.  Under
    ``shard`` the batch/size client axis is this shard's slice; evaluation
    runs replicated on the (replicated) post-round state.
    """
    round_fn = make_round_fn(bundle, fl, mode, impl=impl, shard=shard)

    def one_round(state, b, n, lr, test):
        state, metrics = round_fn(state, b, n, lr)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, metrics

    if n_rounds == 1:
        def superstep(global_state, batches, sizes, lrs, *test):
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, m = one_round(global_state, b0, sizes[0], lrs[0], test)
            return state, _stack1(m)
        return superstep

    def superstep(global_state, batches, sizes, lrs, *test):
        def body(state, xs):
            b, n, lr = xs
            return one_round(state, b, n, lr, test)

        return jax.lax.scan(body, global_state, (batches, sizes, lrs))

    return superstep


# ---------------------------------------------------------------------------
# Row-sharded EF exchange (shard_map body helpers)
# ---------------------------------------------------------------------------

def ef_gather_exchange(table, cids, shard, *, impl="auto"):
    """Assemble the round's full [C, ...] EF rows from row-sharded blocks.

    ``table`` is this shard's LOCAL row block [N_loc, ...] of the
    federation table (shard ``s`` owns client ids ``[s*N_loc,
    (s+1)*N_loc)``); ``cids [C]`` is the full round sample (replicated).
    Each shard gathers the sampled rows it owns — a shard-local
    ``ops.ef_gather`` with clipped indices — masks the rest to zero, and
    one ``psum`` over the client axes gives every shard the complete
    [C, ...] matrix.  Rows are disjointly owned, so the sum is exact.
    """
    n_loc = table.shape[0]
    lo = shard.position() * n_loc
    owned = (cids >= lo) & (cids < lo + n_loc)
    local_idx = jnp.clip(cids - lo, 0, n_loc - 1).astype(jnp.int32)
    rows = ops.ef_gather(table, local_idx, impl=impl)
    mask = owned.reshape((-1,) + (1,) * (rows.ndim - 1))
    contrib = jnp.where(mask, rows, jnp.zeros_like(rows))
    return jax.lax.psum(contrib, shard.axis_name)


def ef_scatter_exchange(table, cids, new_rows, shard, *, impl="auto"):
    """Write this shard's freshly-trained EF rows back to their owners.

    ``new_rows [C_loc, ...]`` are the residuals of this shard's POSITIONAL
    clients; their cids may be owned by any shard.  The rows are placed at
    their positional offset in a zero [C, ...] buffer, one ``psum``
    broadcasts the complete set, and each shard scatters the rows it owns
    into its local block.  Non-owned rows are routed to a scratch row
    appended past the block (row ``N_loc``) so the in-place
    ``ops.ef_scatter`` never sees a colliding index — a clipped index
    could alias a genuinely-owned row and ``.at[].set`` with duplicate
    indices keeps an arbitrary write.
    """
    n_loc = table.shape[0]
    c_loc = new_rows.shape[0]
    pos = shard.position()
    full = jnp.zeros((c_loc * shard.n_shards,) + new_rows.shape[1:],
                     new_rows.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(
        full, new_rows, (pos * c_loc).astype(jnp.int32), axis=0)
    full = jax.lax.psum(full, shard.axis_name)
    lo = pos * n_loc
    owned = (cids >= lo) & (cids < lo + n_loc)
    safe_idx = jnp.where(owned, cids - lo, n_loc).astype(jnp.int32)
    scratch = jnp.concatenate(
        [table, jnp.zeros((1,) + table.shape[1:], table.dtype)], axis=0)
    return ops.ef_scatter(scratch, safe_idx, full, impl=impl)[:n_loc]


def make_compressed_superstep(bundle, fl, mode, n_rounds, uplink, downlink,
                              *, eval_fn=None, impl="auto", shard=None):
    """Compressed (codec-routed) K-round superstep.

    Returns ``superstep(global_state, ef_all, mirror, batches, sizes, lrs,
    cids, round_idx, round_key[, test_batch, test_mask]) ->
    (new_global_state, metrics [K], new_ef_all, new_mirror)``.

    ``ef_all`` holds the FULL federation's per-client uplink EF residuals
    (leaves ``[n_clients, n]``) on device; ``cids [K, C]`` selects each
    round's rows.  ``round_idx [K]`` feeds ``fold_in(round_key, r)`` inside
    the scan, reproducing the reference loop's per-round key derivation
    bit for bit (fold_in is a pure function of the key data and r).

    Under ``shard``, ``ef_all`` is this shard's row block and the row
    movement goes through :func:`ef_gather_exchange` /
    :func:`ef_scatter_exchange`; ``cids`` stays the full round sample.
    """
    round_fn = make_compressed_round_fn(bundle, fl, mode, uplink, downlink,
                                        impl=impl, shard=shard)

    def gather_rows(ef_all, cids, c_loc):
        if shard is None:
            return jax.tree.map(
                lambda t: ops.ef_gather(t, cids, impl=impl), ef_all)
        start = (shard.position() * c_loc).astype(jnp.int32)
        return jax.tree.map(
            lambda t: jax.lax.dynamic_slice_in_dim(
                ef_gather_exchange(t, cids, shard, impl=impl),
                start, c_loc, axis=0),
            ef_all)

    def scatter_rows(ef_all, cids, new_ef):
        if shard is None:
            return jax.tree.map(
                lambda t, rows: ops.ef_scatter(t, cids, rows, impl=impl),
                ef_all, new_ef)
        return jax.tree.map(
            lambda t, rows: ef_scatter_exchange(t, cids, rows, shard,
                                                impl=impl),
            ef_all, new_ef)

    def one_round(state, ef_all, mirror, b, n, lr, cids, r, round_key, test):
        ef_round = gather_rows(ef_all, cids, n.shape[0])
        key_r = jax.random.fold_in(round_key, r)
        state, metrics, new_ef, mirror = round_fn(state, b, n, lr, ef_round,
                                                  mirror, key_r)
        ef_all = scatter_rows(ef_all, cids, new_ef)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, ef_all, mirror, metrics

    if n_rounds == 1:
        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, *test):
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, ef_all, mirror, m = one_round(
                global_state, ef_all, mirror, b0, sizes[0], lrs[0], cids[0],
                round_idx[0], round_key, test)
            return state, _stack1(m), ef_all, mirror
        return superstep

    def superstep(global_state, ef_all, mirror, batches, sizes, lrs, cids,
                  round_idx, round_key, *test):
        def body(carry, xs):
            state, ef_all, mirror = carry
            b, n, lr, cid, r = xs
            state, ef_all, mirror, m = one_round(
                state, ef_all, mirror, b, n, lr, cid, r, round_key, test)
            return (state, ef_all, mirror), m

        (state, ef_all, mirror), mstack = jax.lax.scan(
            body, (global_state, ef_all, mirror),
            (batches, sizes, lrs, cids, round_idx))
        return state, mstack, ef_all, mirror

    return superstep
