"""Jitted K-round supersteps: ``lax.scan`` over the federated round fn.

One superstep call turns K pre-staged rounds entirely on device:

* client sampling arrives as a pre-sampled ``cids [K, C]`` array (drawn on
  the host by the prefetch pipeline with the exact rng stream of the
  one-round-at-a-time loop);
* the lr schedule arrives as a ``lrs [K]`` array;
* the compressed path's full-federation error-feedback tree and broadcast
  mirror ride the scan carry: each round gathers the sampled clients' EF
  rows (``ops.ef_gather``), runs the compressed round fn, and scatters the
  new residuals back with a fused in-place row scatter (``ops.ef_scatter``
  — ``.at[cids].set`` under donation on the jnp path, an aliased Pallas
  kernel on TPU).  The per-round device->host->device NumPy round-trip of
  the old server loop is gone;
* per-round metrics come back stacked ``[K]`` so the host never has to
  block mid-chunk, and when evaluation happens every round (the paper's
  accuracy-per-round curves) the fixed-shape evaluator is folded straight
  into the scan body.

``K == 1`` bypasses ``lax.scan`` and applies the round body to the leading
slice directly, so a chunk-size-1 engine run compiles the same per-round
computation as the reference loop — that is what makes the K=1 final model
bitwise-equal to the pre-engine loop (the equivalence contract
``tests/test_engine.py`` pins down).

The caller jits the returned function; donate ``global_state`` (and for
the compressed path ``ef_all`` + ``mirror``) so steady-state chunks update
those buffers in place instead of reallocating them every call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.rounds import make_compressed_round_fn, make_round_fn
from repro.kernels import ops


def _stack1(tree):
    """Metrics of a single round -> the [1]-stacked layout scan returns."""
    return jax.tree.map(lambda v: jnp.asarray(v)[None], tree)


def make_plain_superstep(bundle, fl, mode, n_rounds, *, eval_fn=None,
                         impl="auto"):
    """Uncompressed K-round superstep.

    Returns ``superstep(global_state, batches, sizes, lrs[, test_batch,
    test_mask]) -> (new_global_state, metrics stacked [K])`` with leading
    dims ``batches [K, C, steps, B, ...]``, ``sizes [K, C]``, ``lrs [K]``.
    ``eval_fn`` (traceable, from :func:`repro.engine.make_eval_fn`) folds
    per-round evaluation of the post-round state into the scan.
    """
    round_fn = make_round_fn(bundle, fl, mode, impl=impl)

    def one_round(state, b, n, lr, test):
        state, metrics = round_fn(state, b, n, lr)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, metrics

    if n_rounds == 1:
        def superstep(global_state, batches, sizes, lrs, *test):
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, m = one_round(global_state, b0, sizes[0], lrs[0], test)
            return state, _stack1(m)
        return superstep

    def superstep(global_state, batches, sizes, lrs, *test):
        def body(state, xs):
            b, n, lr = xs
            return one_round(state, b, n, lr, test)

        return jax.lax.scan(body, global_state, (batches, sizes, lrs))

    return superstep


def make_compressed_superstep(bundle, fl, mode, n_rounds, uplink, downlink,
                              *, eval_fn=None, impl="auto"):
    """Compressed (codec-routed) K-round superstep.

    Returns ``superstep(global_state, ef_all, mirror, batches, sizes, lrs,
    cids, round_idx, round_key[, test_batch, test_mask]) ->
    (new_global_state, metrics [K], new_ef_all, new_mirror)``.

    ``ef_all`` holds the FULL federation's per-client uplink EF residuals
    (leaves ``[n_clients, n]``) on device; ``cids [K, C]`` selects each
    round's rows.  ``round_idx [K]`` feeds ``fold_in(round_key, r)`` inside
    the scan, reproducing the reference loop's per-round key derivation
    bit for bit (fold_in is a pure function of the key data and r).
    """
    round_fn = make_compressed_round_fn(bundle, fl, mode, uplink, downlink,
                                        impl=impl)

    def one_round(state, ef_all, mirror, b, n, lr, cids, r, round_key, test):
        ef_round = jax.tree.map(lambda t: ops.ef_gather(t, cids, impl=impl),
                                ef_all)
        key_r = jax.random.fold_in(round_key, r)
        state, metrics, new_ef, mirror = round_fn(state, b, n, lr, ef_round,
                                                  mirror, key_r)
        ef_all = jax.tree.map(
            lambda t, rows: ops.ef_scatter(t, cids, rows, impl=impl),
            ef_all, new_ef)
        if eval_fn is not None:
            metrics = {**metrics, **eval_fn(state, test[0], test[1])}
        return state, ef_all, mirror, metrics

    if n_rounds == 1:
        def superstep(global_state, ef_all, mirror, batches, sizes, lrs,
                      cids, round_idx, round_key, *test):
            b0 = jax.tree.map(lambda a: a[0], batches)
            state, ef_all, mirror, m = one_round(
                global_state, ef_all, mirror, b0, sizes[0], lrs[0], cids[0],
                round_idx[0], round_key, test)
            return state, _stack1(m), ef_all, mirror
        return superstep

    def superstep(global_state, ef_all, mirror, batches, sizes, lrs, cids,
                  round_idx, round_key, *test):
        def body(carry, xs):
            state, ef_all, mirror = carry
            b, n, lr, cid, r = xs
            state, ef_all, mirror, m = one_round(
                state, ef_all, mirror, b, n, lr, cid, r, round_key, test)
            return (state, ef_all, mirror), m

        (state, ef_all, mirror), mstack = jax.lax.scan(
            body, (global_state, ef_all, mirror),
            (batches, sizes, lrs, cids, round_idx))
        return state, mstack, ef_all, mirror

    return superstep
