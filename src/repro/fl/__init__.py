from repro.fl.comm import CommLog, tree_bytes  # noqa: F401
from repro.fl.newclient import newclient_convergence  # noqa: F401
from repro.fl.server import ServerResult, evaluate, run_federated  # noqa: F401
