from repro.fl.api import (Algorithm, ALGORITHM_NAMES,  # noqa: F401
                          FederatedTrainer, RunOptions, make_algorithm,
                          register_algorithm)
from repro.fl.comm import CommLog, tree_bytes  # noqa: F401
from repro.fl.newclient import newclient_convergence  # noqa: F401
from repro.fl.participation import (ParticipationPolicy,  # noqa: F401
                                    RoundParticipation, make_policy,
                                    register_policy, registered_policies)
from repro.fl.server import ServerResult, evaluate, run_federated  # noqa: F401
