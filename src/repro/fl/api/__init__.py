"""``repro.fl.api`` — pluggable algorithms + the ``FederatedTrainer`` facade.

    Algorithm / register_algorithm / make_algorithm — plugin interface
        and registry (mirrors ``repro.compress.make_codec``); built-in
        plugins live in ``repro.fl.api.plugins``, the out-of-core
        demonstration in ``repro.contrib.fedprox``
    ALGORITHM_NAMES — the default-registered names (the authoritative
        set; ``repro.configs.base.ALGORITHM_NAMES`` mirrors it literally
        and a sync test keeps the two from drifting)
    FederatedTrainer / RunOptions (+ Eval/Checkpoint/EngineOptions) —
        the unified engine-backed entry point; ``repro.fl.server.
        run_federated`` is a thin back-compat wrapper over it
    Controller / register_controller / make_controller /
        registered_controllers — the in-superstep adaptive compression
        axis (re-exported from ``repro.control``; same plugin idiom)
"""
from repro.control import (Controller, make_controller,  # noqa: F401
                           register_controller, registered_controllers)
from repro.fl.api.algorithm import (Algorithm, make_algorithm,  # noqa: F401
                                    register_algorithm,
                                    registered_algorithms)
from repro.fl.api.trainer import (CheckpointOptions, EngineOptions,  # noqa: F401
                                  EvalOptions, FederatedTrainer,
                                  RunOptions)


def __getattr__(name):  # PEP 562
    # computed on access, not at import: always the LIVE registry — this
    # stays correct when a plugin module (e.g. repro.contrib.fedprox) is
    # itself mid-import while this package initializes, and it reflects
    # algorithms registered later at runtime.
    if name == "ALGORITHM_NAMES":
        return registered_algorithms()
    raise AttributeError(name)
