"""The ``Algorithm`` plugin interface + registry.

The paper's whole contribution is *mechanisms added to on-device
training*; this module makes those mechanisms pluggable the same way
``repro.compress`` makes wire codecs pluggable.  An :class:`Algorithm`
supplies four hooks, each mapping onto one place the federated machinery
used to branch on ``fl.algorithm ==``:

    init_extra_state    global-state entries beyond "model"
                        (FedFusion's fusion module params)
    local_loss          the client's two-stream training objective
                        (FedMMD's MMD constraint, FedProx's prox term)
    aggregate_extras /  server-side aggregation of the extra state
    finalize_extra_sums (fusion-gate EMA through ``ClientSharding`` psums;
                        the *_sums variant closes the client_sequential
                        running-sum path)
    deploy_logits       eval-time logits of the deployed global model
                        (FedFusion fuses the global features with
                        themselves through the aggregated module)

Plugins are stateless singletons registered by name — everything
configurable arrives through the :class:`repro.configs.base.FLConfig`
that every hook receives — so one instance serves any number of
concurrent runs, exactly like codec objects.

The hooks are jax-traceable: ``local_loss``/``aggregate_extras``/
``finalize_extra_sums``/``deploy_logits`` run under jit/vmap/shard_map
inside the round and eval functions, so a plugin must keep its output
pytree *structure* independent of traced values.

Registering a new mechanism (RingFed-style partial averaging, a CFedAvg
variant, ...) never touches ``repro.core``: subclass, implement the
hooks you need, call :func:`register_algorithm` — see
``repro/contrib/fedprox.py`` for a complete out-of-core example.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

__all__ = ["Algorithm", "register_algorithm", "make_algorithm",
           "registered_algorithms"]


class Algorithm:
    """Base algorithm: FedAvg semantics; override hooks to add mechanisms.

    ``name``         registry key (``FLConfig.algorithm``).
    ``two_stream``   True when ``local_loss`` consumes the frozen global
                     stream's features — the local trainer then offers the
                     paper-§3.3 per-round feature cache (``cached_feats_g``).
    ``extra_state``  global-state keys this algorithm carries beyond
                     ``"model"`` (e.g. ``("fusion",)``); the round fns
                     thread/accumulate these generically and hand them
                     back through the aggregation hooks.
    """

    name: str = ""
    two_stream: bool = False
    extra_state: Tuple[str, ...] = ()

    # -- global state ---------------------------------------------------
    def init_extra_state(self, bundle, fl, key) -> Dict[str, Any]:
        """Server line 1 extras: ``{key: params}`` for ``extra_state``."""
        return {}

    def extra_from_state(self, global_state) -> Any:
        """The extra-state value handed to the local trainer (the second
        argument of ``local_train``): the raw params for a single extra
        key, a ``{key: params}`` dict for several, None for none."""
        if not self.extra_state:
            return None
        if len(self.extra_state) == 1:
            return global_state.get(self.extra_state[0])
        return {k: global_state[k] for k in self.extra_state}

    # -- client side ----------------------------------------------------
    def init_trainable(self, fl, global_model, extra) -> Dict[str, Any]:
        """The client's trainable pytree.  Keys must be ``"model"`` plus
        exactly ``extra_state`` — the round fns accumulate/aggregate every
        key generically.  ``extra`` is :meth:`extra_from_state`'s value."""
        return {"model": global_model}

    def local_loss(self, bundle, fl, trainable, global_model, batch,
                   cached_feats_g=None, *, impl="auto"):
        """``(loss, aux_dict)`` for one local SGD step.  ``global_model``
        is the FROZEN global stream (never updated during local training —
        paper Fig. 1); ``cached_feats_g`` carries its precomputed features
        when ``two_stream`` and the trainer cached them (else None)."""
        raise NotImplementedError(self.name)

    # -- server side ----------------------------------------------------
    def aggregate_extras(self, fl, global_state, stacked, weights,
                         shard=None) -> Dict[str, Any]:
        """Aggregate the clients' extra state (client_parallel path).

        ``stacked``: ``{key: pytree with leading client axis}`` for every
        ``extra_state`` key; ``weights [n_clients]`` are globally
        normalized.  Under ``shard`` the client axis holds only this
        shard's clients — complete any cross-client statistic with the
        ``repro.core.aggregate`` psum helpers.

        NOTE: the engine's fused-collective path (the sharded default)
        does not call this hook — it packs the weighted sums of the
        stacked extras into the round's single psum and closes them with
        :meth:`finalize_extra_sums`, so keep the two decompositions
        consistent: ``aggregate_extras(stacked, w) ==
        finalize_extra_sums(psum(tensordot(w, stacked)))`` (true of every
        in-tree plugin; a plugin needing a different cross-client
        statistic should run with ``fused_collective=False``)."""
        return {}

    def finalize_extra_sums(self, fl, global_state, sums) -> Dict[str, Any]:
        """Close the client_sequential running-sum path: ``sums`` holds
        the psum-completed weighted sums of the clients' extra state."""
        return {}

    # -- deployment -----------------------------------------------------
    def deploy_logits(self, bundle, fl, global_state, out, *, impl="auto"):
        """Logits of the deployed global model given ``out =
        bundle.apply(global_state['model'], batch)`` — the single
        implementation behind jitted eval, the eager oracle and the
        new-client probe."""
        return out["logits"]


# ---------------------------------------------------------------------------
# Registry (mirrors repro.compress.make_codec)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Algorithm] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Idempotently register the in-tree plugin modules."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    import repro.fl.api.plugins      # noqa: F401 — registers the paper's four
    import repro.contrib.fedprox     # noqa: F401 — out-of-core demonstration
    # latch only after both imports succeed: a transient ImportError must
    # surface again on the next call, not decay into "unknown algorithm"
    _BUILTINS_LOADED = True


def register_algorithm(algo: Algorithm, *, override: bool = False) -> Algorithm:
    """Register ``algo`` under ``algo.name``; returns it (decorator-friendly
    via ``register_algorithm(MyAlgo())``).  Re-registering an existing name
    requires ``override=True`` so typos can't silently shadow a plugin."""
    if not algo.name:
        raise ValueError("Algorithm.name must be a non-empty string")
    if algo.name in _REGISTRY and not override:
        raise ValueError(f"algorithm {algo.name!r} already registered "
                         f"(pass override=True to replace)")
    _REGISTRY[algo.name] = algo
    return algo


def make_algorithm(name: str) -> Algorithm:
    """Look up an algorithm plugin by config name."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown algorithm {name!r}; choose from "
                         f"{registered_algorithms()}") from None


def registered_algorithms() -> Tuple[str, ...]:
    """All registered names, in registration order."""
    _ensure_builtins()
    return tuple(_REGISTRY)
