"""The paper's four mechanisms as :class:`Algorithm` plugins.

Each plugin is the verbatim math that used to live behind
``fl.algorithm ==`` branches in ``core/local.py`` / ``core/rounds.py`` /
``engine/evaljit.py`` / ``fl/server.py`` / ``fl/newclient.py``:

  fedavg    L = L_cls(theta_L)
  fedmmd    L = L_cls(theta_L) + lam * MMD^2(theta_G(X), theta_L(X))
  fedl2     L = L_cls(theta_L) + lam2 * ||Theta_L - Theta_G||^2
  fedfusion L = L_cls(C_L(F(E_l(X), E_g(X))))   with E_g frozen

The frozen global stream is closed over and NEVER updated during local
training (paper Fig. 1: "the global model is fixed while the local model
is trained through back propagation").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fusion import (fusion_aggregate, fusion_apply, fusion_init)
from repro.core.losses import cross_entropy, l2_tree_distance
from repro.core.mmd import mmd_loss
from repro.fl.api.algorithm import Algorithm, register_algorithm

AUX_WEIGHT = 0.01  # MoE load-balance loss weight

__all__ = ["AUX_WEIGHT", "classify_loss", "FedAvg", "FedMMD", "FedL2",
           "FedFusion"]


def classify_loss(bundle, local, batch):
    """Plain single-stream forward: (cls_loss, labels, out).  Public so
    out-of-core plugins (repro.contrib) build on the same classify path
    instead of re-deriving it."""
    labels = bundle.labels(batch)
    out = bundle.apply(local, batch)
    cls = cross_entropy(out["logits"], labels) + AUX_WEIGHT * out["aux"]
    return cls, labels, out


def _frozen_features(bundle, global_model, batch, cached):
    """The frozen stream's features: the per-round cache when the trainer
    recorded one (paper §3.3), recomputed under stop_gradient otherwise."""
    if cached is None:
        cached, _ = bundle.extract(jax.lax.stop_gradient(global_model),
                                   batch)
    return jax.lax.stop_gradient(cached)


class FedAvg(Algorithm):
    name = "fedavg"

    def local_loss(self, bundle, fl, trainable, global_model, batch,
                   cached_feats_g=None, *, impl="auto"):
        cls, _, _ = classify_loss(bundle, trainable["model"], batch)
        return cls, {"cls": cls}


class FedMMD(Algorithm):
    name = "fedmmd"
    two_stream = True

    def local_loss(self, bundle, fl, trainable, global_model, batch,
                   cached_feats_g=None, *, impl="auto"):
        cls, _, out = classify_loss(bundle, trainable["model"], batch)
        feats_g = _frozen_features(bundle, global_model, batch,
                                   cached_feats_g)
        reg = mmd_loss(bundle.pool(out["features"]), bundle.pool(feats_g),
                       fl.mmd_widths, fl.mmd_lambda, impl=impl)
        return cls + reg, {"cls": cls, "mmd": reg}


class FedL2(Algorithm):
    name = "fedl2"

    def local_loss(self, bundle, fl, trainable, global_model, batch,
                   cached_feats_g=None, *, impl="auto"):
        cls, _, _ = classify_loss(bundle, trainable["model"], batch)
        reg = fl.l2_lambda * l2_tree_distance(trainable["model"],
                                              global_model)
        return cls + reg, {"cls": cls, "l2": reg}


class FedFusion(Algorithm):
    name = "fedfusion"
    two_stream = True
    extra_state = ("fusion",)

    def init_extra_state(self, bundle, fl, key):
        return {"fusion": fusion_init(fl.fusion_op, bundle.feature_channels,
                                      key)}

    def init_trainable(self, fl, global_model, extra):
        return {"model": global_model, "fusion": extra}

    def local_loss(self, bundle, fl, trainable, global_model, batch,
                   cached_feats_g=None, *, impl="auto"):
        labels = bundle.labels(batch)
        feats_l, aux = bundle.extract(trainable["model"], batch)
        feats_g = _frozen_features(bundle, global_model, batch,
                                   cached_feats_g)
        fused = fusion_apply(fl.fusion_op, trainable["fusion"],
                             feats_g, feats_l, impl=impl)
        logits = bundle.head(trainable["model"], fused)
        loss = cross_entropy(logits, labels) + AUX_WEIGHT * aux
        return loss, {"cls": loss}

    def aggregate_extras(self, fl, global_state, stacked, weights,
                         shard=None):
        return {"fusion": fusion_aggregate(
            fl.fusion_op, global_state["fusion"], stacked["fusion"],
            weights, fl.ema_beta, shard=shard)}

    def finalize_extra_sums(self, fl, global_state, sums):
        # the running sums already carry the n_t weighting; conv weights
        # average like any parameter, multi/single gates EMA-smooth
        # against the previous global gate (paper §3.3)
        if fl.fusion_op == "conv":
            return {"fusion": sums["fusion"]}
        return {"fusion": jax.tree.map(
            lambda old, new: fl.ema_beta * old + (1 - fl.ema_beta) * new,
            global_state["fusion"], sums["fusion"])}

    def deploy_logits(self, bundle, fl, global_state, out, *, impl="auto"):
        # the deployed global model fuses its own features with itself
        # through the aggregated fusion module (E_g = E_l = global)
        fused = fusion_apply(fl.fusion_op, global_state["fusion"],
                             out["features"], out["features"], impl=impl)
        return bundle.head(global_state["model"], fused)


register_algorithm(FedAvg())
register_algorithm(FedMMD())
register_algorithm(FedL2())
register_algorithm(FedFusion())
