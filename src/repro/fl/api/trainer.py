"""``FederatedTrainer``: the unified entry point over the engine.

``run_federated`` had accreted a 13-kwarg signature across PRs 1-3; the
facade groups those knobs into a :class:`RunOptions` dataclass (eval /
checkpoint / engine sub-groups) and owns the run lifecycle:

    trainer = FederatedTrainer(bundle, fl, data, RunOptions(...))
    trainer.fit(rounds)              # engine-backed, checkpoint-resumable
    trainer.evaluate()               # jitted pad-and-mask eval
    trainer.newclient_probe(data_c)  # paper Fig. 6 generalization probe

``fit`` is resumable two ways: with ``options.checkpoint.dir`` set it
restores the last checkpoint exactly like the engine always has (an
interrupted ``fit(N)`` re-invoked lands on the same state as one
uninterrupted call), and the trainer keeps the last result so
``evaluate``/``newclient_probe`` read the trained state without
re-plumbing it.  ``repro.fl.server.run_federated`` remains as a thin
back-compat wrapper that builds a ``RunOptions`` from the old kwargs.

Engine/server/newclient imports happen inside the methods: this module
sits below ``repro.core`` in the import graph (the round factories
resolve their plugin through ``repro.fl.api``), so the heavy reverse
edges must stay lazy — same pattern as ``repro.engine.engine``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Union

from repro.fl.api.algorithm import Algorithm, make_algorithm

__all__ = ["EvalOptions", "CheckpointOptions", "EngineOptions",
           "RunOptions", "FederatedTrainer"]


@dataclass(frozen=True)
class EvalOptions:
    """Global-model evaluation cadence (the paper's per-round curves)."""

    every: int = 1            # rounds between evals (folded into the scan at 1)
    examples: int = 2048      # pad-and-mask bucket cap


@dataclass(frozen=True)
class CheckpointOptions:
    """Server-state persistence; ``dir=None`` disables checkpointing."""

    dir: Optional[str] = None
    every: int = 10           # rounds between saves


@dataclass(frozen=True)
class EngineOptions:
    """Execution knobs of ``repro.engine`` (throughput only — results are
    invariant to every field except ``mesh``, which is allclose)."""

    superstep_rounds: Union[int, str] = 8   # rounds per jitted chunk | "auto"
    prefetch: bool = True                   # background host staging
    mesh: Any = None                        # client-parallel shard_map mesh
    overlap_eval: bool = True               # snapshot-dispatched boundary eval
    impl: str = "auto"                      # kernel dispatch (jnp | pallas)
    fused_collective: bool = True           # mesh: ONE packed psum per round
    sharded_eval: bool = True               # mesh: eval batch split + psum
    # compressed runs: EF residual backing — "device" dense [N, n] table,
    # "host" cohort-paged store (O(C·n) device memory, bitwise-equal),
    # "auto" pages once the projected dense table passes ~1 GiB
    ef_store: str = "auto"
    # observability (repro.obs) — off by default, bitwise-invisible when on
    telemetry: Any = False                  # True | tap names | Telemetry
    runlog: Any = None                      # JSONL path | RunLog sink
    profile_dir: Optional[str] = None       # jax.profiler trace directory
    # robustness — checkpoint + stop cleanly at the first chunk boundary
    # after a non-finite metric value (costs the metrics overlap when on)
    halt_on_nonfinite: bool = False


@dataclass(frozen=True)
class RunOptions:
    """Everything a federated run needs beyond (bundle, fl, data, rounds)."""

    mode: str = "client_parallel"           # mesh execution mode
    seed: int = 0
    verbose: bool = False
    eval: EvalOptions = field(default_factory=EvalOptions)
    checkpoint: CheckpointOptions = field(default_factory=CheckpointOptions)
    engine: EngineOptions = field(default_factory=EngineOptions)


class FederatedTrainer:
    """Facade owning one (bundle, fl, data, options) federated workload."""

    def __init__(self, bundle, fl, data, options: Optional[RunOptions] = None):
        self.bundle = bundle
        self.fl = fl
        self.data = data
        self.options = options if options is not None else RunOptions()
        self.algorithm: Algorithm = make_algorithm(fl.algorithm)
        self._result = None

    # ------------------------------------------------------------------
    @property
    def result(self):
        """The last ``fit`` result (ServerResult), or None before any fit."""
        return self._result

    @property
    def global_state(self) -> Dict[str, Any]:
        if self._result is None:
            raise RuntimeError("no trained state yet — call fit() first "
                               "(or pass global_state= explicitly)")
        return self._result.global_state

    # ------------------------------------------------------------------
    def fit(self, rounds: int, *, callback: Optional[Callable] = None):
        """Train to ``rounds`` total rounds through the engine.

        With ``options.checkpoint.dir`` set, training RESUMES from the
        last checkpoint if one exists (paper Alg. 1 line 1 only runs on a
        cold start), so an interrupted fit re-invoked with the same
        arguments finishes the same run.  Returns the ``ServerResult``
        (also kept on the trainer for ``evaluate``/``newclient_probe``).
        """
        from repro.engine import run_federated_engine
        o = self.options
        self._result = run_federated_engine(
            self.bundle, self.fl, self.data, rounds=rounds, seed=o.seed,
            mode=o.mode, eval_every=o.eval.every,
            eval_examples=o.eval.examples, verbose=o.verbose,
            checkpoint_dir=o.checkpoint.dir,
            checkpoint_every=o.checkpoint.every, callback=callback,
            superstep_rounds=o.engine.superstep_rounds,
            prefetch=o.engine.prefetch, impl=o.engine.impl,
            mesh=o.engine.mesh, overlap_eval=o.engine.overlap_eval,
            fused_collective=o.engine.fused_collective,
            sharded_eval=o.engine.sharded_eval,
            ef_store=o.engine.ef_store,
            telemetry=o.engine.telemetry, runlog=o.engine.runlog,
            halt_on_nonfinite=o.engine.halt_on_nonfinite,
            profile_dir=o.engine.profile_dir)
        return self._result

    def evaluate(self, global_state=None, batch=None,
                 max_examples: Optional[int] = None) -> Dict[str, float]:
        """Jitted test metrics of the (last-trained) global model."""
        from repro.fl.server import evaluate
        state = global_state if global_state is not None else self.global_state
        if batch is None:
            batch = self.data.test_batch()
        return evaluate(self.bundle, self.fl, state, batch,
                        max_examples if max_examples is not None
                        else self.options.eval.examples)

    def newclient_probe(self, client_data, *, epochs: int,
                        batch: Optional[int] = None,
                        lr: Optional[float] = None, seed: int = 0,
                        global_state=None):
        """Paper Fig. 6: per-epoch local accuracy of a fresh client that
        adapts from the (last-trained) aggregated global state."""
        from repro.fl.newclient import newclient_convergence
        state = global_state if global_state is not None else self.global_state
        return newclient_convergence(
            self.bundle, self.fl, state, client_data, epochs=epochs,
            batch=batch if batch is not None else self.fl.local_batch,
            lr=lr if lr is not None else self.fl.lr, seed=seed)
