"""Communication-cost accounting.

The paper's metric is *communication rounds to reach an accuracy milestone*;
we additionally account raw bytes (down = global model broadcast, up = local
model + fusion module returns), since FedFusion's fusion module adds a small
upload overhead that the round-count metric hides.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import jax
import numpy as np


def tree_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(tree)))


@dataclass
class CommLog:
    rounds: int = 0
    bytes_up: int = 0
    bytes_down: int = 0
    history: List[Dict] = field(default_factory=list)

    def log_round(self, global_state, n_clients: int, metrics: Dict):
        model_b = tree_bytes(global_state["model"])
        fusion_b = tree_bytes(global_state.get("fusion", ()))
        down = n_clients * model_b          # server -> clients: global model
        up = n_clients * (model_b + fusion_b)  # clients -> server
        self.rounds += 1
        self.bytes_down += down
        self.bytes_up += up
        self.history.append({"round": self.rounds, "bytes_up": up,
                             "bytes_down": down, **metrics})

    def rounds_to(self, key: str, threshold: float) -> int:
        """First round where history[key] >= threshold (-1 if never)."""
        for h in self.history:
            if h.get(key, -np.inf) >= threshold:
                return h["round"]
        return -1
