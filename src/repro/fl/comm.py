"""Communication-cost accounting.

The paper's metric is *communication rounds to reach an accuracy milestone*;
we additionally account raw bytes (down = global model broadcast, up = local
model + fusion module returns), since FedFusion's fusion module adds a small
upload overhead that the round-count metric hides.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import numpy as np


def tree_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree.leaves(tree)))


@dataclass
class CommLog:
    rounds: int = 0
    bytes_up: int = 0
    bytes_down: int = 0
    history: List[Dict] = field(default_factory=list)
    # None until bind_sizes() — honest Optional types keep dataclass
    # introspection (get_type_hints, serializers, repr tooling) truthful
    _model_b: Optional[int] = field(default=None, repr=False)
    _fusion_b: Optional[int] = field(default=None, repr=False)

    def bind_sizes(self, global_state) -> "CommLog":
        """Precompute the model/fusion wire sizes once.

        Parameter shapes are static for a run, but ``tree_bytes`` walks the
        whole pytree; the superstep engine logs rounds in a deferred batch
        (``repro.engine.metrics``), so per-round traversal is pure host
        overhead.  After binding, ``log_round`` accepts
        ``global_state=None``."""
        self._model_b = tree_bytes(global_state["model"])
        self._fusion_b = tree_bytes(global_state.get("fusion", ()))
        return self

    def log_round(self, global_state, n_clients: int, metrics: Dict, *,
                  wire_up: Optional[int] = None,
                  wire_down: Optional[int] = None,
                  n_down: Optional[int] = None,
                  n_up: Optional[int] = None,
                  effective: Optional[Dict] = None):
        """Account one round.

        ``wire_up`` / ``wire_down``: codec-reported bytes per client for the
        model payload (repro.compress).  None falls back to the idealized
        raw fp32 size — the pre-codec behaviour.  FedFusion's fusion module
        crosses the wire uncompressed in BOTH directions (clients receive
        the aggregated module and return their trained copy), so its raw
        size rides along on up and down alike.
        ``n_down``: receivers of the model broadcast; defaults to
        ``n_clients``.  A mirror-based downlink codec is a multicast
        *stream* — every client must hear every round's update to keep its
        mirror current — so the server passes the full federation size
        there, not just the round's sampled clients.  The fusion module is
        only needed by the round's participants, so its raw bytes are
        charged to ``n_clients`` receivers in both directions.
        ``n_up``: uploaders this round; defaults to ``n_clients``.  A
        partial-participation round (deadline / buffered-async policies,
        chaos dropouts) only receives uploads from the clients that
        actually arrived — dropped clients were still *broadcast to*
        (they started the round), so the downlink keeps charging the full
        cohort while the uplink charges ``n_up``.
        ``effective``: the adaptive-compression controller's per-round
        effective codec configuration (``{"level": int, "eff_topk_frac":
        float}`` or ``{"level": int, "eff_quant_bits": int}`` — see
        ``repro.control``); the fields merge into the round record so the
        schedule is replayable from the history.  The ``wire_up`` passed
        alongside is then the LEVEL's effective bytes, not the codec's
        capacity.  None (static runs) keeps the record shape unchanged.
        """
        if global_state is None:
            if self._model_b is None:
                # a real error, not an assert: -O strips asserts, and the
                # deferred MetricsPump would then account garbage sizes
                raise RuntimeError(
                    "CommLog.log_round(global_state=None) requires "
                    "bind_sizes(global_state) to have been called first")
            model_b, fusion_b = self._model_b, self._fusion_b
        else:
            model_b = tree_bytes(global_state["model"])
            fusion_b = tree_bytes(global_state.get("fusion", ()))
        n_down = n_clients if n_down is None else n_down
        down = (n_down * (model_b if wire_down is None else wire_down)
                + n_clients * fusion_b)
        n_up = n_clients if n_up is None else n_up
        up = n_up * ((model_b if wire_up is None else wire_up)
                     + fusion_b)
        self.rounds += 1
        self.bytes_down += down
        self.bytes_up += up
        self.history.append({"round": self.rounds, "bytes_up": up,
                             "bytes_down": down,
                             "bytes_up_ideal": n_clients * (model_b
                                                            + fusion_b),
                             "cum_bytes_up": self.bytes_up,
                             **(effective or {}), **metrics})

    def rounds_to(self, key: str, threshold: float) -> int:
        """First round where history[key] >= threshold (-1 if never)."""
        for h in self.history:
            if h.get(key, -np.inf) >= threshold:
                return h["round"]
        return -1

    def to_records(self) -> List[Dict]:
        """History as plain-JSON round records (numpy scalars/arrays
        converted via ``repro.obs.runlog.json_safe``) plus a final
        ``{"kind": "summary"}`` record with the run totals.  The shared
        shape with RunLog's JSONL stream is what lets
        ``repro.obs.report`` consume both files with one loader.

        Record schema v2: round records MAY carry the adaptive
        controller's per-round effective codec fields (``level`` +
        ``eff_topk_frac`` / ``eff_quant_bits`` — absent on static runs)
        and the summary record carries ``"schema": 2``.  v1 records
        (no ``schema`` key, no effective fields) parse identically —
        every v1 key keeps its name and meaning."""
        from repro.obs.runlog import json_safe
        records = [{"kind": "round",
                    **{k: json_safe(v) for k, v in h.items()}}
                   for h in self.history]
        records.append({"kind": "summary", "schema": 2,
                        "rounds": self.rounds,
                        "bytes_up": self.bytes_up,
                        "bytes_down": self.bytes_down})
        return records

    def save(self, path: str) -> str:
        """Write :meth:`to_records` as JSONL; returns ``path``."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            for rec in self.to_records():
                f.write(json.dumps(rec) + "\n")
        return path
