"""New-client generalization probe (paper Fig. 6).

When a fresh client joins, how many *local epochs* does it need to converge
on its own data, starting from the aggregated global state?  FedFusion's
fusion module gives the newcomer a ready-made mixer between the global
features and its soon-to-be-personal features — the paper's claimed
initialization advantage.

Both the local trainer and the per-epoch evaluation are compiled: the
eval runs through the algorithm plugin's ``deploy_logits`` hook under one
``jax.jit`` (the eval batch shape is fixed across epochs), instead of the
old uncompiled op-by-op ``bundle.apply`` every epoch.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import accuracy, make_local_trainer
from repro.fl.api import make_algorithm
from repro.models.registry import ModelBundle


def newclient_convergence(bundle: ModelBundle, fl: FLConfig, global_state,
                          client_data: Dict[str, np.ndarray], *,
                          epochs: int, batch: int, lr: float,
                          seed: int = 0) -> List[float]:
    """Train locally for ``epochs`` epochs; returns per-epoch local accuracy."""
    rng = np.random.default_rng(seed)
    algo = make_algorithm(fl.algorithm)
    trainer = jax.jit(make_local_trainer(bundle, fl))
    key = "x" if "x" in client_data else "tokens"
    n = len(client_data[key])
    steps = max(n // batch, 1)

    def _epoch_eval(state, eval_batch):
        out = bundle.apply(state["model"], eval_batch)
        logits = algo.deploy_logits(bundle, fl, state, out)
        return accuracy(logits, bundle.labels(eval_batch))

    epoch_eval = jax.jit(_epoch_eval)

    state = {k: v for k, v in global_state.items()}
    accs = []
    eval_batch = {k: jnp.asarray(v) for k, v in client_data.items()}
    for _ in range(epochs):
        idx = rng.permutation(n)[: steps * batch].reshape(steps, batch)
        batches = {k: jnp.asarray(v[idx]) for k, v in client_data.items()}
        trainable, _ = trainer(state["model"], algo.extra_from_state(state),
                               batches, jnp.float32(lr))
        state = {k: trainable[k] for k in ("model",) + algo.extra_state}
        accs.append(float(epoch_eval(state, eval_batch)))
    return accs
