"""New-client generalization probe (paper Fig. 6).

When a fresh client joins, how many *local epochs* does it need to converge
on its own data, starting from the aggregated global state?  FedFusion's
fusion module gives the newcomer a ready-made mixer between the global
features and its soon-to-be-personal features — the paper's claimed
initialization advantage.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import accuracy, make_local_trainer
from repro.core.fusion import fusion_apply
from repro.models.registry import ModelBundle


def newclient_convergence(bundle: ModelBundle, fl: FLConfig, global_state,
                          client_data: Dict[str, np.ndarray], *,
                          epochs: int, batch: int, lr: float,
                          seed: int = 0) -> List[float]:
    """Train locally for ``epochs`` epochs; returns per-epoch local accuracy."""
    rng = np.random.default_rng(seed)
    trainer = jax.jit(make_local_trainer(bundle, fl))
    key = "x" if "x" in client_data else "tokens"
    n = len(client_data[key])
    steps = max(n // batch, 1)

    state = {k: v for k, v in global_state.items()}
    accs = []
    eval_batch = {k: jnp.asarray(v) for k, v in client_data.items()}
    for _ in range(epochs):
        idx = rng.permutation(n)[: steps * batch].reshape(steps, batch)
        batches = {k: jnp.asarray(v[idx]) for k, v in client_data.items()}
        trainable, _ = trainer(state["model"], state.get("fusion"), batches,
                               jnp.float32(lr))
        state = {"model": trainable["model"]}
        if fl.algorithm == "fedfusion":
            state["fusion"] = trainable["fusion"]
        out = bundle.apply(state["model"], eval_batch)
        logits = out["logits"]
        if fl.algorithm == "fedfusion":
            fused = fusion_apply(fl.fusion_op, state["fusion"],
                                 out["features"], out["features"])
            logits = bundle.head(state["model"], fused)
        accs.append(float(accuracy(logits, bundle.labels(eval_batch))))
    return accs
