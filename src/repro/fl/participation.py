"""Participation policies: who makes it into a round, and at what weight.

The engine is synchronous at the tensor level — every round aggregates a
fixed-shape ``[C', ...]`` cohort in one psum — but *which* of those C'
lanes actually contribute, and with what weight, is decided per round on
the host by a :class:`ParticipationPolicy`.  A policy looks at the
round's simulated arrival times / dropouts (the chaos draws produced by
``FederatedDataset.chaos_round``; see ``repro.data.federated``) and
returns a :class:`RoundParticipation`: a 0/1 contribution mask, a
per-client staleness (in units of the round's closing time), the
staleness weight applied to each contribution, and the simulated
wall-clock the round took.

Masked clients are zeroed *by weight* inside the existing fused one-psum
— no shape changes, no extra collectives — and their error-feedback
residual is carried forward untouched (``core.rounds`` guards the EF
update with the mask).  Staleness weights are folded into the example
weights on the host (``sizes * mask * weight``), so the normalized
weighted mean downstream is exactly the staleness-discounted FedBuff-style
average; the psum-completed loss / staleness *metrics* are finalized in
the post-psum ``finish_fn``.

Built-in policies (registered under ``register_policy`` /
``make_policy``, mirroring ``make_algorithm`` / ``make_codec``):

``full_sync``
    Today's behavior and the bitwise oracle: the round closes when the
    slowest surviving client reports.  With chaos off this is the exact
    pre-participation engine (the engine skips participation plumbing
    entirely, so the traced computation is byte-identical).

``deadline``
    Over-provision the cohort to C' = ceil(C * fl.over_provision) and
    close the round when the first C surviving clients arrive; the
    laggards' weight is zeroed and their EF state is untouched.

``buffered_async``
    FedBuff-style buffered aggregation, simulated statelessly per round:
    the round closes when K of C contributions land (K =
    ``fl.buffer_k`` or C//2); later arrivals still contribute but are
    staleness-discounted by ``(1 + s)^(-fl.staleness_alpha)`` where
    ``s`` is how many round-durations late they landed.  This is the
    standard weight-based simulation of an async buffer — contributions
    stay in their own round (static shapes, one psum) while carrying the
    staleness discount an async server would apply.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Policy protocol + registry


@dataclass(frozen=True)
class RoundParticipation:
    """Host-side outcome of one round's participation decision.

    ``mask``/``staleness``/``weight`` are float32 ``[cohort]`` arrays;
    ``round_time`` is the simulated wall-clock of the round (in units of
    a nominal client round: arrival time 1.0 == a median client with no
    jitter); ``n_arrived`` is ``int(mask.sum())``.
    """

    mask: np.ndarray
    staleness: np.ndarray
    weight: np.ndarray
    round_time: float
    n_arrived: int


class ParticipationPolicy:
    """Base class: subclass, set ``name``, implement ``select``."""

    name: str = ""

    def cohort_size(self, clients_per_round: int, fl) -> int:
        """How many clients to sample per round (>= clients_per_round)."""
        return clients_per_round

    def select(self, arrival: np.ndarray, dropped: np.ndarray, fl,
               n_target: int) -> RoundParticipation:
        """Decide the round from simulated arrivals.

        ``arrival``: float ``[cohort]`` simulated completion times (chaos
        draws; all-ones when chaos is off).  ``dropped``: bool
        ``[cohort]``.  ``n_target`` is the pre-over-provision C.
        """
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    @staticmethod
    def _surviving(arrival: np.ndarray, dropped: np.ndarray) -> np.ndarray:
        """Bool alive-mask; guarantees at least one survivor (the fastest
        client is un-dropped), so the round's weight total is never zero."""
        alive = ~np.asarray(dropped, bool)
        if not alive.any():
            alive = alive.copy()
            alive[int(np.argmin(arrival))] = True
        return alive


Factory = Callable[[], ParticipationPolicy]

_REGISTRY: Dict[str, Factory] = {}
_BUILTINS_REGISTERED = False


def register_policy(name: str, factory: Factory, *, overwrite: bool = False) -> None:
    """Register a participation-policy factory under ``name``."""
    _ensure_builtins()
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"participation policy {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    _REGISTRY[name] = factory


def make_policy(name: str) -> ParticipationPolicy:
    """Instantiate a registered participation policy by name."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise ValueError(f"unknown participation policy {name!r}; "
                         f"registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def registered_policies() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Built-in policies


class FullSyncPolicy(ParticipationPolicy):
    """Wait for everyone who did not drop; no staleness, no discount."""

    name = "full_sync"

    def select(self, arrival, dropped, fl, n_target):
        arrival = np.asarray(arrival, np.float32)
        alive = self._surviving(arrival, dropped)
        mask = alive.astype(np.float32)
        zeros = np.zeros_like(mask)
        return RoundParticipation(
            mask=mask, staleness=zeros, weight=np.ones_like(mask),
            round_time=float(arrival[alive].max()),
            n_arrived=int(alive.sum()))


class DeadlinePolicy(ParticipationPolicy):
    """Over-provision to C' > C; close when the first C survivors arrive."""

    name = "deadline"

    def cohort_size(self, clients_per_round, fl):
        over = float(getattr(fl, "over_provision", 1.0))
        return max(clients_per_round,
                   int(np.ceil(clients_per_round * over)))

    def select(self, arrival, dropped, fl, n_target):
        arrival = np.asarray(arrival, np.float32)
        alive = self._surviving(arrival, dropped)
        k = min(int(n_target), int(alive.sum()))
        # stable argsort: with chaos off every arrival is 1.0 and the
        # first C positions win deterministically.
        order = np.argsort(arrival, kind="stable")
        chosen = np.zeros(arrival.shape[0], bool)
        taken = 0
        for i in order:
            if alive[i]:
                chosen[i] = True
                taken += 1
                if taken == k:
                    break
        mask = chosen.astype(np.float32)
        zeros = np.zeros_like(mask)
        return RoundParticipation(
            mask=mask, staleness=zeros, weight=np.ones_like(mask),
            round_time=float(arrival[chosen].max()),
            n_arrived=int(chosen.sum()))


class BufferedAsyncPolicy(ParticipationPolicy):
    """Close at the K-th arrival; discount laggards by staleness."""

    name = "buffered_async"

    def select(self, arrival, dropped, fl, n_target):
        arrival = np.asarray(arrival, np.float32)
        alive = self._surviving(arrival, dropped)
        buffer_k = int(getattr(fl, "buffer_k", 0)) or max(1, n_target // 2)
        k = min(buffer_k, int(alive.sum()))
        t_close = float(np.sort(arrival[alive])[k - 1])
        # how many round-durations past the close each contribution lands
        staleness = np.where(
            alive, np.maximum(arrival / max(t_close, 1e-9) - 1.0, 0.0),
            0.0).astype(np.float32)
        alpha = float(getattr(fl, "staleness_alpha", 0.5))
        weight = ((1.0 + staleness) ** (-alpha)).astype(np.float32)
        mask = alive.astype(np.float32)
        return RoundParticipation(
            mask=mask, staleness=staleness, weight=weight,
            round_time=t_close, n_arrived=int(alive.sum()))


def _ensure_builtins() -> None:
    global _BUILTINS_REGISTERED
    if _BUILTINS_REGISTERED:
        return
    _BUILTINS_REGISTERED = True
    _REGISTRY["full_sync"] = FullSyncPolicy
    _REGISTRY["deadline"] = DeadlinePolicy
    _REGISTRY["buffered_async"] = BufferedAsyncPolicy
