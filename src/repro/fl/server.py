"""Federated server loop (paper Alg. 1 / Alg. 2) for CPU-scale experiments.

``run_federated`` is the back-compat flat-kwarg wrapper over
:class:`repro.fl.api.FederatedTrainer`, which drives the device-resident
engine (``repro.engine``): a jitted K-round superstep scans the per-round
step on device with donated buffers and on-device error-feedback scatter,
a prefetch thread stages the next chunk's batches, and metrics come back
as futures.  The pre-engine one-round-at-a-time loop is preserved verbatim
as ``run_federated_reference`` — it is the equivalence oracle for the
engine tests and the baseline ``benchmarks/bench_engine.py`` measures
speedups against.  The pod-scale counterpart (pjit on the production mesh)
lives in ``repro.launch.train``.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import make_codec
from repro.configs.base import FLConfig
from repro.core import accuracy, cross_entropy, init_global_state, make_round_fn
from repro.core.rounds import make_compressed_round_fn
from repro.data.federated import FederatedDataset
from repro.engine import ServerResult, make_eval_fn, pad_eval_batch
from repro.fl.api import (CheckpointOptions, EngineOptions, EvalOptions,
                          FederatedTrainer, RunOptions, make_algorithm)
from repro.fl.comm import CommLog
from repro.models.registry import ModelBundle
from repro.optim import exp_decay_per_round

__all__ = ["ServerResult", "evaluate", "run_federated",
           "run_federated_reference"]

# jitted evaluators, keyed by (bundle identity, algorithm, fusion_op); the
# value keeps a strong ref to the bundle so the id() key stays valid.
_EVAL_CACHE: Dict = {}


def _jitted_eval(bundle: ModelBundle, fl: FLConfig):
    key = (id(bundle), fl.algorithm, fl.fusion_op)
    hit = _EVAL_CACHE.get(key)
    if hit is None or hit[0] is not bundle:
        while len(_EVAL_CACHE) >= 64:    # evict oldest, keep the hot set
            _EVAL_CACHE.pop(next(iter(_EVAL_CACHE)))
        hit = (bundle, jax.jit(make_eval_fn(bundle, fl)))
        _EVAL_CACHE[key] = hit
    return hit[1]


def evaluate(bundle: ModelBundle, fl: FLConfig, global_state, batch,
             max_examples: int = 2048) -> Dict[str, float]:
    """Test accuracy of the *global* model (paper's y-axis) — compiled.

    The batch is padded to a fixed power-of-two bucket with a validity
    mask (``repro.engine.pad_eval_batch``) so one jitted evaluator serves
    any test-set size; masked means equal the unpadded metrics exactly.
    For FedFusion the deployed global model fuses its own features with
    itself through the aggregated fusion module (E_g = E_l = global).
    """
    padded, mask = pad_eval_batch(batch, max_examples)
    out = _jitted_eval(bundle, fl)(global_state, padded, mask)
    return {k: float(v) for k, v in out.items()}


def _evaluate_eager(bundle: ModelBundle, fl: FLConfig, global_state, batch,
                    max_examples: int = 2048) -> Dict[str, float]:
    """The pre-engine evaluator: uncompiled ``bundle.apply`` on the raw
    batch.  Kept as the op-by-op oracle for the jitted path and as the
    faithful baseline cost model in ``benchmarks/bench_engine.py``."""
    key = "x" if "x" in batch else "tokens"
    n = min(len(batch[key]), max_examples)
    batch = {k: jnp.asarray(v[:n]) for k, v in batch.items()}
    out = bundle.apply(global_state["model"], batch)
    logits = make_algorithm(fl.algorithm).deploy_logits(
        bundle, fl, global_state, out)
    labels = bundle.labels(batch)
    return {"acc": float(accuracy(logits, labels)),
            "loss": float(cross_entropy(logits, labels))}


def run_federated(bundle: ModelBundle, fl: FLConfig, data: FederatedDataset,
                  *, rounds: int, seed: int = 0, mode: str = "client_parallel",
                  eval_every: int = 1, eval_examples: int = 2048,
                  verbose: bool = False,
                  checkpoint_dir: Optional[str] = None,
                  checkpoint_every: int = 10,
                  callback: Optional[Callable] = None,
                  superstep_rounds=8,
                  prefetch: bool = True, mesh=None,
                  overlap_eval: bool = True,
                  fused_collective: bool = True,
                  sharded_eval: bool = True,
                  ef_store: str = "auto",
                  telemetry=False, runlog=None,
                  halt_on_nonfinite: bool = False,
                  profile_dir: Optional[str] = None) -> ServerResult:
    """Back-compat wrapper over :class:`repro.fl.api.FederatedTrainer`.

    The flat kwargs map 1:1 onto the grouped ``RunOptions`` fields (see
    the README's migration table); new code should build the options and
    use the facade directly.  Behaviour is identical — the facade drives
    the same engine (``repro.engine``): checkpoint-resume, superstep
    chunking (``"auto"`` calibration), prefetch staging, client-parallel
    ``shard_map`` under ``mesh``, snapshot-overlapped boundary eval.  On
    a single device the results are identical to
    :func:`run_federated_reference` on the same seed/config.

    Partial participation (``fl.participation`` other than ``full_sync``,
    or a chaos-configured ``data``) and ``halt_on_nonfinite`` are
    engine-only robustness features — the reference loop predates them
    and refuses such configs rather than silently diverging.
    """
    opts = RunOptions(
        mode=mode, seed=seed, verbose=verbose,
        eval=EvalOptions(every=eval_every, examples=eval_examples),
        checkpoint=CheckpointOptions(dir=checkpoint_dir,
                                     every=checkpoint_every),
        engine=EngineOptions(superstep_rounds=superstep_rounds,
                             prefetch=prefetch, mesh=mesh,
                             overlap_eval=overlap_eval,
                             fused_collective=fused_collective,
                             sharded_eval=sharded_eval, ef_store=ef_store,
                             telemetry=telemetry, runlog=runlog,
                             halt_on_nonfinite=halt_on_nonfinite,
                             profile_dir=profile_dir))
    return FederatedTrainer(bundle, fl, data, opts).fit(rounds,
                                                        callback=callback)


def run_federated_reference(bundle: ModelBundle, fl: FLConfig,
                            data: FederatedDataset, *, rounds: int,
                            seed: int = 0, mode: str = "client_parallel",
                            eval_every: int = 1, eval_examples: int = 2048,
                            verbose: bool = False,
                            checkpoint_dir: Optional[str] = None,
                            checkpoint_every: int = 10,
                            callback: Optional[Callable] = None,
                            eval_fn: Callable = None) -> ServerResult:
    """The pre-engine server loop, one Python-dispatched round at a time.

    Preserved as (a) the equivalence oracle the engine is tested against —
    same rng streams, same per-round math, bitwise-equal final model at
    chunk size 1 — and (b) the baseline ``benchmarks/bench_engine.py``
    times (pass ``eval_fn=_evaluate_eager`` there to reproduce the
    pre-engine cost model, uncompiled eval included).  ``eval_fn`` defaults
    to the jitted :func:`evaluate` so reference and engine histories match
    exactly.
    """
    from repro.checkpoint.io import (load_tree, restore_server_state,
                                     save_server_state, save_tree)

    if getattr(data, "chaos", None) is not None \
            or getattr(fl, "participation", "full_sync") != "full_sync":
        raise NotImplementedError(
            "partial participation / chaos injection is an engine feature "
            "(repro.engine); the reference loop has no fault schedule and "
            "would silently diverge from the engine's rng stream")
    if getattr(fl, "controller", "static") != "static":
        raise NotImplementedError(
            "adaptive compression controllers are an engine feature "
            "(repro.control rides the superstep scan carry); the reference "
            "loop only runs the static codec configuration")
    if eval_fn is None:
        eval_fn = evaluate
    key = jax.random.PRNGKey(seed)
    global_state = init_global_state(bundle, fl, key)
    start_round = 0
    if checkpoint_dir and os.path.exists(
            os.path.join(checkpoint_dir, "meta.json")):
        global_state, start_round = restore_server_state(checkpoint_dir,
                                                         global_state)
        global_state = jax.tree.map(jnp.asarray, global_state)
        # same stream replay as the engine: resumed == uninterrupted
        data.skip_round_sampling(start_round, fl.clients_per_round,
                                 fl.local_steps, fl.local_batch)
    lr_at = exp_decay_per_round(fl.lr, fl.lr_decay)
    comm = CommLog()
    test = data.test_batch()

    # --- wire codecs (repro.compress) ---------------------------------
    compressed = fl.compressed
    wire_up = wire_down = None
    if compressed:
        uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac,
                            quant_bits=fl.quant_bits)
        downlink = make_codec(fl.downlink_codec, topk_frac=fl.topk_frac,
                              quant_bits=fl.quant_bits)
        uplink.bind(global_state["model"])
        downlink.bind(global_state["model"])
        wire_up = uplink.wire_bytes()
        wire_down = downlink.wire_bytes()
        round_fn = jax.jit(make_compressed_round_fn(bundle, fl, mode,
                                                    uplink, downlink))
        # per-client uplink EF residuals + the clients' broadcast-mirror,
        # persisted across rounds (and checkpoints)
        ef_template = uplink.init_state()
        ef_all = jax.tree.map(
            lambda z: np.zeros((data.n_clients,) + z.shape,
                               np.dtype(z.dtype)), ef_template)
        down_mirror = global_state["model"]
        ef_path = (os.path.join(checkpoint_dir, "ef.npz")
                   if checkpoint_dir else None)
        if start_round and ef_path and os.path.exists(ef_path):
            ef_all, down_mirror = load_tree(ef_path,
                                            (ef_all, down_mirror))
        round_key = jax.random.fold_in(key, 0x636f6d70)  # "comp"
    else:
        round_fn = jax.jit(make_round_fn(bundle, fl, mode))

    for r in range(start_round, rounds):
        cids = data.sample_clients(fl.clients_per_round)
        batches, sizes = data.round_batch(cids, fl.local_steps,
                                          fl.local_batch)
        batches = {k: jnp.asarray(v) for k, v in batches.items()}
        if compressed:
            ef_round = jax.tree.map(lambda a: jnp.asarray(a[cids]), ef_all)
            global_state, metrics, new_ef, down_mirror = round_fn(
                global_state, batches, jnp.asarray(sizes), lr_at(r),
                ef_round, down_mirror, jax.random.fold_in(round_key, r))
            for dst, src in zip(jax.tree_util.tree_leaves(ef_all),
                                jax.tree_util.tree_leaves(new_ef)):
                dst[np.asarray(cids)] = np.asarray(src)
        else:
            global_state, metrics = round_fn(global_state, batches,
                                             jnp.asarray(sizes), lr_at(r))
        metrics = {k: float(v) for k, v in metrics.items()}
        if (r + 1) % eval_every == 0:
            metrics.update(eval_fn(bundle, fl, global_state, test,
                                   eval_examples))
        comm.log_round(global_state, len(cids), metrics,
                       wire_up=wire_up, wire_down=wire_down,
                       n_down=(data.n_clients
                               if fl.downlink_codec != "identity" else None))
        if verbose:
            print(f"round {r+1:4d} " +
                  " ".join(f"{k}={v:.4f}" for k, v in metrics.items()))
        if callback is not None:
            callback(r, global_state, metrics)
        if checkpoint_dir and (r + 1) % checkpoint_every == 0:
            save_server_state(checkpoint_dir, global_state, r + 1,
                              extra={"algorithm": fl.algorithm})
            if compressed:
                save_tree(ef_path, (ef_all, down_mirror))
    if checkpoint_dir:
        save_server_state(checkpoint_dir, global_state, rounds,
                          extra={"algorithm": fl.algorithm})
        if compressed:
            save_tree(ef_path, (ef_all, down_mirror))
    return ServerResult(global_state=global_state, comm=comm)
