"""Pallas TPU kernels for the ``repro.compress`` wire hot paths.

Three kernels back the codec subsystem (oracles in ``kernels/ref.py``):

* ``quant_pack``   — fused stochastic-quantize + bit-pack: fp32 deltas are
  scaled, stochastically rounded (the uniform offsets arrive as an input so
  the kernel stays deterministic and vmap/test friendly) and written as int8
  codes, or as two 4-bit nibbles per uint8 for ``bits=4``.  One pass over
  the tensor, no intermediate integer tensor in HBM.
* ``quant_unpack`` — scatter-unpack: codes -> fp32, nibble split for int4.
* ``topk_select``  — magnitude threshold select ``x * (|x| >= t)``: the
  dense decode∘encode of top-k sparsification, used to form the error-
  feedback residual without materialising gather/scatter indices.
* ``ef_gather`` / ``ef_scatter`` — row gather/scatter for the device-
  resident per-client error-feedback table (``repro.engine``): the full-
  federation EF tree lives flattened as [n_clients, n] and each round
  pulls/pushes only the sampled clients' rows.  The sampled client ids are
  SCALAR-PREFETCH operands (``pltpu.PrefetchScalarGridSpec``): the block
  index maps read ``cids[i]`` before the kernel body runs, so the row
  index feeds the DMA engine directly — each grid step is one HBM<->VMEM
  row copy with no in-kernel address computation, which is what lets the
  kernels compile TPU-native (the pre-prefetch version read the index
  from an ANY-memory ref inside the body and could only interpret).
  ``ef_scatter`` aliases the table input to its output
  (``input_output_aliases``) so the update is in-place — no
  [n_clients, n]-sized copy per round, which is the whole point of
  keeping EF on device.

All kernels view the flat tensor as [rows, 128] lanes and run a 1-D grid
over row blocks; wrappers pad to tile multiples and slice the result back,
so callers see exact flat shapes.  On CPU they run with ``interpret=True``
(the jnp reference is the production CPU path — see ``kernels/ops.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
BLOCK_ROWS = 8          # 8 x 128 fp32 tile per grid step


def _pad_rows(flat, lanes, block_rows, fill):
    """[n] -> [R, lanes] with R a multiple of block_rows."""
    n = flat.shape[0]
    per = lanes * block_rows
    pad = (-n) % per
    if pad:
        flat = jnp.pad(flat, (0, pad), constant_values=fill)
    return flat.reshape(-1, lanes)


# ---------------------------------------------------------------------------
# quantize + pack
# ---------------------------------------------------------------------------

def _quant_pack_kernel(x_ref, noise_ref, scale_ref, out_ref, *, bits):
    qmax = 127 if bits == 8 else 7
    x = x_ref[...].astype(jnp.float32)
    q = jnp.floor(x / scale_ref[0] + noise_ref[...].astype(jnp.float32))
    q = jnp.clip(q, -qmax, qmax)
    if bits == 8:
        out_ref[...] = q.astype(jnp.int8)
    else:
        u = (q + 8.0).astype(jnp.uint8)
        r, c = u.shape
        u = u.reshape(r, c // 2, 2)
        out_ref[...] = u[:, :, 0] | (u[:, :, 1] << 4)


def quant_pack(x, scale, noise, *, bits=8, interpret=True):
    """x [n] float, noise [n] in [0,1), scale scalar -> packed codes.

    int8: int8 [n].  int4: uint8 [n/2] (n must be even), element 2i in the
    low nibble — the exact wire format of ``ref.quant_pack_ref``.
    """
    if bits not in (4, 8):
        raise ValueError(f"quant_pack bits={bits!r} must be 4 or 8")
    n = x.shape[0]
    if bits == 4:
        if n % 2:
            raise ValueError("int4 pack needs an even element count, "
                             f"got {n}")
    xr = _pad_rows(x.astype(jnp.float32), LANES, BLOCK_ROWS, 0.0)
    nr = _pad_rows(noise.astype(jnp.float32), LANES, BLOCK_ROWS, 0.5)
    rows = xr.shape[0]
    grid = (rows // BLOCK_ROWS,)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    out_lanes = LANES if bits == 8 else LANES // 2
    out_dtype = jnp.int8 if bits == 8 else jnp.uint8
    packed = pl.pallas_call(
        functools.partial(_quant_pack_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, out_lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, out_lanes), out_dtype),
        interpret=interpret,
    )(xr, nr, scale)
    m = n if bits == 8 else n // 2
    return packed.reshape(-1)[:m]


# ---------------------------------------------------------------------------
# unpack
# ---------------------------------------------------------------------------

def _quant_unpack_kernel(q_ref, scale_ref, out_ref, *, bits):
    scale = scale_ref[0]
    q = q_ref[...]
    if bits == 8:
        out_ref[...] = q.astype(jnp.float32) * scale
    else:
        low = (q & 0xF).astype(jnp.int32) - 8
        high = ((q >> 4) & 0xF).astype(jnp.int32) - 8
        r, c = q.shape
        inter = jnp.stack([low, high], axis=-1).reshape(r, 2 * c)
        out_ref[...] = inter.astype(jnp.float32) * scale


def quant_unpack(packed, scale, *, bits=8, n=None, interpret=True):
    """Packed codes -> fp32 [n] (inverse of :func:`quant_pack`)."""
    if bits not in (4, 8):
        raise ValueError(f"quant_unpack bits={bits!r} must be 4 or 8")
    m = packed.shape[0]
    n = (m if bits == 8 else 2 * m) if n is None else n
    in_lanes = LANES if bits == 8 else LANES // 2
    qr = _pad_rows(packed, in_lanes, BLOCK_ROWS,
                   0 if bits == 8 else 0x88)       # 0x88 = (8,8) = zeros
    rows = qr.shape[0]
    grid = (rows // BLOCK_ROWS,)
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_quant_unpack_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, in_lanes), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(qr, scale)
    return out.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# top-k threshold select
# ---------------------------------------------------------------------------

def _topk_select_kernel(x_ref, thresh_ref, out_ref):
    x = x_ref[...]
    keep = jnp.abs(x) >= thresh_ref[0]
    out_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))


def _ef_cols(table):
    """[N, ...] -> ([N, cols] fp32 lane-padded view, n, trailing shape)."""
    N = table.shape[0]
    trail = table.shape[1:]
    flat = table.reshape(N, -1)
    n = flat.shape[1]
    pad = (-n) % LANES
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    return flat, n, trail


def _ef_gather_kernel(idx_ref, table_ref, out_ref):
    del idx_ref    # consumed by the index maps (scalar prefetch)
    out_ref[...] = table_ref[...]


def ef_gather(table, idx, *, interpret=True):
    """table [N, ...], idx [k] int -> the idx rows as [k, ...].

    Grid over the k sampled clients with ``idx`` scalar-prefetched: the
    input index map selects table row ``idx[i]`` for grid step i, so the
    DMA engine streams exactly the sampled rows HBM->VMEM and the body is
    a pure row copy."""
    flat, n, trail = _ef_cols(table)
    cols = flat.shape[1]
    k = idx.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[pl.BlockSpec((1, cols), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, cols), lambda i, idx_ref: (i, 0)),
    )
    out = pl.pallas_call(
        _ef_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, cols), flat.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), flat)
    return out[:, :n].reshape((k,) + trail)


def _ef_scatter_kernel(idx_ref, rows_ref, table_ref, out_ref):
    del idx_ref, table_ref   # idx: index maps; table: aliased, never read
    out_ref[...] = rows_ref[...]


def ef_scatter(table, idx, rows, *, interpret=True):
    """Write rows [k, ...] into table [N, ...] at idx — in place.

    The table is donated into the kernel via ``input_output_aliases`` (the
    aliased operand never enters the body — untouched N-k rows are never
    copied) and ``idx`` is scalar-prefetched: the OUTPUT index map routes
    grid step i's row block to table row ``idx[i]``, so the writeback is
    a direct VMEM->HBM row DMA.  ``idx`` must be unique (the federated
    sampler asserts this); duplicate rows would race.
    """
    flat, n, trail = _ef_cols(table)
    cols = flat.shape[1]
    k = idx.shape[0]
    rflat = rows.reshape(k, -1).astype(flat.dtype)
    if cols != rflat.shape[1]:
        rflat = jnp.pad(rflat, ((0, 0), (0, cols - rflat.shape[1])))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, cols), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, cols), lambda i, idx_ref: (idx_ref[i], 0)),
    )
    out = pl.pallas_call(
        _ef_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(idx.astype(jnp.int32), rflat, flat)
    return out[:, :n].reshape(table.shape)


def topk_select(x, thresh, *, interpret=True):
    """x [n], thresh scalar -> x masked to entries with |x| >= thresh."""
    n = x.shape[0]
    xr = _pad_rows(x.astype(jnp.float32), LANES, BLOCK_ROWS, 0.0)
    rows = xr.shape[0]
    thresh = jnp.asarray(thresh, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _topk_select_kernel,
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(xr, thresh)
    return out.reshape(-1)[:n].astype(x.dtype)
