"""Pallas TPU kernel: GQA flash-decode (one query token vs a long KV cache).

Decode attention is memory-bound: the whole KV cache streams once through
VMEM per step.  The kernel blocks the cache length, keeps the online-softmax
running (m, l, acc) state in VMEM scratch, and writes the normalised output
on the last cache block.  Grid = (batch, kv_head, cache_blocks); the
rep = H/KV query heads of a KV group are processed together so each K/V tile
is read exactly once (the GQA bandwidth win).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_L = 512


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, block_l, n_blocks, scale):
    b = pl.program_id(0)   # noqa: F841  (batch handled by BlockSpec)
    g = pl.program_id(1)   # noqa: F841
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)         # [rep, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)         # [Lb, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)         # [Lb, hd]
    valid_len = valid_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = j * block_l + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < valid_len, s, -1e30)

    m_prev = m_ref[...]                            # [rep]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, valid_len=None, *, block_l=BLOCK_L,
                 interpret=True):
    """q [B,1,H,hd]; caches [B,L,KV,hd] -> [B,1,H,hd]."""
    B, _, H, hd = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    bl = min(block_l, L)
    pad = (-L) % bl
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    n_blocks = Lp // bl
    if valid_len is None:
        valid_len = L
    valid = jnp.asarray(valid_len, jnp.int32).reshape(1)

    qh = q.reshape(B, 1, KV, rep, hd).transpose(0, 2, 1, 3, 4)  # [B,KV,1,rep,hd]
    kernel = functools.partial(_decode_kernel, block_l=bl, n_blocks=n_blocks,
                               scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, 1, rep, hd), lambda b, g, j: (b, g, 0, 0, 0)),
            pl.BlockSpec((1, bl, 1, hd), lambda b, g, j: (b, j, g, 0)),
            pl.BlockSpec((1, bl, 1, hd), lambda b, g, j: (b, j, g, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, g, j: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep,), jnp.float32),
            pltpu.VMEM((rep, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, k_cache, v_cache, valid)
    return out.reshape(B, 1, H, hd)
