"""Pallas TPU kernel: GQA flash attention with a flash BACKWARD pass.

The §Perf analysis (EXPERIMENTS.md, hillclimb 1) showed that the jnp
scan-based flash attention materialises its probability tiles as scan
residuals under autodiff — the S x S score matrix hits HBM in the
backward even under remat, which is the dominant memory term of every
train_4k pair.  The fix is this kernel: forward and backward are
custom-calls whose probability tiles live only in VMEM, so HBM traffic
is O(S·d) (q, k, v, o, do, dq, dk, dv and the [S]-sized softmax stats).

Layout follows kernels/decode_attn.py: grid over (batch, kv_head,
outer block, inner block) with VMEM scratch carrying the online-softmax
state across the innermost grid axis; the rep = H/KV query heads of a
KV group are processed together so each K/V tile is read once per group.

Backward math (standard flash, Dao et al.):
    p_ij = exp(s_ij - lse_i)
    dv_j = sum_i p_ij^T do_i
    dp   = do_i v_j^T
    ds   = p ∘ (dp - D_i),  D_i = rowsum(do_i ∘ o_i)
    dq_i = sum_j ds k_j * scale
    dk_j = sum_i ds^T q_i * scale

Three pallas_calls: forward (o, lse), dq (inner loop over kv blocks),
dkv (inner loop over q blocks).  Causal + sliding-window masks are
applied by position arithmetic inside the tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
Q_BLOCK = 128
KV_BLOCK = 128


def _mask(q_pos, k_pos, *, causal, window, seq_len):
    m = k_pos[None, :] < seq_len
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m  # [qb, kb]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                *, scale, causal, window, q_block, kv_block, n_kv, seq_len):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)        # [qb, rep, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)        # [kb, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)        # [kb, hd]

    q_pos = i * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, 1), 0)[:, 0]
    k_pos = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, (kv_block, 1), 0)[:, 0]
    mask = _mask(q_pos, k_pos, causal=causal, window=window, seq_len=seq_len)

    # s [rep, qb, kb]
    s = jax.lax.dot_general(q.transpose(1, 0, 2), k,
                            (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_ref[...]                           # [rep, qb]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0] = (acc_ref[...] / l[..., None]).transpose(1, 0, 2) \
            .astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_fwd(q, k, v, *, scale, causal, window, q_block, kv_block,
              interpret):
    B, S0, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qb = min(q_block, S0)
    kb = min(kv_block, S0)
    q = _pad_to(q, 1, qb)
    k = _pad_to(k, 1, kb)
    v = _pad_to(v, 1, kb)
    Sq, Sk = q.shape[1], k.shape[1]
    nq, nk = Sq // qb, Sk // kb

    qh = q.reshape(B, Sq, KV, rep, hd)   # BlockSpec maps (b, i, g, 0, 0)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_block=qb, kv_block=kb, n_kv=nk, seq_len=S0)
    o, lse = pl.pallas_call(
        kernel,
        grid=(B, KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, 1, rep, hd), lambda b, g, i, j: (b, i, g, 0, 0)),
            pl.BlockSpec((1, kb, 1, hd), lambda b, g, i, j: (b, j, g, 0)),
            pl.BlockSpec((1, kb, 1, hd), lambda b, g, i, j: (b, j, g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qb, 1, rep, hd), lambda b, g, i, j: (b, i, g, 0, 0)),
            pl.BlockSpec((1, 1, rep, qb), lambda b, g, i, j: (b, g, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq, KV, rep, hd), q.dtype),
            jax.ShapeDtypeStruct((B, KV, rep, nq * qb), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rep, qb), jnp.float32),
            pltpu.VMEM((rep, qb), jnp.float32),
            pltpu.VMEM((rep, qb, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, k, v)
    o = o.reshape(B, Sq, H, hd)[:, :S0]
    return o, lse  # lse [B, KV, rep, Sq]


# ---------------------------------------------------------------------------
# Backward: dq  (grid inner axis = kv blocks)
# ---------------------------------------------------------------------------

def _dq_kernel_real(q_ref, k_ref, v_ref, do_ref, lse_ref, dcap_ref, dq_ref,
                    acc_ref, *, scale, causal, window, q_block, kv_block,
                    n_kv, seq_len):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32).transpose(1, 0, 2)   # [rep, qb, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)                      # [kb, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)                      # [kb, hd]
    do = do_ref[0, :, 0].astype(jnp.float32).transpose(1, 0, 2)
    lse = lse_ref[0, 0]                                         # [rep, qb]
    dcap = dcap_ref[0, 0]                                       # [rep, qb]

    q_pos = i * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, 1), 0)[:, 0]
    k_pos = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, (kv_block, 1), 0)[:, 0]
    mask = _mask(q_pos, k_pos, causal=causal, window=window, seq_len=seq_len)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])                             # [rep, qb, kb]
    dp = jax.lax.dot_general(do, v, (((2,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dcap[..., None])                             # [rep, qb, kb]
    acc_ref[...] += jax.lax.dot_general(
        ds, k, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(j == n_kv - 1)
    def _finish():
        dq_ref[0, :, 0] = acc_ref[...].transpose(1, 0, 2).astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# Backward: dk, dv  (grid inner axis = q blocks)
# ---------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dcap_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale, causal, window, q_block, kv_block, n_q, seq_len):
    j = pl.program_id(2)   # kv block (outer)
    i = pl.program_id(3)   # q block (inner)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, :, 0].astype(jnp.float32).transpose(1, 0, 2)   # [rep, qb, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)                      # [kb, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)
    do = do_ref[0, :, 0].astype(jnp.float32).transpose(1, 0, 2)
    lse = lse_ref[0, 0]
    dcap = dcap_ref[0, 0]

    q_pos = i * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, 1), 0)[:, 0]
    k_pos = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, (kv_block, 1), 0)[:, 0]
    mask = _mask(q_pos, k_pos, causal=causal, window=window, seq_len=seq_len)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])                             # [rep, qb, kb]

    # dv_j += sum_rep p^T do : contract rep+qb
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32)                     # [kb, hd]
    dp = jax.lax.dot_general(do, v, (((2,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dcap[..., None])
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((0, 1), (0, 1)), ((), ())),
        preferred_element_type=jnp.float32) * scale             # [kb, hd]

    @pl.when(i == n_q - 1)
    def _finish():
        dk_ref[0, :, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0] = dv_acc[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------

def make_flash_attention(*, causal=True, window: Optional[int] = None,
                         q_block=Q_BLOCK, kv_block=KV_BLOCK,
                         interpret=True):
    """Returns flash(q, k, v) -> o with a flash (tile-recompute) backward.

    q [B,S,H,hd]; k,v [B,S,KV,hd] with H = KV*rep.  The S x S probability
    matrix never leaves VMEM in either direction.
    """

    @jax.custom_vjp
    def flash(q, k, v):
        o, _ = _fwd(q, k, v)
        return o

    def _fwd(q, k, v):
        hd = q.shape[-1]
        return flash_fwd(q, k, v, scale=hd ** -0.5, causal=causal,
                         window=window, q_block=q_block, kv_block=kv_block,
                         interpret=interpret)

    def fwd_rule(q, k, v):
        o, lse = _fwd(q, k, v)
        return o, (q, k, v, o, lse)

    def bwd_rule(res, do):
        q, k, v, o, lse = res
        B, S0, H, hd = q.shape
        KV = k.shape[2]
        rep = H // KV
        scale = hd ** -0.5
        qb = min(q_block, S0)
        kb = min(kv_block, S0)

        # D_i = rowsum(do * o): O(S*hd), computed outside the kernels
        dcap = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
        dcap = dcap.reshape(B, S0, KV, rep).transpose(0, 2, 3, 1)  # [B,KV,rep,S]

        qp = _pad_to(q, 1, qb)
        dop = _pad_to(do, 1, qb)
        kp = _pad_to(k, 1, kb)
        vp = _pad_to(v, 1, kb)
        Sq, Sk = qp.shape[1], kp.shape[1]
        nq, nk = Sq // qb, Sk // kb
        lsep = _pad_to(lse, 3, qb)[..., :Sq]
        dcapp = _pad_to(dcap, 3, qb)[..., :Sq]

        qh = qp.reshape(B, Sq, KV, rep, hd)
        doh = dop.reshape(B, Sq, KV, rep, hd)

        qspec = pl.BlockSpec((1, qb, 1, rep, hd),
                             lambda b, g, i, j: (b, i, g, 0, 0))
        kspec = pl.BlockSpec((1, kb, 1, hd), lambda b, g, i, j: (b, j, g, 0))
        sspec = pl.BlockSpec((1, 1, rep, qb), lambda b, g, i, j: (b, g, 0, i))

        dq_kernel = functools.partial(
            _dq_kernel_real, scale=scale, causal=causal, window=window,
            q_block=qb, kv_block=kb, n_kv=nk, seq_len=S0)
        dq = pl.pallas_call(
            dq_kernel,
            grid=(B, KV, nq, nk),
            in_specs=[qspec, kspec, kspec, qspec, sspec, sspec],
            out_specs=pl.BlockSpec((1, qb, 1, rep, hd),
                                   lambda b, g, i, j: (b, i, g, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((B, Sq, KV, rep, hd), q.dtype),
            scratch_shapes=[pltpu.VMEM((rep, qb, hd), jnp.float32)],
            interpret=interpret,
        )(qh, kp, vp, doh, lsep, dcapp)
        dq = dq.reshape(B, Sq, H, hd)[:, :S0]

        # dk/dv: swap grid so q blocks are innermost
        qspec2 = pl.BlockSpec((1, qb, 1, rep, hd),
                              lambda b, g, j, i: (b, i, g, 0, 0))
        kspec2 = pl.BlockSpec((1, kb, 1, hd), lambda b, g, j, i: (b, j, g, 0))
        sspec2 = pl.BlockSpec((1, 1, rep, qb), lambda b, g, j, i: (b, g, 0, i))
        dkv_kernel = functools.partial(
            _dkv_kernel, scale=scale, causal=causal, window=window,
            q_block=qb, kv_block=kb, n_q=nq, seq_len=S0)
        dk, dv = pl.pallas_call(
            dkv_kernel,
            grid=(B, KV, nk, nq),
            in_specs=[qspec2, kspec2, kspec2, qspec2, sspec2, sspec2],
            out_specs=[
                pl.BlockSpec((1, kb, 1, hd), lambda b, g, j, i: (b, j, g, 0)),
                pl.BlockSpec((1, kb, 1, hd), lambda b, g, j, i: (b, j, g, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B, Sk, KV, hd), k.dtype),
                jax.ShapeDtypeStruct((B, Sk, KV, hd), v.dtype),
            ],
            scratch_shapes=[
                pltpu.VMEM((kb, hd), jnp.float32),
                pltpu.VMEM((kb, hd), jnp.float32),
            ],
            interpret=interpret,
        )(qh, kp, vp, doh, lsep, dcapp)
        dk = dk[:, :S0]
        dv = dv[:, :S0]
        return dq, dk, dv

    flash.defvjp(fwd_rule, bwd_rule)
    return flash
