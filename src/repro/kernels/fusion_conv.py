"""Pallas TPU kernel: fused FedFusion `conv` operator.

F_conv(E_g, E_l) = W . concat(E_g, E_l)  with W in R^{2C x C} (paper Eq. 6).
The concat is never materialised: W is consumed as two C x C halves and the
kernel computes  out = E_g @ W_g + E_l @ W_l  tile-by-tile in VMEM, with both
matmuls hitting the MXU and a single accumulator.

Token axis (B*S or B*H*W) is tiled by ``tile_t``; the channel contraction is
done in full per tile (C <= ~8k fits VMEM comfortably at f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T = 256


def _fusion_kernel(fg_ref, fl_ref, wg_ref, wl_ref, out_ref):
    fg = fg_ref[...]
    fl = fl_ref[...]
    acc = jax.lax.dot(fg, wg_ref[...], preferred_element_type=jnp.float32)
    acc = acc + jax.lax.dot(fl, wl_ref[...], preferred_element_type=jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def fusion_conv(f_g, f_l, w, *, tile_t=TILE_T, interpret=True):
    """f_g, f_l [..., C]; w [2C, C] -> fused [..., C]."""
    orig_shape = f_g.shape
    C = orig_shape[-1]
    fg = f_g.reshape(-1, C)
    fl = f_l.reshape(-1, C)
    T = fg.shape[0]
    tt = min(tile_t, T)
    pad = (-T) % tt
    if pad:
        fg = jnp.pad(fg, ((0, pad), (0, 0)))
        fl = jnp.pad(fl, ((0, pad), (0, 0)))
    grid = (fg.shape[0] // tt,)
    wg, wl = w[:C], w[C:]

    out = pl.pallas_call(
        _fusion_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, C), lambda i: (i, 0)),
            pl.BlockSpec((tt, C), lambda i: (i, 0)),
            pl.BlockSpec((C, C), lambda i: (0, 0)),
            pl.BlockSpec((C, C), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tt, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((fg.shape[0], C), f_g.dtype),
        interpret=interpret,
    )(fg, fl, wg, wl)
    return out[:T].reshape(orig_shape)
