"""Pallas TPU kernel: fused multi-width RBF Gram-sum for MK-MMD.

Computes  S(x, y) = sum_{i<n, j<m} mean_w exp(-||x_i - y_j||^2 / (2 w sigma))
without materialising the n x m Gram matrix in HBM.  Squared distances are
formed per VMEM tile via the ||x||^2 + ||y||^2 - 2 x.y identity, so the
inner product runs on the MXU; all RBF widths are applied to the distance
tile in-register and accumulated.  HBM traffic is O((n+m) d), arithmetic
intensity ~ O(tile).

MMD^2 then assembles three of these sums (xx, yy, xy) on the host side of
the kernel (see ops.mk_mmd2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _gram_sum_kernel(x_ref, y_ref, sigma_ref, out_ref, *, widths, n, m,
                     tile_i, tile_j):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)            # [ti, d]
    y = y_ref[...].astype(jnp.float32)            # [tj, d]
    sigma = sigma_ref[0]

    x2 = jnp.sum(x * x, axis=-1)                  # [ti]
    y2 = jnp.sum(y * y, axis=-1)                  # [tj]
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [ti, tj]
    d2 = x2[:, None] + y2[None, :] - 2.0 * xy
    d2 = jnp.maximum(d2, 0.0)

    # validity mask for the padded tail rows/cols
    row = i * tile_i + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 0)
    col = j * tile_j + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    valid = (row < n) & (col < m)

    acc = jnp.zeros_like(d2)
    for w in widths:
        acc = acc + jnp.exp(-d2 / (2.0 * w * sigma))
    acc = jnp.where(valid, acc, 0.0)
    out_ref[...] += jnp.sum(acc) / len(widths)


def gram_sum(x, y, sigma, widths, *, tile_i=TILE, tile_j=TILE,
             interpret=True):
    """sum_{ij} mean_w RBF_w(||x_i - y_j||^2); x [n,d], y [m,d]."""
    n, d = x.shape
    m = y.shape[0]
    ti = min(tile_i, max(8, n))
    tj = min(tile_j, max(8, m))
    pn = (-n) % ti
    pm = (-m) % tj
    if pn:
        x = jnp.pad(x, ((0, pn), (0, 0)))
    if pm:
        y = jnp.pad(y, ((0, pm), (0, 0)))
    grid = (x.shape[0] // ti, y.shape[0] // tj)
    sigma = jnp.asarray(sigma, jnp.float32).reshape(1)

    kernel = functools.partial(_gram_sum_kernel, widths=tuple(widths), n=n,
                               m=m, tile_i=ti, tile_j=tj)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tj, d), lambda i, j: (j, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=interpret,
    )(x, y, sigma)
    return out[0]
