"""jit'd public wrappers for the Pallas kernels, with implementation dispatch.

On CPU (this container) the default implementation is the pure-jnp oracle —
Pallas ``interpret=True`` executes the kernel body in Python and is used by
the correctness tests, not the hot path.  On TPU the Pallas kernels compile
natively (``interpret=False``).

Select with ``impl``: 'auto' | 'jnp' | 'pallas' | 'pallas_interpret'.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attn import flash_decode
from repro.kernels.fusion_conv import fusion_conv
from repro.kernels.mk_mmd import gram_sum


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "jnp"
    return impl


def mk_mmd2(x, y, widths, *, impl="auto"):
    """Multi-kernel squared MMD between feature batches x [n,d], y [m,d]."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.mk_mmd2_ref(x, y, widths)
    interpret = impl == "pallas_interpret"
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    n, m = x.shape[0], y.shape[0]
    # median-heuristic sigma from the cross sq-distances (O(nm d) but cheap
    # relative to the Gram sums; stop-grad like the oracle).
    x2 = jnp.sum(x * x, -1)
    y2 = jnp.sum(y * y, -1)
    dxy = x2[:, None] + y2[None, :] - 2 * (x @ y.T)
    sigma = jax.lax.stop_gradient(jnp.mean(dxy)) + 1e-8
    sxx = gram_sum(x, x, sigma, widths, interpret=interpret)
    syy = gram_sum(y, y, sigma, widths, interpret=interpret)
    sxy = gram_sum(x, y, sigma, widths, interpret=interpret)
    return sxx / (n * n) + syy / (m * m) - 2.0 * sxy / (n * m)


def fused_fusion_conv(f_g, f_l, w, *, impl="auto"):
    """FedFusion conv operator: W . concat(f_g, f_l) along channels."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.fusion_conv_ref(f_g, f_l, w)
    return fusion_conv(f_g, f_l, w, interpret=(impl == "pallas_interpret"))


def gqa_flash_decode(q, k_cache, v_cache, valid_len=None, *, impl="auto"):
    """One-token GQA decode attention against a KV cache."""
    impl = _resolve(impl)
    if impl == "jnp":
        vl = k_cache.shape[1] if valid_len is None else valid_len
        return ref.decode_attn_ref(q, k_cache, v_cache, vl)
    return flash_decode(q, k_cache, v_cache, valid_len,
                        interpret=(impl == "pallas_interpret"))
