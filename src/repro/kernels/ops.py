"""jit'd public wrappers for the Pallas kernels, with implementation dispatch.

On CPU (this container) the default implementation is the pure-jnp oracle —
Pallas ``interpret=True`` executes the kernel body in Python and is used by
the correctness tests, not the hot path.  On TPU the Pallas kernels compile
natively (``interpret=False``).

Select with ``impl``: 'auto' | 'jnp' | 'pallas' | 'pallas_interpret'.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import compress_pack, ref
from repro.kernels.decode_attn import flash_decode
from repro.kernels.fusion_conv import fusion_conv
from repro.kernels.mk_mmd import gram_sum


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "jnp"
    return impl


def mk_mmd2(x, y, widths, *, impl="auto"):
    """Multi-kernel squared MMD between feature batches x [n,d], y [m,d]."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.mk_mmd2_ref(x, y, widths)
    interpret = impl == "pallas_interpret"
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    n, m = x.shape[0], y.shape[0]
    # median-heuristic sigma from the cross sq-distances (O(nm d) but cheap
    # relative to the Gram sums; stop-grad like the oracle).
    x2 = jnp.sum(x * x, -1)
    y2 = jnp.sum(y * y, -1)
    dxy = x2[:, None] + y2[None, :] - 2 * (x @ y.T)
    sigma = jax.lax.stop_gradient(jnp.mean(dxy)) + 1e-8
    sxx = gram_sum(x, x, sigma, widths, interpret=interpret)
    syy = gram_sum(y, y, sigma, widths, interpret=interpret)
    sxy = gram_sum(x, y, sigma, widths, interpret=interpret)
    return sxx / (n * n) + syy / (m * m) - 2.0 * sxy / (n * m)


def fused_fusion_conv(f_g, f_l, w, *, impl="auto"):
    """FedFusion conv operator: W . concat(f_g, f_l) along channels."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.fusion_conv_ref(f_g, f_l, w)
    return fusion_conv(f_g, f_l, w, interpret=(impl == "pallas_interpret"))


def quantize_pack(x, scale, noise, *, bits=8, impl="auto"):
    """Fused stochastic-quantize + bit-pack of a flat fp32 tensor.

    Wire format of ``repro.compress``: int8 codes, or nibble-packed uint8
    for ``bits=4``.  All impls produce bit-identical packed payloads."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.quant_pack_ref(x, scale, noise, bits=bits)
    return compress_pack.quant_pack(x, scale, noise, bits=bits,
                                    interpret=(impl == "pallas_interpret"))


def quantize_unpack(packed, scale, *, bits=8, n=None, impl="auto"):
    """Scatter-unpack quantized codes back to fp32 [n]."""
    impl = _resolve(impl)
    if impl == "jnp":
        m = packed.shape[0]
        n = (m if bits == 8 else 2 * m) if n is None else n
        return ref.quant_unpack_ref(packed, scale, bits=bits, n=n)
    return compress_pack.quant_unpack(packed, scale, bits=bits, n=n,
                                      interpret=(impl == "pallas_interpret"))


def topk_threshold_select(x, thresh, *, impl="auto"):
    """Dense top-k select: keep entries with |x| >= thresh, zero the rest."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.topk_select_ref(x, thresh)
    return compress_pack.topk_select(x, thresh,
                                     interpret=(impl == "pallas_interpret"))


def ef_gather(table, idx, *, impl="auto"):
    """Pull the sampled clients' rows [k, ...] out of a device-resident
    per-client table [N, ...] (error-feedback residuals, ``repro.engine``).

    The Pallas kernel scalar-prefetches ``idx`` (``PrefetchScalarGridSpec``)
    so the row index feeds the DMA engine directly — it compiles TPU-native
    and ``auto`` selects it there; on CPU ``auto`` stays on the jnp
    ``take`` oracle (interpret mode is for the correctness tests)."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.ef_gather_ref(table, idx)
    return compress_pack.ef_gather(table, idx,
                                   interpret=(impl == "pallas_interpret"))


def ef_scatter(table, idx, rows, *, impl="auto"):
    """Write rows [k, ...] back into table [N, ...] at the (unique) idx.

    The jnp path is ``table.at[idx].set(rows)`` — under jit with the table
    donated, XLA performs this in place; the Pallas path aliases the table
    buffer explicitly (``input_output_aliases``) and scalar-prefetches
    ``idx`` so each row writes back as one direct VMEM->HBM DMA.  Either
    way the full-federation EF tree is updated without a device->host
    round-trip.  ``auto`` -> pallas on TPU, jnp elsewhere."""
    impl = _resolve(impl)
    if impl == "jnp":
        return ref.ef_scatter_ref(table, idx, rows)
    return compress_pack.ef_scatter(table, idx, rows,
                                    interpret=(impl == "pallas_interpret"))


def gqa_flash_decode(q, k_cache, v_cache, valid_len=None, *, impl="auto"):
    """One-token GQA decode attention against a KV cache."""
    impl = _resolve(impl)
    if impl == "jnp":
        vl = k_cache.shape[1] if valid_len is None else valid_len
        return ref.decode_attn_ref(q, k_cache, v_cache, vl)
    return flash_decode(q, k_cache, v_cache, valid_len,
                        interpret=(impl == "pallas_interpret"))
