"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the Pallas kernels must match them (tests sweep
shapes/dtypes and assert_allclose against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mk_mmd2_ref(x, y, widths, *, median_heuristic=True):
    """Multi-kernel (multi-width RBF) squared MMD — paper Eq. (2).

    x [n,d], y [m,d] feature batches.  Biased V-statistic estimator:
        MMD^2 = E[K(x,x)] + E[K(y,y)] - 2 E[K(x,y)]
    with K = mean over RBF kernels exp(-||a-b||^2 / (2 w sigma)).
    ``sigma`` is the (stop-grad) mean pairwise squared distance (median-
    heuristic surrogate) so the widths are scale-free, matching MK-MMD
    practice (Gretton et al. 2012).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)

    def sqdist(a, b):
        a2 = jnp.sum(a * a, axis=-1)
        b2 = jnp.sum(b * b, axis=-1)
        return a2[:, None] + b2[None, :] - 2.0 * (a @ b.T)

    dxx, dyy, dxy = sqdist(x, x), sqdist(y, y), sqdist(x, y)
    if median_heuristic:
        sigma = jax.lax.stop_gradient(jnp.mean(dxy)) + 1e-8
    else:
        sigma = 1.0

    def kmean(d2):
        k = 0.0
        for w in widths:
            k = k + jnp.exp(-d2 / (2.0 * w * sigma))
        return jnp.mean(k) / len(widths)

    return kmean(dxx) + kmean(dyy) - 2.0 * kmean(dxy)


def fusion_conv_ref(f_g, f_l, w):
    """1x1-conv fusion operator (paper Eq. 6).

    f_g, f_l [..., C]; w [2C, C].  Equivalent to concat along the channel
    axis followed by a 1x1 convolution (= matmul over channels).
    """
    C = f_g.shape[-1]
    return f_g @ w[:C] + f_l @ w[C:]


def decode_attn_ref(q, k_cache, v_cache, valid_len):
    """GQA flash-decode oracle.

    q [B,1,H,hd]; caches [B,L,KV,hd]; valid_len scalar int (positions
    >= valid_len are masked).  Returns [B,1,H,hd].
    """
    B, _, H, hd = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    qh = q.reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrd,blgd->bgrl", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.arange(L) < valid_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrl,blgd->bgrd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
