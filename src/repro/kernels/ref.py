"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the Pallas kernels must match them (tests sweep
shapes/dtypes and assert_allclose against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mk_mmd2_ref(x, y, widths, *, median_heuristic=True):
    """Multi-kernel (multi-width RBF) squared MMD — paper Eq. (2).

    x [n,d], y [m,d] feature batches.  Biased V-statistic estimator:
        MMD^2 = E[K(x,x)] + E[K(y,y)] - 2 E[K(x,y)]
    with K = mean over RBF kernels exp(-||a-b||^2 / (2 w sigma)).
    ``sigma`` is the (stop-grad) mean pairwise squared distance (median-
    heuristic surrogate) so the widths are scale-free, matching MK-MMD
    practice (Gretton et al. 2012).
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)

    def sqdist(a, b):
        a2 = jnp.sum(a * a, axis=-1)
        b2 = jnp.sum(b * b, axis=-1)
        return a2[:, None] + b2[None, :] - 2.0 * (a @ b.T)

    dxx, dyy, dxy = sqdist(x, x), sqdist(y, y), sqdist(x, y)
    if median_heuristic:
        sigma = jax.lax.stop_gradient(jnp.mean(dxy)) + 1e-8
    else:
        sigma = 1.0

    def kmean(d2):
        k = 0.0
        for w in widths:
            k = k + jnp.exp(-d2 / (2.0 * w * sigma))
        return jnp.mean(k) / len(widths)

    return kmean(dxx) + kmean(dyy) - 2.0 * kmean(dxy)


def fusion_conv_ref(f_g, f_l, w):
    """1x1-conv fusion operator (paper Eq. 6).

    f_g, f_l [..., C]; w [2C, C].  Equivalent to concat along the channel
    axis followed by a 1x1 convolution (= matmul over channels).
    """
    C = f_g.shape[-1]
    return f_g @ w[:C] + f_l @ w[C:]


def quant_pack_ref(x, scale, noise, *, bits):
    """Fused stochastic-quantize + pack oracle (repro.compress wire format).

    x [n] float; scale scalar (wire step size); noise [n] in [0,1) — the
    stochastic-rounding offsets (0.5 = deterministic round-half-up).
    ``bits=8``: int8 codes in [-127, 127].
    ``bits=4``: codes in [-7, 7] stored as ``code+8`` nibbles, two per uint8
    (element 2i in the low nibble, 2i+1 in the high one); n must be even.
    """
    if bits not in (4, 8):
        raise ValueError(f"quant_pack_ref bits={bits!r} must be 4 or 8")
    qmax = 127 if bits == 8 else 7
    q = jnp.floor(x.astype(jnp.float32) / scale + noise)
    q = jnp.clip(q, -qmax, qmax)
    if bits == 8:
        return q.astype(jnp.int8)
    u = (q + 8).astype(jnp.uint8).reshape(-1, 2)
    return (u[:, 0] | (u[:, 1] << 4)).astype(jnp.uint8)


def quant_unpack_ref(packed, scale, *, bits, n):
    """Inverse of :func:`quant_pack_ref`: packed codes -> float32 [n]."""
    if bits not in (4, 8):
        raise ValueError(f"quant_unpack_ref bits={bits!r} must be 4 "
                         "or 8")
    if bits == 8:
        return packed.astype(jnp.float32) * scale
    low = (packed & 0xF).astype(jnp.int32) - 8
    high = ((packed >> 4) & 0xF).astype(jnp.int32) - 8
    q = jnp.stack([low, high], axis=-1).reshape(-1)[:n]
    return q.astype(jnp.float32) * scale


def topk_select_ref(x, thresh):
    """Magnitude threshold select: keep x where |x| >= thresh, else 0.

    With thresh = the k-th largest |x| this is the dense form of top-k
    sparsification (the decode∘encode of the topk codec)."""
    return jnp.where(jnp.abs(x) >= thresh, x, jnp.zeros_like(x))


def ef_gather_ref(table, idx):
    """Row gather of the device-resident error-feedback table.

    table [N, ...] (one row per federation client), idx [k] int32 — the
    round's sampled client ids.  Returns the [k, ...] rows the round fn
    threads as per-client EF state.
    """
    return jnp.take(table, idx, axis=0)


def ef_scatter_ref(table, idx, rows):
    """Row scatter: write rows [k, ...] back into table [N, ...] at idx.

    ``idx`` must be unique (``FederatedDataset.sample_clients`` asserts
    it); with duplicates ``.at[].set`` keeps the last write, silently
    dropping the other client's residual — the exact hazard the sampler
    guard exists for.
    """
    return table.at[idx].set(rows)


def decode_attn_ref(q, k_cache, v_cache, valid_len):
    """GQA flash-decode oracle.

    q [B,1,H,hd]; caches [B,L,KV,hd]; valid_len scalar int (positions
    >= valid_len are masked).  Returns [B,1,H,hd].
    """
    B, _, H, hd = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    qh = q.reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrd,blgd->bgrl", qh.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.arange(L) < valid_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrl,blgd->bgrd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
