import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on the
production meshes and record memory/cost/roofline terms.

This file — and ONLY this file — forces 512 placeholder host devices, which
is why the env var is set before any other import.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]

Results: one JSON per (arch, shape, mesh) under benchmarks/artifacts/dryrun/.
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_CONFIGS, INPUT_SHAPES  # noqa: E402
from repro.configs.base import ALGORITHM_NAMES, FLConfig  # noqa: E402
from repro.launch.mesh import mesh_context, make_production_mesh    # noqa: E402
from repro.launch.specs import skip_reason            # noqa: E402
from repro.launch.steps import build_step             # noqa: E402
from repro.roofline import analyze                    # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def default_fl() -> FLConfig:
    # the paper's main technique, conv operator (most representative)
    return FLConfig(algorithm="fedfusion", fusion_op="conv", local_steps=2)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            fl: FLConfig | None = None, save: bool = True,
            save_hlo: bool = False, remat: str = "none",
            serve_ep: bool = False, shard_capacity: bool = False,
            moe_dispatch: str = "gather", tag: str = "") -> dict:
    cfg = dataclasses.replace(ARCH_CONFIGS[arch], remat=remat,
                              serve_expert_parallel=serve_ep,
                              moe_shard_capacity=shard_capacity,
                              moe_dispatch=moe_dispatch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if tag:
        rec["tag"] = tag
    if reason:
        rec.update(status="skip", reason=reason)
        return _save(rec) if save else rec

    fl = fl or default_fl()
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args, in_sh, out_sh = build_step(cfg, fl, shape, mesh)
        with mesh_context(mesh):   # sharding-constraint P specs resolve here
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        from repro.fl.api import make_algorithm  # noqa: E402 (env-var file)
        chips = mesh.size
        roof = analyze(compiled, cfg, shape, mesh_name, chips, mesh,
                       two_stream=make_algorithm(fl.algorithm).two_stream)
        mem = compiled.memory_analysis()
        rec.update(
            status="ok",
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            memory={k: int(getattr(mem, k, 0)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")},
            roofline=roof.to_dict(),
        )
        per_chip = (rec["memory"]["argument_size_in_bytes"]
                    + rec["memory"]["temp_size_in_bytes"]) / chips
        rec["bytes_per_chip"] = int(per_chip)
        rec["fits_16gb_hbm"] = bool(per_chip < 16e9)
        if save_hlo:
            os.makedirs(ART_DIR, exist_ok=True)
            hsuffix = f"__{tag}" if tag else ""
            hpath = os.path.join(
                ART_DIR,
                f"{arch}__{shape_name}__{mesh_name}{hsuffix}.hlo.txt")
            with open(hpath, "w") as f:
                f.write(compiled.as_text())
            rec["hlo_path"] = hpath
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a finding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return _save(rec) if save else rec


def run_superstep(multi_pod: bool, compressed: bool = True,
                  save: bool = True, n_rounds: int = 8,
                  fused: bool = True, sharded_eval: bool = True) -> dict:
    """Dry-run the SHARDED federated superstep on a production mesh.

    Lowers (never compiles — no real devices needed beyond the forced
    host placeholders) the ``shard_map``-wrapped K-round superstep with
    abstract chunk arguments: the client axis over ``data``/``pod``, the
    full-federation EF table row-sharded by client id in the resident
    scratch-row layout, shard-split evaluation folded into the scan, and
    the fused one-psum-per-round collective on by default (``fused=False``
    lowers the three-collective oracle).  Catches sharding-spec and shape
    regressions of ``repro.engine.sharded`` against the 16x16 / 2x16x16
    meshes on a CPU box.
    """
    import jax.numpy as jnp
    from repro.compress import make_codec
    from repro.configs import CNN_CONFIGS
    from repro.core.rounds import init_global_state
    from repro.engine.evaljit import make_eval_fn
    from repro.engine.sharded import client_sharding, make_sharded_superstep
    from repro.launch.sharding import (chunk_shardings, ef_table_sharding,
                                       eval_batch_sharding)
    from repro.models.registry import make_bundle

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": "cnn_mnist", "shape": "superstep", "mesh": mesh_name,
           "tag": ("topk" if compressed else "plain")
                  + ("" if fused else "-unfused")}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        shard = client_sharding(mesh)
        n_clients_round = 32          # divides 16 (data) and 32 (pod*data)
        n_federation = 64
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"], dropout=0.0)
        fl = FLConfig(algorithm="fedavg", clients_per_round=n_clients_round,
                      local_steps=2, local_batch=8,
                      uplink_codec="topk" if compressed else "identity",
                      topk_frac=0.05)
        bundle = make_bundle(cfg)
        state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                               jax.random.PRNGKey(0))
        K, C, S, B = n_rounds, n_clients_round, fl.local_steps, fl.local_batch
        H, W, Ch = cfg.input_shape
        batches = {
            "x": jax.ShapeDtypeStruct((K, C, S, B, H, W, Ch), jnp.float32),
            "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32),
        }
        sizes = jax.ShapeDtypeStruct((K, C), jnp.float32)
        lrs = jax.ShapeDtypeStruct((K,), jnp.float32)
        sh_batch, sh_repl = chunk_shardings(mesh)
        # eval folded into the scan, batch split over the client shards
        eval_fn = (make_eval_fn(bundle, fl, shard=shard)
                   if sharded_eval else None)
        bucket = 512                  # divides 16 and 32 client shards
        test_args = ()
        test_sh = ()
        if sharded_eval:
            test_args = (
                {"x": jax.ShapeDtypeStruct((bucket, H, W, Ch), jnp.float32),
                 "y": jax.ShapeDtypeStruct((bucket,), jnp.int32)},
                jax.ShapeDtypeStruct((bucket,), jnp.bool_))
            ev_sh = eval_batch_sharding(mesh)
            test_sh = (ev_sh, ev_sh)

        if compressed:
            uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac)
            downlink = make_codec(fl.downlink_codec)
            uplink.bind(state["model"])
            downlink.bind(state["model"])
            # resident scratch-row layout: one extra row per shard block
            n_loc = n_federation // shard.n_shards
            ef = [jax.ShapeDtypeStruct(
                      ((n_loc + 1) * shard.n_shards,) + z.shape, z.dtype)
                  for z in jax.eval_shape(uplink.init_state)]
            fn = make_sharded_superstep(bundle, fl, "client_parallel", K,
                                        mesh, uplink=uplink,
                                        downlink=downlink, eval_fn=eval_fn,
                                        fused_collective=fused)
            args = (state, ef, state["model"], batches, sizes, lrs,
                    jax.ShapeDtypeStruct((K, C), jnp.int32),
                    jax.ShapeDtypeStruct((K,), jnp.int32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32)) + test_args
            ef_sh = ef_table_sharding(mesh)
            in_sh = (sh_repl, ef_sh, sh_repl, sh_batch, sh_batch, sh_repl,
                     sh_repl, sh_repl, sh_repl) + test_sh
        else:
            fn = make_sharded_superstep(bundle, fl, "client_parallel", K,
                                        mesh, eval_fn=eval_fn,
                                        fused_collective=fused)
            args = (state, batches, sizes, lrs) + test_args
            in_sh = (sh_repl, sh_batch, sh_batch, sh_repl) + test_sh

        with mesh_context(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        out = jax.eval_shape(fn, *args)
        rec.update(
            status="ok",
            t_lower_s=round(time.time() - t0, 1),
            fused_collective=fused,
            sharded_eval=sharded_eval,
            client_shards=shard.n_shards,
            clients_per_shard=n_clients_round // shard.n_shards,
            ef_rows_per_shard=(n_federation // shard.n_shards + 1
                               if compressed else 0),
            out_avals=[str(x.shape) for x in jax.tree_util.tree_leaves(out)
                       ][:4],
            hlo_ops=len(lowered.as_text()) > 0,
        )
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a finding
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return _save(rec) if save else rec


def _save(rec: dict) -> dict:
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = f"__{rec['tag']}" if rec.get("tag") else ""
    path = os.path.join(
        ART_DIR, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCH_CONFIGS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--algorithm", default="fedfusion",
                    choices=sorted(ALGORITHM_NAMES))
    ap.add_argument("--fusion-op", default="conv",
                    choices=("conv", "multi", "single"))
    ap.add_argument("--save-hlo", action="store_true",
                    help="dump compiled HLO text next to the JSON record")
    ap.add_argument("--remat", default="none",
                    choices=("none", "attn", "layer"),
                    help="activation-checkpoint policy (perf knob)")
    ap.add_argument("--serve-ep", action="store_true",
                    help="expert-parallel sharding for prefill/decode")
    ap.add_argument("--moe-shard-capacity", action="store_true",
                    help="shard MoE capacity dim over 'model' (perf knob)")
    ap.add_argument("--moe-a2a", action="store_true",
                    help="shard_map all-to-all expert dispatch (perf knob)")
    ap.add_argument("--tag", default="",
                    help="suffix for the artifact filename (perf variants)")
    ap.add_argument("--superstep", action="store_true",
                    help="dry-run the sharded federated superstep "
                         "(repro.engine.sharded) on the production meshes "
                         "instead of a model step")
    args = ap.parse_args()
    fl = FLConfig(algorithm=args.algorithm, fusion_op=args.fusion_op,
                  local_steps=2)

    if args.superstep:
        pods = [True] if args.multi_pod else [False, True]
        failed = False
        for mp in pods:
            # fused one-psum path (the engine default) for plain + topk,
            # plus the three-collective oracle layout on the compressed
            # round (the fused path's equivalence baseline)
            points = [(False, True), (True, True), (True, False)]
            for compressed, fused in points:
                rec = run_superstep(mp, compressed=compressed, fused=fused)
                tag = f"{rec['mesh']:8s} {rec['tag']:13s}"
                if rec["status"] == "ok":
                    print(f"superstep {tag} ok  lower={rec['t_lower_s']}s "
                          f"shards={rec['client_shards']} "
                          f"C/shard={rec['clients_per_shard']} "
                          f"ef-rows/shard={rec['ef_rows_per_shard']}")
                else:
                    failed = True
                    print(f"superstep {tag} ERROR {rec['error']}")
                    print(rec.get("traceback", ""))
        if failed:
            raise SystemExit(1)
        return

    if args.all:
        pods = [False, True]
        if args.single_pod_only:
            pods = [False]
        if args.multi_pod_only:
            pods = [True]
        for arch in ARCH_CONFIGS:
            for shape in INPUT_SHAPES:
                for mp in pods:
                    rec = run_one(arch, shape, mp, fl,
                                  save_hlo=args.save_hlo, remat=args.remat,
                                  serve_ep=args.serve_ep,
                                  shard_capacity=args.moe_shard_capacity,
                                  tag=args.tag)
                    _report(rec)
        return
    rec = run_one(args.arch, args.shape, args.multi_pod, fl,
                  save_hlo=args.save_hlo, remat=args.remat,
                  serve_ep=args.serve_ep,
                  shard_capacity=args.moe_shard_capacity,
                  moe_dispatch="a2a" if args.moe_a2a else "gather",
                  tag=args.tag)
    _report(rec, verbose=True)


def _report(rec: dict, verbose: bool = False) -> None:
    tag = f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s}"
    if rec["status"] == "skip":
        print(f"{tag} SKIP ({rec['reason']})")
    elif rec["status"] == "error":
        print(f"{tag} ERROR {rec['error']}")
        if verbose:
            print(rec.get("traceback", ""))
    else:
        r = rec["roofline"]
        print(f"{tag} ok  compile={rec['t_compile_s']}s "
              f"bytes/chip={rec['bytes_per_chip']/1e9:.2f}GB "
              f"t_comp={r['t_compute']*1e3:.2f}ms t_mem={r['t_memory']*1e3:.2f}ms "
              f"t_coll={r['t_collective']*1e3:.2f}ms -> {r['bottleneck']}"
              f" useful={r['useful_ratio']:.2f}")
        if verbose:
            print(json.dumps(rec["memory"], indent=1))
            print(json.dumps(r["coll_breakdown"], indent=1))


if __name__ == "__main__":
    main()
