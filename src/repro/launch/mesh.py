"""Production mesh construction (TPU v5e target).

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model").

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests must keep
seeing the single real CPU device).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes AxisType; 0.4.x builds Mesh without it
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    # explicit Auto axis types: silences the jax 0.9 default-change warning
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Version-portable ``jax.make_mesh`` (axis_types only where supported)."""
    return _mesh(shape, axes)


def mesh_context(mesh):
    """Context manager activating ``mesh``.

    jax >= 0.5 uses ``jax.set_mesh``; on 0.4.x the ``Mesh`` object itself is
    the context manager that sets the thread-local physical mesh.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh over the real local device (CPU smoke paths)."""
    return _mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes a batch-like dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def client_axes(mesh) -> tuple:
    """Mesh axes the federated CLIENT axis shards over (major to minor).

    Same axes a batch dimension uses — one sampled client per data-group —
    but returned only for axes present on the mesh, in the fixed
    ``("pod", "data")`` order the engine's positional client split relies
    on (shard position = row-major index over these axes).
    """
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_engine_mesh(n_client_shards: int = None):
    """Mesh for the client-parallel engine on the locally visible devices.

    Factors ``n_client_shards`` devices (default: all of them) into
    ``(data, model=1)`` — the engine shards the client axis over ``data``
    and treats ``model`` as replicated.  Raising the device count is done
    by the launcher (``XLA_FLAGS=--xla_force_host_platform_device_count``
    for CPU simulation), never here.
    """
    n = n_client_shards or len(jax.devices())
    if n > len(jax.devices()):
        raise ValueError(f"engine mesh wants {n} devices, only "
                         f"{len(jax.devices())} visible")
    return _mesh((n, 1), ("data", "model"))


def axis_size(mesh, *names) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
