"""Pod-scale serving launcher: batched prefill + decode under pjit.

The decode step is the one the decode_32k / long_500k dry-run shapes lower;
here it runs for real on whatever mesh the devices support (1 CPU in this
container, a v5e pod in production).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b \
        --scale tiny --batch 4 --prompt-len 32 --gen-len 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_CONFIGS
from repro.launch.mesh import mesh_context
from repro.launch.train import mesh_from_devices
from repro.launch import sharding as sh
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b", choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--scale", default="tiny", choices=("tiny", "full"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch]
    if args.scale == "tiny":
        cfg = cfg.reduced()
    mesh = mesh_from_devices()
    max_len = args.prompt_len + args.gen_len
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name}")

    with mesh_context(mesh):
        params_struct = jax.eval_shape(
            lambda k: tfm.init_params(cfg, k), jax.random.PRNGKey(0))
        params_sh = sh.param_shardings(mesh, params_struct, fsdp=False)
        params = jax.jit(lambda k: tfm.init_params(cfg, k),
                         out_shardings=params_sh)(jax.random.PRNGKey(0))

        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.n_vision_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["audio_frames"] = jax.random.normal(
                jax.random.PRNGKey(3),
                (args.batch, cfg.n_audio_frames, cfg.d_model))

        t0 = time.perf_counter()
        out = jax.jit(lambda p, b: tfm.forward_seq(
            cfg, p, b, want_cache=True, max_cache_len=max_len))(params, batch)
        jax.block_until_ready(out["logits"])
        print(f"prefill: {(time.perf_counter()-t0)*1e3:.0f} ms (w/ compile)")

        step = jax.jit(lambda p, t, c, pos: tfm.decode_step(cfg, p, t, c, pos))
        cache = out["cache"]
        last = out["logits"][:, -1]
        t0 = time.perf_counter()
        toks = []
        for i in range(args.gen_len):
            nxt = jnp.argmax(last, axis=-1)
            toks.append(nxt)
            logits, cache = step(params, nxt[:, None], cache,
                                 jnp.int32(args.prompt_len + i))
            last = logits[:, 0]
        jax.block_until_ready(last)
        dt = time.perf_counter() - t0
        print(f"decode {args.gen_len} tokens: {dt*1e3:.0f} ms; ids[0]="
              f"{[int(t[0]) for t in toks]}")


if __name__ == "__main__":
    main()
