"""PartitionSpec builders: name-based rules over the param/cache pytrees.

Three layouts (DESIGN.md §4):
* ``client_parallel`` train — params replicated over data/pod (each data
  group holds one client's transient replica), tensor-parallel over model.
* ``client_sequential`` train — FSDP: the d_model-ish dim of large matrices
  additionally sharded over data; MoE experts expert-parallel over data.
* ``serve`` — tensor-parallel params; KV caches sharded batch x cache-length
  (flash-decode style sequence sharding when batch alone can't fill the
  mesh); SSD/RG-LRU states sharded over whatever divides.

All rules are divisibility-aware: a dim is only sharded if the axis size
divides it (GSPMD tolerates uneven shardings, but even layouts keep the
roofline accounting clean).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _fits(dim: int, mesh, name) -> bool:
    return name in mesh.axis_names and dim % _axis(mesh, name) == 0


def shard_if(dim: int, mesh, name) -> Optional[str]:
    return name if _fits(dim, mesh, name) else None


def _names_of(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(f"#{p.idx}")
    return out


# matrices whose FIRST dim is the contraction (d_model-like) axis and whose
# SECOND dim is model-parallel; and the transposed set
_COL_PARALLEL = {"wq", "wk", "wv", "w1", "w3", "w_x", "w_gate", "w_a", "w_i"}
_ROW_PARALLEL = {"wo", "w2", "w_out"}
_REPLICATED = {"scale", "bias", "b_a", "b_i", "conv_b", "dt_bias", "A_log",
               "D", "lam", "b", "router", "conv_w"}


def param_pspec(path, leaf, mesh, *, fsdp: bool, ep: Optional[bool] = None) -> P:
    names = _names_of(path)
    last = names[-1]
    shape = leaf.shape
    stacked = 1 if ("cycles" in names or "layers" in names) else 0
    fsdp_ax = "data" if fsdp else None
    ep = fsdp if ep is None else ep   # expert-parallel defaults to fsdp mode

    def spec(*dims):
        return P(*([None] * stacked + list(dims)))

    # --- MoE experts: expert-parallel over data when fsdp/EP mode ---
    # (rank check excludes the 2-D dense-residual MLP nested under "moe")
    if "moe" in names and last in ("w1", "w2", "w3") \
            and len(shape) - stacked == 3:
        e_ax = shard_if(shape[stacked], mesh, "data") if ep else None
        if last == "w2":  # [E, f, d]
            return spec(e_ax, shard_if(shape[stacked + 1], mesh, "model"), None)
        return spec(e_ax, None, shard_if(shape[stacked + 2], mesh, "model"))
    if last == "table":  # embedding [V, d]
        return spec(shard_if(shape[stacked], mesh, "model"),
                    shard_if(shape[stacked + 1], mesh, fsdp_ax)
                    if fsdp else None)
    if "head" in names and last == "w":  # [d, V]
        return spec(shard_if(shape[stacked], mesh, fsdp_ax) if fsdp else None,
                    shard_if(shape[stacked + 1], mesh, "model"))
    if last == "w_in":  # ssd in-proj [d, mixed] — shard only the d side
        return spec(shard_if(shape[stacked], mesh, fsdp_ax) if fsdp else None,
                    None)
    if last in _COL_PARALLEL and len(shape) - stacked == 2:
        return spec(shard_if(shape[stacked], mesh, fsdp_ax) if fsdp else None,
                    shard_if(shape[stacked + 1], mesh, "model"))
    if last in _ROW_PARALLEL and len(shape) - stacked == 2:
        return spec(shard_if(shape[stacked], mesh, "model"),
                    shard_if(shape[stacked + 1], mesh, fsdp_ax)
                    if fsdp else None)
    if last == "w" and len(shape) - stacked == 2:  # generic proj (vis/fusion)
        return spec(None, shard_if(shape[stacked + 1], mesh, "model"))
    return P(*([None] * len(shape)))


def param_shardings(mesh, params_struct, *, fsdp: bool,
                    ep: Optional[bool] = None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh,
                                                           fsdp=fsdp, ep=ep)),
        params_struct)


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------

def _batch_axes_for(dim: int, mesh) -> Any:
    """Largest prefix of ('pod','data') whose product divides dim."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    prod = 1
    for a in axes:
        if dim % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def train_batch_shardings(mesh, batch_struct):
    """Leading dim = clients (client_parallel) or within-client batch dim
    at index 2 (client_sequential) — both handled by sharding dim 0 if it
    divides, else dim 2."""
    def rule(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        ax0 = _batch_axes_for(leaf.shape[0], mesh)
        if ax0 is not None:
            return NamedSharding(mesh, P(*([ax0] + [None] * (leaf.ndim - 1))))
        if leaf.ndim >= 3:
            ax2 = _batch_axes_for(leaf.shape[2], mesh)
            return NamedSharding(
                mesh, P(*([None, None, ax2] + [None] * (leaf.ndim - 3))))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree_util.tree_map_with_path(rule, batch_struct)


def serve_batch_shardings(mesh, batch_struct):
    def rule(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        ax0 = _batch_axes_for(leaf.shape[0], mesh)
        return NamedSharding(mesh, P(*([ax0] + [None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(rule, batch_struct)


def cache_shardings(mesh, cache_struct):
    """KV caches [.., B, L, KV, hd]; SSD states; RG-LRU states.

    Batch shards over ('pod','data') when it divides; the cache length L
    additionally shards over 'model' (sequence-parallel flash-decode) since
    KV head counts (1..20) generally don't divide the model axis.
    """
    def rule(path, leaf):
        names = _names_of(path)
        shape = leaf.shape
        stacked = 1 if "cycles" in names else 0
        dims = [None] * len(shape)
        last = names[-1]
        if last in ("k", "v", "xk", "xv"):
            b, L = shape[stacked], shape[stacked + 1]
            dims[stacked] = _batch_axes_for(b, mesh)
            if dims[stacked] is None and b == 1:
                # batch-1 long-context: shard L over everything that fits
                dims[stacked + 1] = _batch_axes_for(L, mesh)
                if _fits(L // max(_axis(mesh, 'data') * _axis(mesh, 'pod'), 1),
                         mesh, "model"):
                    pass
            if _fits(L, mesh, "model"):
                merged = dims[stacked + 1]
                if merged is None:
                    dims[stacked + 1] = "model"
                elif isinstance(merged, tuple):
                    dims[stacked + 1] = merged + ("model",)
                else:
                    dims[stacked + 1] = (merged, "model")
        elif last == "h" and len(shape) - stacked == 4:   # SSD state [B,H,P,N]
            dims[stacked] = _batch_axes_for(shape[stacked], mesh)
            if _fits(shape[stacked + 2], mesh, "model"):
                dims[stacked + 2] = "model"
        elif last == "h":                                  # RG-LRU [B,W]
            dims[stacked] = _batch_axes_for(shape[stacked], mesh)
            if _fits(shape[stacked + 1], mesh, "model"):
                dims[stacked + 1] = "model"
        elif last == "conv":
            dims[stacked] = _batch_axes_for(shape[stacked], mesh)
            if _fits(shape[-1], mesh, "model"):
                dims[-1] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(rule, cache_struct)


def replicated(mesh, struct):
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), struct)


# ---------------------------------------------------------------------------
# Engine chunk layout (repro.engine.sharded)
# ---------------------------------------------------------------------------

def client_axis_entry(mesh):
    """The PartitionSpec entry a client-sharded dim uses on ``mesh``."""
    from repro.launch.mesh import client_axes
    axes = client_axes(mesh)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def chunk_shardings(mesh):
    """(client-sharded, replicated) NamedShardings for a staged chunk.

    The client-sharded one targets ``batches [K, C, ...]`` / ``sizes
    [K, C]`` (dim 1 = the round's client axis, split over pod/data); lrs,
    cids and round indices stage replicated.
    """
    ax = client_axis_entry(mesh)
    return (NamedSharding(mesh, P(None, ax)), NamedSharding(mesh, P()))


def ef_table_sharding(mesh):
    """Row sharding (by client id) for the full-federation EF table.

    The sharded engine stages the table in the RESIDENT scratch-row
    layout: the global array is ``[(N_loc + 1) * S, ...]`` — each shard's
    ``N_loc`` owned rows followed by one permanent scratch row that
    absorbs non-owned scatter writes (``repro.engine.superstep``), so the
    per-round EF scatter stays a single in-place aliased write under
    donation.  ``repro.checkpoint.io.strip_scratch_rows`` /
    ``insert_scratch_rows`` convert to/from the compact ``[N, ...]``
    layout ``ef.npz`` keeps on disk.
    """
    return NamedSharding(mesh, P(client_axis_entry(mesh)))


def eval_batch_sharding(mesh):
    """Positional client-axis split for the padded eval batch and mask.

    Dim 0 (examples) shards over the mesh's client axes; pad with
    ``repro.engine.pad_eval_batch(shard=...)`` so the bucket divides.
    Sharded evaluation forwards ``bucket / S`` examples per shard and
    completes the masked metric sums with one psum
    (``repro.engine.make_eval_fn(shard=...)``).
    """
    return NamedSharding(mesh, P(client_axis_entry(mesh)))
