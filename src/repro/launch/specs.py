"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape, mesh)`` returns the abstract batch for the given
(architecture x input-shape) pair; modality frontends (vision patches, audio
frames) appear as precomputed embeddings per the assignment's stub carve-out.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import axis_size


@dataclass(frozen=True)
class FLPlan:
    """How one FL round maps onto the mesh for the train shape."""
    n_clients: int
    local_steps: int
    client_batch: int


def fl_plan(cfg: ArchConfig, shape: InputShape, mesh) -> FLPlan:
    if shape.kind != "train":
        raise ValueError(f"fl_plan needs a 'train' shape, got "
                         f"{shape.kind!r}")
    if cfg.fl_mode == "client_parallel":
        # one client per data(-pod) group
        nc = axis_size(mesh, "pod", "data")
    else:
        # sequential visitation; a few clients per round, batch-parallel within
        nc = 4
    nc = min(nc, shape.global_batch)
    return FLPlan(n_clients=nc, local_steps=2,
                  client_batch=max(shape.global_batch // nc, 1))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: InputShape, mesh,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch for train/prefill shapes (decode handled in steps.py
    together with the cache struct)."""
    S = shape.seq_len
    if shape.kind == "train":
        plan = fl_plan(cfg, shape, mesh)
        lead = (plan.n_clients, plan.local_steps, plan.client_batch)
        batch = {
            "tokens": _sds(lead + (S,), jnp.int32),
            "labels": _sds(lead + (S,), jnp.int32),
        }
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds(
                lead + (cfg.n_vision_tokens, cfg.d_model), dtype)
        if cfg.family == "audio":
            batch["audio_frames"] = _sds(
                lead + (cfg.n_audio_frames, cfg.d_model), dtype)
        return batch
    B = shape.global_batch
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = _sds(
                (B, cfg.n_vision_tokens, cfg.d_model), dtype)
        if cfg.family == "audio":
            batch["audio_frames"] = _sds(
                (B, cfg.n_audio_frames, cfg.d_model), dtype)
        return batch
    # decode: one new token; the KV/state cache is built in steps.py
    return {"tokens": _sds((B, 1), jnp.int32)}


def skip_reason(cfg: ArchConfig, shape: InputShape) -> str | None:
    """Assignment carve-outs (DESIGN.md §6)."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return "enc-dec audio backbone: context bounded by encoder frames"
        if not cfg.has_subquadratic_decode:
            return "pure full-attention arch: no sub-quadratic variant"
    return None
