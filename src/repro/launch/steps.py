"""Step builders: jit-able train / prefill / serve steps with shardings.

Each builder returns (fn, arg_structs, in_shardings, out_shardings) ready
for ``jax.jit(fn, in_shardings=..., out_shardings=...).lower(*arg_structs)``
— the dry-run and the real launchers share this code path.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, FLConfig, InputShape
from repro.core.rounds import init_global_state, make_round_fn
from repro.launch import sharding as sh
from repro.launch.specs import fl_plan, input_specs
from repro.models import transformer as tfm
from repro.models.registry import make_bundle


def build_train_step(cfg: ArchConfig, fl: FLConfig, shape: InputShape, mesh,
                     dtype=jnp.bfloat16):
    """One FL round (paper Alg. 1/2) as a single pjit step."""
    if getattr(cfg, "moe_dispatch", "gather") == "a2a":
        from repro.models import moe_dispatch
        moe_dispatch.set_dispatch_mesh(mesh)
    bundle = make_bundle(cfg, dtype)
    mode = cfg.fl_mode
    round_fn = make_round_fn(bundle, fl, mode)
    plan = fl_plan(cfg, shape, mesh)

    state_struct = jax.eval_shape(
        lambda k: init_global_state(bundle, fl, k), jax.random.PRNGKey(0))
    batch_struct = input_specs(cfg, shape, mesh, dtype)
    nex_struct = jax.ShapeDtypeStruct((plan.n_clients,), jnp.float32)
    lr_struct = jax.ShapeDtypeStruct((), jnp.float32)

    fsdp = mode == "client_sequential"
    state_shardings = sh.param_shardings(mesh, state_struct, fsdp=fsdp)
    batch_shardings = sh.train_batch_shardings(mesh, batch_struct)
    in_shardings = (state_shardings, batch_shardings,
                    sh.replicated(mesh, nex_struct),
                    sh.replicated(mesh, lr_struct))
    metrics_struct = jax.eval_shape(round_fn, state_struct, batch_struct,
                                    nex_struct, lr_struct)[1]
    out_shardings = (state_shardings, sh.replicated(mesh, metrics_struct))
    args = (state_struct, batch_struct, nex_struct, lr_struct)
    return round_fn, args, in_shardings, out_shardings


def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh,
                       dtype=jnp.bfloat16):
    """Prefill: full-sequence forward producing logits + KV/state cache."""
    if getattr(cfg, "moe_dispatch", "gather") == "a2a":
        from repro.models import moe_dispatch
        moe_dispatch.set_dispatch_mesh(mesh)
    def prefill(params, batch):
        out = tfm.forward_seq(cfg, params, batch, want_cache=True)
        return out["logits"], out["cache"]

    params_struct = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    batch_struct = input_specs(cfg, shape, mesh, dtype)
    params_sh = sh.param_shardings(mesh, params_struct, fsdp=False,
                                   ep=cfg.serve_expert_parallel)
    batch_sh = sh.serve_batch_shardings(mesh, batch_struct)
    out_struct = jax.eval_shape(prefill, params_struct, batch_struct)
    logits_sh = sh.serve_batch_shardings(mesh, out_struct[0])
    cache_sh = sh.cache_shardings(mesh, out_struct[1])
    return (prefill, (params_struct, batch_struct), (params_sh, batch_sh),
            (logits_sh, cache_sh))


def build_serve_step(cfg: ArchConfig, shape: InputShape, mesh,
                     dtype=jnp.bfloat16):
    """Decode: ONE new token against a cache of ``shape.seq_len``."""
    if getattr(cfg, "moe_dispatch", "gather") == "a2a":
        from repro.models import moe_dispatch
        moe_dispatch.set_dispatch_mesh(mesh)
    B, S = shape.global_batch, shape.seq_len

    def serve(params, tokens, cache, pos):
        return tfm.decode_step(cfg, params, tokens, cache, pos)

    params_struct = jax.eval_shape(
        lambda k: tfm.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    cache_struct = jax.eval_shape(
        lambda: tfm.init_cache(cfg, B, S, dtype))
    tok_struct = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

    params_sh = sh.param_shardings(mesh, params_struct, fsdp=False,
                                   ep=cfg.serve_expert_parallel)
    cache_sh = sh.cache_shardings(mesh, cache_struct)
    tok_sh = sh.serve_batch_shardings(mesh, tok_struct)
    out_struct = jax.eval_shape(serve, params_struct, tok_struct,
                                cache_struct, pos_struct)
    logits_sh = sh.serve_batch_shardings(mesh, out_struct[0])
    in_shardings = (params_sh, tok_sh, cache_sh, sh.replicated(mesh, pos_struct))
    out_shardings = (logits_sh, cache_sh)
    args = (params_struct, tok_struct, cache_struct, pos_struct)
    return serve, args, in_shardings, out_shardings


def build_step(cfg: ArchConfig, fl: FLConfig, shape: InputShape, mesh,
               dtype=jnp.bfloat16):
    if shape.kind == "train":
        return build_train_step(cfg, fl, shape, mesh, dtype)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, dtype)
    return build_serve_step(cfg, shape, mesh, dtype)
