"""Pod-scale federated training launcher (pjit on a real device mesh).

Builds the same step as the dry-run (build_train_step) but on a mesh
factorized from the devices that actually exist — 1 CPU here, a v5e pod in
production — and runs real rounds with synthetic federated data.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --algorithm fedfusion --rounds 10 --scale tiny
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_CONFIGS, INPUT_SHAPES
from repro.configs.base import FLConfig, InputShape
from repro.core.rounds import init_global_state
from repro.data.partition import source_partition
from repro.data.synth import token_stream
from repro.launch import sharding as sh
from repro.launch.mesh import mesh_context
from repro.launch.specs import fl_plan
from repro.launch.steps import build_train_step
from repro.models.registry import make_bundle
from repro.optim import exp_decay_per_round


def mesh_from_devices():
    """Factor the available devices into (data, model)."""
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--algorithm", default="fedavg",
                    choices=("fedavg", "fedmmd", "fedfusion", "fedl2"))
    ap.add_argument("--fusion-op", default="conv")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--scale", default="tiny", choices=("tiny", "full"))
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch]
    if args.scale == "tiny":
        cfg = dataclasses.replace(cfg.reduced(), vocab_size=256)
    fl = FLConfig(algorithm=args.algorithm, fusion_op=args.fusion_op,
                  local_steps=2, lr=args.lr)
    shape = InputShape("custom_train", args.seq_len, args.global_batch,
                       "train")

    mesh = mesh_from_devices()
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    round_fn, arg_structs, in_sh, out_sh = build_train_step(
        cfg, fl, shape, mesh, dtype=jnp.float32)
    step = jax.jit(round_fn, in_shardings=in_sh, out_shardings=out_sh)

    plan = fl_plan(cfg, shape, mesh)
    bundle = make_bundle(cfg, jnp.float32)
    with mesh_context(mesh):
        state = jax.jit(
            lambda k: init_global_state(bundle, fl, k),
            out_shardings=in_sh[0])(jax.random.PRNGKey(0))

        toks, src = token_stream(
            max(plan.n_clients * plan.client_batch * 4, 64), args.seq_len,
            vocab=cfg.vocab_size, n_sources=plan.n_clients)
        parts = source_partition(toks, src, plan.n_clients)
        rng = np.random.default_rng(0)
        lr_at = exp_decay_per_round(fl.lr, 0.995)

        def make_batch():
            per = []
            for c in range(plan.n_clients):
                pool = parts[c]["tokens"]
                idx = rng.choice(len(pool),
                                 (plan.local_steps, plan.client_batch))
                per.append(pool[idx])
            arr = np.stack(per)                      # [C, steps, B, S+1]
            return {"tokens": jnp.asarray(arr[..., :-1]),
                    "labels": jnp.asarray(arr[..., 1:])}

        # Pipelined round loop (repro.engine style): dispatch round r, then
        # assemble round r+1's batch on the host while the device trains,
        # and only force round r-1's metrics — the `float()` sync that used
        # to serialize host and device every round now trails by one round.
        nex = jnp.ones((plan.n_clients,), jnp.float32)
        batch = make_batch()
        pending = None
        t0 = time.perf_counter()
        for r in range(args.rounds):
            state, metrics = step(state, batch, nex, lr_at(r))
            if r + 1 < args.rounds:
                batch = make_batch()                 # overlaps device work
            if pending is not None:
                pr, pm, pt = pending
                print(f"round {pr+1:3d}  loss={float(pm['local_loss']):.4f}"
                      f"  {(time.perf_counter()-pt)*1e3:.0f} ms")
                t0 = time.perf_counter()
            pending = (r, metrics, t0)
        if pending is not None:
            pr, pm, pt = pending
            print(f"round {pr+1:3d}  loss={float(pm['local_loss']):.4f}  "
                  f"{(time.perf_counter()-pt)*1e3:.0f} ms")
    print("done")


if __name__ == "__main__":
    main()
