"""Pod-scale federated training launcher (pjit on a real device mesh).

Builds the same step as the dry-run (build_train_step) but on a mesh
factorized from the devices that actually exist — 1 CPU here, a v5e pod in
production — and runs real rounds with synthetic federated data.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --algorithm fedfusion --rounds 10 --scale tiny
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_CONFIGS, INPUT_SHAPES
from repro.configs.base import FLConfig, InputShape
from repro.core.rounds import init_global_state
from repro.fl.api import ALGORITHM_NAMES
from repro.data.partition import source_partition
from repro.data.synth import token_stream
from repro.launch import sharding as sh
from repro.launch.mesh import mesh_context
from repro.launch.specs import fl_plan
from repro.launch.steps import build_train_step
from repro.models.registry import make_bundle
from repro.optim import exp_decay_per_round


def mesh_from_devices():
    """Factor the available devices into (data, model)."""
    n = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0 and m <= n:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def run_engine(args, cfg, fl) -> None:
    """Drive the same workload through the client-parallel engine.

    Instead of the hand-rolled pjit round loop below, build a federated
    token dataset and hand it to a :class:`repro.fl.api.FederatedTrainer`
    on a mesh whose whole device count backs the CLIENT axis
    (``launch.mesh.make_engine_mesh``): the K-round superstep runs under
    ``shard_map``, clients split over ``data``, chunk staging/eval
    overlap/adaptive chunk sizing included.  On one device this
    degenerates to the single-device engine.
    """
    from repro.data.federated import ChaosConfig, FederatedDataset
    from repro.fl.api import (EngineOptions, EvalOptions, FederatedTrainer,
                              RunOptions)
    from repro.launch.mesh import client_axes, make_engine_mesh

    mesh = make_engine_mesh()
    shards = 1
    for a in client_axes(mesh):
        shards *= mesh.shape[a]
    # the sampled-client axis must split evenly over the mesh
    ladder = (tuple(float(v) for v in args.ladder.split(","))
              if args.ladder else ())
    if args.controller != "static" and args.uplink_codec == "identity":
        # adaptive compression needs something to adapt: default to the
        # top-k + error-feedback codec at the paper's keep fraction
        args.uplink_codec = "topk"
    fl = dataclasses.replace(
        fl, clients_per_round=max(fl.clients_per_round, shards)
        // shards * shards,
        participation=args.participation,
        over_provision=args.over_provision,
        buffer_k=args.buffer_k,
        staleness_alpha=args.staleness_alpha,
        uplink_codec=args.uplink_codec,
        topk_frac=args.topk_frac,
        controller=args.controller,
        ladder=ladder)
    # over-provisioned cohorts must still divide over the shards; size the
    # federation off the policy's cohort so sampling never starves
    from repro.fl.participation import make_policy
    c_round = make_policy(fl.participation).cohort_size(
        fl.clients_per_round, fl)
    c_round = -(-c_round // shards) * shards
    n_clients = 2 * max(fl.clients_per_round, c_round)
    bundle = make_bundle(cfg, jnp.float32)
    chaos = None
    if args.chaos:
        chaos = ChaosConfig(speed_sigma=args.chaos_speed_sigma,
                            jitter=args.chaos_jitter,
                            dropout=args.chaos_dropout,
                            truncation=args.chaos_truncation)

    toks, src = token_stream(
        max(n_clients * fl.local_batch * 8, 128), args.seq_len,
        vocab=cfg.vocab_size, n_sources=n_clients)
    test_toks, _ = token_stream(64, args.seq_len, vocab=cfg.vocab_size,
                                n_sources=n_clients, seed=1)
    data = FederatedDataset(source_partition(toks, src, n_clients),
                            {"tokens": test_toks}, seed=0, chaos=chaos)
    print(f"engine mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"clients/round={fl.clients_per_round} federation={n_clients}"
          + (f" participation={fl.participation}"
             if fl.participation != "full_sync" else "")
          + (" chaos=on" if chaos is not None else "")
          + (f" controller={fl.controller} uplink={fl.uplink_codec}"
             if fl.controller != "static" else ""))
    trainer = FederatedTrainer(bundle, fl, data, RunOptions(
        seed=0, verbose=True,
        eval=EvalOptions(every=max(args.rounds // 2, 1), examples=64),
        engine=EngineOptions(superstep_rounds="auto",
                             mesh=mesh if shards > 1 else None,
                             ef_store=args.ef_store,
                             telemetry=args.telemetry,
                             runlog=args.runlog,
                             halt_on_nonfinite=args.halt_on_nonfinite,
                             profile_dir=args.profile)))
    t0 = time.perf_counter()
    res = trainer.fit(args.rounds)
    dt = time.perf_counter() - t0
    print(f"done: {args.rounds} rounds in {dt:.1f}s "
          f"({args.rounds / dt:.2f} r/s)  stats={res.stats}")
    if args.telemetry and res.comm.history:
        last = res.comm.history[-1]
        tele = {k: v for k, v in last.items() if k.startswith("tele/")}
        if tele:
            print("telemetry (last round): " +
                  " ".join(f"{k}={v:.4g}" for k, v in sorted(tele.items())))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(ARCH_CONFIGS))
    ap.add_argument("--algorithm", default="fedavg",
                    choices=sorted(ALGORITHM_NAMES))
    ap.add_argument("--fusion-op", default="conv")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--scale", default="tiny", choices=("tiny", "full"))
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--engine", action="store_true",
                    help="run via the client-parallel shard_map engine "
                         "(repro.engine) instead of the pjit round loop")
    ap.add_argument("--ef-store", default="auto",
                    choices=("auto", "device", "host"),
                    help="engine only: EF residual backing — dense device "
                         "table, cohort-paged host store, or size-based "
                         "auto (paged runs are bitwise-equal)")
    ap.add_argument("--telemetry", action="store_true",
                    help="engine only: enable repro.obs on-device telemetry "
                         "taps (tele/... metrics; bitwise-invisible)")
    ap.add_argument("--runlog", default=None, metavar="PATH",
                    help="engine only: stream host span traces / events to "
                         "this JSONL file (repro.obs.RunLog)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="engine only: write a jax.profiler trace for the "
                         "whole run into DIR")
    ap.add_argument("--participation", default="full_sync",
                    help="engine only: round participation policy "
                         "(full_sync | deadline | buffered_async | any "
                         "registered name)")
    ap.add_argument("--over-provision", type=float, default=1.5,
                    help="deadline policy: cohort over-sampling factor")
    ap.add_argument("--buffer-k", type=int, default=0,
                    help="buffered_async policy: close the round at the "
                         "K-th arrival (0 -> clients_per_round // 2)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="buffered_async policy: staleness discount "
                         "exponent (1+s)^-alpha")
    ap.add_argument("--chaos", action="store_true",
                    help="engine only: inject deterministic client faults "
                         "(speed skew, dropouts, truncated local work)")
    ap.add_argument("--chaos-speed-sigma", type=float, default=1.0,
                    help="lognormal sigma of static per-client speeds")
    ap.add_argument("--chaos-jitter", type=float, default=0.1,
                    help="lognormal sigma of per-round completion jitter")
    ap.add_argument("--chaos-dropout", type=float, default=0.05,
                    help="per-round client dropout probability")
    ap.add_argument("--chaos-truncation", type=float, default=0.0,
                    help="probability a client truncates its local work")
    ap.add_argument("--halt-on-nonfinite", action="store_true",
                    help="engine only: checkpoint and stop cleanly at the "
                         "first chunk boundary after a non-finite metric")
    ap.add_argument("--uplink-codec", default="identity",
                    help="engine only: client->server delta codec "
                         "(identity | topk | topk_noef | quant | int8 | "
                         "int4 | mask | lowrank)")
    ap.add_argument("--topk-frac", type=float, default=0.05,
                    help="top-k family codecs: kept coordinate fraction "
                         "(also the adaptive ladder's capacity level)")
    ap.add_argument("--controller", default="static",
                    help="engine only: in-superstep adaptive compression "
                         "controller (static | ef_ratio | bytes_budget | "
                         "loss_trend | any registered name); non-static "
                         "defaults --uplink-codec to topk")
    ap.add_argument("--ladder", default="", metavar="V0,V1,...",
                    help="controller ladder: ascending effective levels "
                         "(topk fracs or quant bits) topping out at the "
                         "static codec parameter; empty -> default ladder")
    args = ap.parse_args()

    cfg = ARCH_CONFIGS[args.arch]
    if args.scale == "tiny":
        cfg = dataclasses.replace(cfg.reduced(), vocab_size=256)
    fl = FLConfig(algorithm=args.algorithm, fusion_op=args.fusion_op,
                  local_steps=2, lr=args.lr)

    if args.engine:
        run_engine(args, cfg, dataclasses.replace(
            fl, clients_per_round=4, local_batch=args.global_batch))
        return
    shape = InputShape("custom_train", args.seq_len, args.global_batch,
                       "train")

    mesh = mesh_from_devices()
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    round_fn, arg_structs, in_sh, out_sh = build_train_step(
        cfg, fl, shape, mesh, dtype=jnp.float32)
    step = jax.jit(round_fn, in_shardings=in_sh, out_shardings=out_sh)

    plan = fl_plan(cfg, shape, mesh)
    bundle = make_bundle(cfg, jnp.float32)
    with mesh_context(mesh):
        state = jax.jit(
            lambda k: init_global_state(bundle, fl, k),
            out_shardings=in_sh[0])(jax.random.PRNGKey(0))

        toks, src = token_stream(
            max(plan.n_clients * plan.client_batch * 4, 64), args.seq_len,
            vocab=cfg.vocab_size, n_sources=plan.n_clients)
        parts = source_partition(toks, src, plan.n_clients)
        rng = np.random.default_rng(0)
        lr_at = exp_decay_per_round(fl.lr, 0.995)

        def make_batch():
            per = []
            for c in range(plan.n_clients):
                pool = parts[c]["tokens"]
                idx = rng.choice(len(pool),
                                 (plan.local_steps, plan.client_batch))
                per.append(pool[idx])
            arr = np.stack(per)                      # [C, steps, B, S+1]
            return {"tokens": jnp.asarray(arr[..., :-1]),
                    "labels": jnp.asarray(arr[..., 1:])}

        # Pipelined round loop (repro.engine style): dispatch round r, then
        # assemble round r+1's batch on the host while the device trains,
        # and only force round r-1's metrics — the `float()` sync that used
        # to serialize host and device every round now trails by one round.
        nex = jnp.ones((plan.n_clients,), jnp.float32)
        batch = make_batch()
        pending = None
        t0 = time.perf_counter()
        for r in range(args.rounds):
            state, metrics = step(state, batch, nex, lr_at(r))
            if r + 1 < args.rounds:
                batch = make_batch()                 # overlaps device work
            if pending is not None:
                pr, pm, pt = pending
                print(f"round {pr+1:3d}  loss={float(pm['local_loss']):.4f}"
                      f"  {(time.perf_counter()-pt)*1e3:.0f} ms")
                t0 = time.perf_counter()
            pending = (r, metrics, t0)
        if pending is not None:
            pr, pm, pt = pending
            print(f"round {pr+1:3d}  loss={float(pm['local_loss']):.4f}  "
                  f"{(time.perf_counter()-pt)*1e3:.0f} ms")
    print("done")


if __name__ == "__main__":
    main()
