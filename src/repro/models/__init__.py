from repro.models.registry import ModelBundle, make_bundle  # noqa: F401
