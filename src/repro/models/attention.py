"""GQA attention: blocked flash-style for train/prefill, cached for decode.

All functions are pure; params are dicts.  Shapes:
  q: [B, S, H, hd]    k/v: [B, S, KV, hd]   with H = KV * rep (GQA).

The sequence path is a blocked online-softmax (flash) implemented with
``lax.scan`` over query blocks and an inner scan over KV blocks, so the
S x S score matrix is never materialised — this is what makes the 32k
prefill shapes lowerable with sane memory.  Sliding-window layers slice a
static-length KV span per query block (FLOPs O(S * window), not O(S^2)).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

NEG_INF = -1e30


def attn_init(key, d_model, n_heads, n_kv_heads, head_dim, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype),
    }


def project_qkv(params, x, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


def project_out(params, o):
    B, S = o.shape[:2]
    return o.reshape(B, S, -1) @ params["wo"]


# ---------------------------------------------------------------------------
# Blocked flash attention (sequence mode)
# ---------------------------------------------------------------------------

def _block_attn(q_blk, k_blk, v_blk, q_pos, k_pos, carry, *, window, scale,
                causal=True, kv_valid=2**62):
    """One (q_block, kv_block) tile of online-softmax attention.

    q_blk [B,qb,KV,rep,hd]; k_blk/v_blk [B,kb,KV,hd];
    carry = (m [B,KV,rep,qb], l [B,KV,rep,qb], acc [B,qb,KV,rep,hd]).
    """
    m, l, acc = carry
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q_blk, k_blk,
                   preferred_element_type=jnp.float32) * scale
    mask = k_pos[None, :] <= q_pos[:, None] if causal else (
        jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool))
    mask &= (k_pos < kv_valid)[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
    acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, window=None, q_block=512, kv_block=1024,
                    q_offset=0, causal=True):
    """Blocked attention: causal (default), sliding-window, or bidirectional.

    q [B,Sq,H,hd], k/v [B,Sk,KV,hd]; returns [B,Sq,H,hd] in q.dtype.
    ``q_offset``: global position of q[0] (for prefill continuation).
    Non-block-aligned sequence lengths are zero-padded internally and the
    padded KV positions are masked out.
    """
    B, Sq0, H, hd = q.shape
    Sk0, KV = k.shape[1], k.shape[2]
    q_block = min(q_block, Sq0)
    kv_block = min(kv_block, Sk0)
    pad_q = (-Sq0) % q_block
    pad_k = (-Sk0) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Sk = Sq0 + pad_q, Sk0 + pad_k
    rep = H // KV
    scale = hd ** -0.5
    nq = Sq // q_block

    qs = q.reshape(B, nq, q_block, KV, rep, hd)
    qs = jnp.moveaxis(qs, 1, 0)  # [nq, B, qb, KV, rep, hd]

    span = None
    if window is not None:
        span = window + q_block
        span = -(-span // kv_block) * kv_block  # round up to kv_block
        if span >= Sk:
            span = None  # window covers everything -> global path

    def q_body(_, inputs):
        i, q_blk = inputs
        q_pos = q_offset + i * q_block + jnp.arange(q_block)
        m0 = jnp.full((B, KV, rep, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, q_block), jnp.float32)
        a0 = jnp.zeros((B, q_block, KV, rep, hd), jnp.float32)

        if span is None:
            k_src, v_src, k_start = k, v, 0
        else:
            start = jnp.clip(q_offset + (i + 1) * q_block - span, 0, Sk - span)
            k_src = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, span, KV, hd))
            v_src = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, span, KV, hd))
            k_start = start

        Sk_eff = k_src.shape[1]
        nk = Sk_eff // kv_block
        ks = jnp.moveaxis(k_src.reshape(B, nk, kv_block, KV, hd), 1, 0)
        vs = jnp.moveaxis(v_src.reshape(B, nk, kv_block, KV, hd), 1, 0)

        def kv_body(carry, kv_in):
            j, k_blk, v_blk = kv_in
            k_pos = k_start + j * kv_block + jnp.arange(kv_block)
            return _block_attn(q_blk, k_blk, v_blk, q_pos, k_pos, carry,
                               window=window, scale=scale, causal=causal,
                               kv_valid=Sk0), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(jnp.moveaxis(l, -1, 1), 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, hd)
    return out[:, :Sq0]


# ---------------------------------------------------------------------------
# Decode (one query token against a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, *, cache_len=None, window=None,
                     kernel=None):
    """q [B,1,H,hd]; caches [B,L,KV,hd]. Returns [B,1,H,hd].

    ``cache_len``: number of valid cache positions (int array or None=all).
    ``window``: for sliding-window layers whose cache is already the ring
    buffer, pass None (the cache itself is the window).
    ``kernel``: optional accelerated implementation (Pallas flash-decode);
    signature (q, k, v, valid_len) -> out.
    """
    B, _, H, hd = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    if kernel is not None:
        return kernel(q, k_cache, v_cache, cache_len)
    scale = hd ** -0.5
    qh = q.reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrd,blgd->bgrl", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(L)
    valid = jnp.ones((L,), bool) if cache_len is None else pos < cache_len
    if window is not None:
        hi = L if cache_len is None else cache_len
        valid &= pos >= hi - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrl,blgd->bgrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def reference_attention(q, k, v, *, window=None, q_offset=0, causal=True):
    """Naive O(S^2) oracle for tests."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qh = q.reshape(B, Sq, KV, rep, hd)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = (k_pos[None, :] <= q_pos[:, None] if causal else
            jnp.ones((Sq, k.shape[1]), bool))
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
