"""The paper's CNN models (§4.1.1), split into extractor / classifier.

The split matters: FedFusion keeps the *global feature extractor* E_g frozen
and fuses its feature maps with the local extractor's before the classifier
(paper Fig. 3).  Feature maps are NHWC; the fusion channel axis is the last.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.models.layers import dense_init


def _conv_init(key, k, cin, cout, dtype):
    ks = jax.random.split(key)
    return {
        "w": dense_init(ks[0], (k, k, cin, cout), dtype,
                        scale=1.0 / (k * (cin ** 0.5))),
        "b": jnp.zeros((cout,), dtype),
    }


def cnn_init(cfg: CNNConfig, key, dtype=jnp.float32):
    n_conv = len(cfg.conv_channels)
    keys = jax.random.split(key, n_conv + len(cfg.fc_units) + 1)
    convs = []
    cin = cfg.input_shape[-1]
    for i, cout in enumerate(cfg.conv_channels):
        convs.append(_conv_init(keys[i], 5, cin, cout, dtype))
        cin = cout
    h, w = cfg.feature_hw
    fcs = []
    d = h * w * cin
    for j, units in enumerate(cfg.fc_units):
        fcs.append({"w": dense_init(keys[n_conv + j], (d, units), dtype),
                    "b": jnp.zeros((units,), dtype)})
        d = units
    head = {"w": dense_init(keys[-1], (d, cfg.n_classes), dtype),
            "b": jnp.zeros((cfg.n_classes,), dtype)}
    return {"convs": convs, "fcs": fcs, "head": head}


def _maxpool(x, size, stride):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1),
        "VALID")


def cnn_extract(cfg: CNNConfig, params, x):
    """x [B,H,W,C_in] -> feature maps [B,h,w,C]."""
    h = x
    for conv in params["convs"]:
        h = jax.lax.conv_general_dilated(
            h, conv["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + conv["b"]
        h = jax.nn.relu(h)
        h = _maxpool(h, cfg.pool_size, cfg.pool_stride)
    return h


def cnn_head(cfg: CNNConfig, params, feats, *, rng=None):
    """feats [B,h,w,C] -> logits [B,n_classes]. rng enables dropout."""
    h = feats.reshape(feats.shape[0], -1)
    for i, fc in enumerate(params["fcs"]):
        h = jax.nn.relu(h @ fc["w"] + fc["b"])
        if rng is not None and cfg.dropout > 0:
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1 - cfg.dropout), 0.0)
    return h @ params["head"]["w"] + params["head"]["b"]


def cnn_apply(cfg: CNNConfig, params, x, *, rng=None):
    feats = cnn_extract(cfg, params, x)
    return {"features": feats, "logits": cnn_head(cfg, params, feats, rng=rng),
            "aux": jnp.zeros((), jnp.float32)}
