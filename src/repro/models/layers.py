"""Core layer primitives (pure functions over pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, dtype=jnp.float32, scale=None):
    """Truncated-normal fan-in init (LeCun-style)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps=1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def norm_init(kind, dim, dtype=jnp.float32):
    return layernorm_init(dim, dtype) if kind == "layernorm" else rmsnorm_init(dim, dtype)


def norm_apply(kind, params, x, eps=1e-6):
    return layernorm(params, x, eps) if kind == "layernorm" else rmsnorm(params, x, eps)


# ---------------------------------------------------------------------------
# MLP: SwiGLU ("silu") or plain GELU MLP ("gelu")
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, act, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "w1": dense_init(ks[0], (d_model, d_ff), dtype),
        "w2": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if act == "silu":
        p["w3"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_apply(params, x, act):
    h = x @ params["w1"]
    if act == "silu":
        h = jax.nn.silu(h) * (x @ params["w3"])
    else:
        h = jax.nn.gelu(h)
    return h @ params["w2"]


def mlp_flops(d_model, d_ff, act, n_tokens):
    mult = 3 if act == "silu" else 2
    return 2 * mult * d_model * d_ff * n_tokens


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d_model, dtype=jnp.float32):
    return {"table": dense_init(key, (vocab, d_model), dtype, scale=1.0)}


def embed_apply(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed_apply(params, x, head=None):
    """Tied (use embedding table) or separate LM head."""
    table = head if head is not None else params["table"]
    return x @ table.T if head is None else x @ table


def sinusoidal_positions(n_pos, dim, dtype=jnp.float32):
    """Whisper-style sinusoidal absolute position embeddings."""
    inv = np.exp(-np.log(10_000.0) * np.arange(dim // 2) / max(dim // 2 - 1, 1))
    pos = np.arange(n_pos)[:, None] * inv[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(pos), np.cos(pos)], axis=-1), dtype=dtype)


def sinusoidal_position_at(pos, dim, dtype=jnp.float32):
    """Single-position sinusoidal embedding [dim] for a traced scalar pos
    (avoids baking an O(max_len * dim) constant into decode HLO)."""
    half = dim // 2
    inv = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                  / max(half - 1, 1))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)
