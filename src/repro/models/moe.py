"""Mixture-of-Experts layer with capacity-based gather/scatter dispatch.

TPU-idiomatic design: instead of GShard's [T, E, C] one-hot dispatch tensors
(O(T*E*C) memory), the router computes token->expert top-k assignments and
each expert then gathers its top-C assigned tokens ("expert's choice among
the assigned"), runs the FFN as a batched einsum over [E, C, d] and
scatter-adds the weighted results back.  Memory is O(E*C*d); the gathers and
the [E, C, d] activation shard cleanly over an expert-parallel mesh axis
(tokens move via all-to-all inserted by GSPMD).

Tokens that exceed an expert's capacity are dropped (standard); the router
aux loss (Switch-style load balancing) discourages that.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init


def _shard_capacity(x):
    """Constrain [E, C, *] intermediates to shard C over the 'model' axis.

    Under an active mesh (jax.set_mesh), splitting the capacity dim turns
    the w2 row-parallel partial-sum all-reduce into a reduce-scatter and
    parallelises the gather/scatter paths — §Perf hillclimb 3.  No-op when
    there is no mesh, no 'model' axis, or C does not divide.
    """
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        return x   # jax 0.4.x: no ambient-mesh introspection; skip the hint
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[1] % mesh.shape["model"]:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(*([None, "model"] + [None] * (x.ndim - 2))))


def moe_init(key, d_model, n_experts, moe_d_ff, act, dtype=jnp.float32,
             dense_residual=False, d_ff=0):
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), dtype),
        "w1": dense_init(ks[1], (n_experts, d_model, moe_d_ff), dtype),
        "w2": dense_init(ks[2], (n_experts, moe_d_ff, d_model), dtype),
    }
    if act == "silu":
        p["w3"] = dense_init(ks[3], (n_experts, d_model, moe_d_ff), dtype)
    if dense_residual:
        from repro.models.layers import mlp_init
        p["dense"] = mlp_init(ks[4], d_model, d_ff, act, dtype)
    return p


def moe_apply(params, x, *, top_k, act, capacity_factor=1.25,
              dense_residual=False, full_capacity=False,
              shard_capacity=False):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar).

    ``full_capacity=True`` sets every expert's capacity to T (no token ever
    dropped) — used by the decode path, where T = B is tiny and dropping the
    single token of a sequence would corrupt generation.
    """
    B, S, d = x.shape
    E = params["router"].shape[1]
    xt = x.reshape(B * S, d)
    T = B * S

    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    topk_vals, topk_idx = jax.lax.top_k(gates, top_k)          # [T, k]
    assign = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(1)  # [T, E]
    scores = gates * assign                                    # gate if assigned

    # Switch-style load-balance aux loss.
    frac_tokens = assign.mean(axis=0)          # fraction routed to e
    frac_probs = gates.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) / top_k

    # Expert capacity: each expert picks its top-C assigned tokens.
    if full_capacity:
        cap = T
    else:
        cap = int(max(top_k * T / E * capacity_factor, 1))
        cap = min(cap, T)
    w_ec, idx_ec = jax.lax.top_k(scores.T, cap)                # [E, C]

    xe = jnp.take(xt, idx_ec.reshape(-1), axis=0)
    xe = xe.reshape(E, cap, d)                                 # [E, C, d]
    if shard_capacity:
        xe = _shard_capacity(xe)
    h = jnp.einsum("ecd,edf->ecf", xe, params["w1"])
    if act == "silu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, params["w3"])
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w2"])           # [E, C, d]
    if shard_capacity:
        ye = _shard_capacity(ye)

    ye = ye * w_ec[..., None].astype(ye.dtype)                 # gate weighting
    out = jnp.zeros((T, d), ye.dtype).at[idx_ec.reshape(-1)].add(
        ye.reshape(E * cap, d))
    out = out.reshape(B, S, d).astype(x.dtype)

    if dense_residual:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(params["dense"], x, act)
    return out, aux


def moe_reference(params, x, *, top_k, act, dense_residual=False):
    """Dense-compute oracle: every expert on every token, exact top-k mix.

    Capacity-free; used by tests as the semantic reference (the production
    path may drop over-capacity tokens, tests use capacity_factor covering
    all tokens so both match).
    """
    B, S, d = x.shape
    E = params["router"].shape[1]
    xt = x.reshape(B * S, d)
    gates = jax.nn.softmax(
        xt.astype(jnp.float32) @ params["router"].astype(jnp.float32), -1)
    topk_vals, topk_idx = jax.lax.top_k(gates, top_k)
    mask = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(1)
    w = gates * mask                                           # [T, E]

    h = jnp.einsum("td,edf->etf", xt, params["w1"])
    if act == "silu":
        h = jax.nn.silu(h) * jnp.einsum("td,edf->etf", xt, params["w3"])
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("etf,efd->etd", h, params["w2"])            # [E, T, d]
    out = jnp.einsum("te,etd->td", w.astype(y.dtype), y)
    out = out.reshape(B, S, d).astype(x.dtype)
    if dense_residual:
        from repro.models.layers import mlp_apply
        out = out + mlp_apply(params["dense"], x, act)
    return out
