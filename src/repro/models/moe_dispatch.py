"""Expert-parallel MoE with explicit shard_map all-to-all token dispatch.

§Perf (EXPERIMENTS.md, hillclimb 3 iter 2) showed that annotating the
capacity axis cannot fix the MoE collective term: the gather/scatter
anchor the sharding and GSPMD re-inserts the giant all-reduce.  The real
fix is restructuring the dispatch — each data shard routes its OWN tokens,
sends only its top-C picks per expert to the expert's home shard via
``lax.all_to_all`` (the top-k/E activation fraction), and receives the
results back.  This module implements that as a drop-in alternative to
``moe.moe_apply``.

Layout inside ``shard_map`` over the ``data`` axis (n_sh shards):
    tokens   x      [T_loc, d]           (sharded)
    experts  w1/w2  [E_loc, ...]         (sharded; E = n_sh * E_loc)
    router          [d, E]               (replicated)

Per shard:
  1. route local tokens, per-expert top-C pick  -> xe [E, C, d]
  2. all_to_all (send dim = expert home shard)  -> recv [n_sh, E_loc, C, d]
  3. local expert FFN over [E_loc, n_sh*C, d]
  4. all_to_all back, apply gate weights at the source, scatter-add.

Communication per shard: 2 * top_k/E-ish * T_loc * d * capacity_factor —
vs the replicated-expert all-reduce of the FULL [E, C, d] activations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import mlp_apply

if hasattr(jax, "shard_map"):          # jax >= 0.6
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:                                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

# Concrete mesh for shard_map, set by the launch layer before tracing
# (jax.sharding.get_mesh() is unavailable inside jit; the model call stack
# does not thread the mesh, so the launcher registers it here).
_DISPATCH_MESH = None


def set_dispatch_mesh(mesh) -> None:
    global _DISPATCH_MESH
    _DISPATCH_MESH = mesh


def _local_moe(xt, router, w1, w2, w3, *, top_k, act, capacity_factor,
               axis, mean_axes=None, tp_psum=False):
    """Per-shard body (runs under shard_map).  xt [T_loc, d]."""
    T, d = xt.shape
    E = router.shape[1]
    n_sh = (jax.lax.axis_size(axis) if hasattr(jax.lax, "axis_size")
            else jax.lax.psum(1, axis))  # jax 0.4.x compat
    E_loc = w1.shape[0]
    if E != n_sh * E_loc:
        raise ValueError(f"router has {E} experts but {n_sh} shards x "
                         f"{E_loc} local experts")

    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    topk_vals, topk_idx = jax.lax.top_k(gates, top_k)
    assign = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(1)
    scores = gates * assign

    # load-balance aux (Switch), averaged over ALL token shards (incl. pod)
    mean_axes = mean_axes or axis
    frac_tokens = jax.lax.pmean(assign.mean(axis=0), mean_axes)
    frac_probs = jax.lax.pmean(gates.mean(axis=0), mean_axes)
    aux = E * jnp.sum(frac_tokens * frac_probs) / top_k

    # per-SOURCE-shard capacity per expert
    cap = int(max(top_k * T / E * capacity_factor, 1))
    cap = min(cap, T)
    w_ec, idx_ec = jax.lax.top_k(scores.T, cap)                 # [E, C]

    xe = jnp.take(xt, idx_ec.reshape(-1), axis=0).reshape(E, cap, d)
    # group by expert home shard and exchange
    xe = xe.reshape(n_sh, E_loc, cap, d)
    xe_recv = jax.lax.all_to_all(xe, axis, split_axis=0, concat_axis=0,
                                 tiled=False)                   # [n_sh,E_loc,C,d]

    # local expert FFN over all received tokens.  w1/w3 arrive with the
    # FFN dim additionally sharded over 'model' (EP x TP): each model shard
    # computes its f/|model| slice and the w2 partial sums are psum'd —
    # without this the model axis idles during MoE and per-chip FLOPs
    # blow up by |model| (measured: t_comp 20.9 s -> 65.2 s on arctic).
    xw = xe_recv.transpose(1, 0, 2, 3).reshape(E_loc, n_sh * cap, d)
    h = jnp.einsum("ecd,edf->ecf", xw, w1)
    if act == "silu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xw, w3)
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("ecf,efd->ecd", h, w2)                      # [E_loc,nshC,d]
    if tp_psum:
        ye = jax.lax.psum(ye, "model")

    # send results back to the source shards
    ye = ye.reshape(E_loc, n_sh, cap, d).transpose(1, 0, 2, 3)  # [n_sh,E_loc,C,d]
    ye_back = jax.lax.all_to_all(ye, axis, split_axis=0, concat_axis=0,
                                 tiled=False)
    ye_back = ye_back.reshape(E, cap, d)

    # gate-weight at the source and scatter-add into token order
    ye_back = ye_back * w_ec[..., None].astype(ye_back.dtype)
    out = jnp.zeros((T, d), ye_back.dtype).at[idx_ec.reshape(-1)].add(
        ye_back.reshape(E * cap, d))
    return out.astype(xt.dtype), aux


def moe_apply_a2a(params, x, mesh=None, *, top_k, act, capacity_factor=1.25,
                  dense_residual=False, axis="data"):
    """Expert-parallel MoE forward with all-to-all dispatch.

    ``params`` as produced by ``moe.moe_init``; the expert tensors must be
    sharded over ``axis`` on dim 0 (param_shardings with ep=True does
    this).  x [B, S, d] sharded over ``axis`` on dim 0.
    Returns (out [B, S, d], aux scalar) — semantics of ``moe.moe_apply``.
    """
    if mesh is None:
        mesh = _DISPATCH_MESH
    if mesh is None:
        raise ValueError("moe_dispatch='a2a' needs a concrete mesh: call "
                         "moe_dispatch.set_dispatch_mesh(mesh) before "
                         "tracing (steps.build_* does this)")
    B, S, d = x.shape
    has_w3 = "w3" in params
    batch_axes = tuple(a for a in ("pod", axis) if a in mesh.axis_names)

    def body(xb, router, w1, w2, w3):
        xt = xb.reshape(-1, d)
        out, aux = _local_moe(xt, router, w1, w2, w3, top_k=top_k, act=act,
                              capacity_factor=capacity_factor, axis=axis,
                              mean_axes=batch_axes,
                              tp_psum="model" in mesh.axis_names)
        return out.reshape(xb.shape), aux

    w3 = params["w3"] if has_w3 else jnp.zeros_like(params["w1"])
    tp = "model" if "model" in mesh.axis_names else None
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes), P(), P(axis, None, tp), P(axis, tp, None),
                  P(axis, None, tp)),
        out_specs=(P(batch_axes), P()),
        **{_CHECK_KW: False})
    out, aux = fn(x, params["router"], params["w1"], params["w2"], w3)
    if dense_residual:
        out = out + mlp_apply(params["dense"], x, act)
    return out, aux
