"""Uniform ModelBundle API over all architectures (transformers + CNNs).

The FL core (FedAvg / FedMMD / FedFusion) is written against this protocol:
    bundle.init(key)                 -> params
    bundle.extract(params, batch)    -> (features, aux)   # trunk only
    bundle.head(params, features)    -> logits
    bundle.apply(params, batch)      -> {'features','logits','aux'}
    bundle.pool(features)            -> [B, C] pooled features (for MMD)
    bundle.labels(batch)             -> targets for the loss
    bundle.loss_kind                 -> 'lm' | 'classify'
    bundle.feature_channels          -> fusion channel width C
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Union

import jax.numpy as jnp

from repro.configs.base import ArchConfig, CNNConfig
from repro.models import cnn as cnn_mod
from repro.models import transformer as tfm


@dataclass(frozen=True)
class ModelBundle:
    name: str
    config: Union[ArchConfig, CNNConfig]
    init: Callable[..., Any]
    extract: Callable[..., Any]
    head: Callable[..., Any]
    apply: Callable[..., Dict[str, Any]]
    pool: Callable[..., Any]
    labels: Callable[[Dict[str, Any]], Any]
    loss_kind: str
    feature_channels: int


def make_bundle(cfg: Union[ArchConfig, CNNConfig], dtype=jnp.float32
                ) -> ModelBundle:
    if isinstance(cfg, CNNConfig):
        return _cnn_bundle(cfg, dtype)
    return _transformer_bundle(cfg, dtype)


def _cnn_bundle(cfg: CNNConfig, dtype) -> ModelBundle:
    def init(key):
        return cnn_mod.cnn_init(cfg, key, dtype)

    def extract(params, batch):
        return cnn_mod.cnn_extract(cfg, params, batch["x"]), jnp.zeros((), jnp.float32)

    def head(params, feats):
        return cnn_mod.cnn_head(cfg, params, feats)

    def apply(params, batch):
        return cnn_mod.cnn_apply(cfg, params, batch["x"])

    def pool(feats):           # [B,h,w,C] -> [B,C]
        return feats.mean(axis=(1, 2))

    return ModelBundle(
        name=cfg.name, config=cfg, init=init, extract=extract, head=head,
        apply=apply, pool=pool, labels=lambda b: b["y"],
        loss_kind="classify", feature_channels=cfg.conv_channels[-1])


def _transformer_bundle(cfg: ArchConfig, dtype) -> ModelBundle:
    def init(key):
        return tfm.init_params(cfg, key, dtype)

    def extract(params, batch):
        out = tfm.forward_seq(cfg, params, batch, want_logits=False)
        return out["features"], out["aux"]

    def head(params, feats):
        return tfm.head_apply(cfg, params, feats)

    def apply(params, batch):
        return tfm.forward_seq(cfg, params, batch)

    def pool(feats):           # [B,S,d] -> [B,d]
        return feats.mean(axis=1)

    def labels(batch):
        # next-token prediction: labels[t] = tokens[t+1]; last target is pad
        if "labels" in batch:
            return batch["labels"]
        toks = batch["tokens"]
        return jnp.concatenate([toks[:, 1:], toks[:, -1:]], axis=1)

    return ModelBundle(
        name=cfg.name, config=cfg, init=init, extract=extract, head=head,
        apply=apply, pool=pool, labels=labels, loss_kind="lm",
        feature_channels=cfg.d_model)


def decode_step(cfg: ArchConfig, params, tokens, cache, pos):
    return tfm.decode_step(cfg, params, tokens, cache, pos)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    return tfm.init_cache(cfg, batch, max_len, dtype)
