"""RecurrentGemma RG-LRU recurrent block (Griffin, arXiv:2402.19427).

Block = gated dual branch:
    branch A: linear -> causal conv1d(w=4) -> RG-LRU
    branch B: linear -> GeLU
    out     = linear(branch A * branch B)

RG-LRU recurrence (elementwise, width W):
    r_t = sigmoid(x_t @ W_a + b_a)            recurrence gate
    i_t = sigmoid(x_t @ W_x + b_x)            input gate
    log_a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = exp(log_a_t) * h_{t-1} + sqrt(1 - exp(2*log_a_t)) * (i_t * x_t)

Sequence mode uses `lax.associative_scan` over the linear recurrence
(h_t = a_t h_{t-1} + b_t), which parallelises over the sequence — the
TPU-native alternative to a step-wise scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

_C = 8.0


def rglru_init(key, d_model, lru_width, conv_width=4, dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], (d_model, lru_width), dtype),
        "w_gate": dense_init(ks[1], (d_model, lru_width), dtype),
        "conv_w": dense_init(ks[2], (conv_width, lru_width), dtype, scale=0.5),
        "conv_b": jnp.zeros((lru_width,), dtype),
        "lam": jnp.linspace(-2.0, 2.0, lru_width).astype(dtype),  # softplus arg
        "w_a": dense_init(ks[3], (lru_width, lru_width), dtype),
        "b_a": jnp.zeros((lru_width,), dtype),
        "w_i": dense_init(ks[4], (lru_width, lru_width), dtype),
        "b_i": jnp.zeros((lru_width,), dtype),
        "w_out": dense_init(ks[5], (lru_width, d_model), dtype),
    }


def _gates(params, x):
    """x [..., W] -> (log_a [..., W], gated input [..., W]) in f32."""
    x32 = x.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ params["w_i"].astype(jnp.float32)
                       + params["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * (i * x32)


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :], window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b


def rglru_apply(params, x, conv_width=4):
    """Sequence mode. x [B,S,d] -> [B,S,d]."""
    u = x @ params["w_x"]
    u = _causal_conv(u, params["conv_w"], params["conv_b"])
    log_a, b = _gates(params, u)
    a = jnp.exp(log_a)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    gate = jax.nn.gelu(x @ params["w_gate"])
    return (h * gate) @ params["w_out"]


def rglru_init_cache(batch, lru_width, conv_width=4, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, lru_width), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, lru_width), dtype),
    }


def rglru_decode(params, x, cache, conv_width=4):
    """x [B,1,d] -> (y [B,1,d], new_cache)."""
    u = x @ params["w_x"]                                      # [B,1,W]
    win = jnp.concatenate([cache["conv"], u], axis=1)
    u1 = jnp.einsum("bwc,wc->bc", win, params["conv_w"]) + params["conv_b"]
    log_a, b = _gates(params, u1)
    h = jnp.exp(log_a) * cache["h"] + b
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate"])
    y = (h.astype(x.dtype) * gate) @ params["w_out"]
    return y[:, None, :], {"h": h, "conv": win[:, 1:, :]}


def rglru_reference(params, x, conv_width=4):
    """Step-wise oracle for tests."""
    B, S, _ = x.shape
    cache = rglru_init_cache(B, params["w_x"].shape[1], conv_width, x.dtype)
    ys = []
    for t in range(S):
        y, cache = rglru_decode(params, x[:, t:t + 1], cache, conv_width)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
