"""Rotary position embeddings: standard, partial-rotary, and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions, head_dim_rot, theta):
    """positions [..., S] -> (cos, sin) of shape [..., S, head_dim_rot//2]."""
    half = head_dim_rot // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """Apply rotation to the first 2*half dims of x (split-halves convention).

    x: [..., S, H, hd]; cos/sin: [..., S, half] broadcast over heads.
    """
    half = cos.shape[-1]
    x_rot, x_pass = x[..., : 2 * half], x[..., 2 * half:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    c = cos[..., None, :]  # add head axis
    s = sin[..., None, :]
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1, out2, x_pass], axis=-1).astype(x.dtype)


def apply_rope(q, k, positions, *, theta, head_dim, partial_pct=1.0):
    """q [B,S,H,hd], k [B,S,KV,hd], positions [B,S] (or [S])."""
    rot = int(head_dim * partial_pct)
    rot -= rot % 2
    if rot == 0 or theta <= 0:
        return q, k
    cos, sin = rope_angles(positions, rot, theta)   # [B,S,half]
    if cos.ndim == 2:                               # [S,half] -> [1,S,half]
        cos, sin = cos[None], sin[None]
    return _rotate(q, cos, sin), _rotate(k, cos, sin)


def mrope_angles(positions_3d, head_dim, theta, sections):
    """Qwen2-VL multimodal RoPE.

    positions_3d: [3, B, S] (temporal, height, width position ids).
    sections: per-axis number of rotary *pairs*, sums to head_dim//2.
    Returns cos/sin [B, S, head_dim//2] where frequency slot j uses the
    position id of the section it falls in.
    """
    half = head_dim // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to "
                         f"head_dim//2 = {half}")
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # section id per frequency slot
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half)
    # pick the matching positional stream per slot: [B, S, half]
    pos = jnp.take(positions_3d, sec_id, axis=0)          # [half?, ...] wrong axis
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)    # [B, S, half]
    ang = pos * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_mrope(q, k, positions_3d, *, theta, head_dim, sections):
    cos, sin = mrope_angles(positions_3d, head_dim, theta, sections)
    return _rotate(q, cos, sin), _rotate(k, cos, sin)
