"""Mamba-2 SSD (state-space duality) block — chunked scan, TPU-friendly.

The SSD recurrence per head (state N, head dim P):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (x_t outer B_t)        [P, N]
    y_t = h_t @ C_t + D * x_t
is computed chunk-wise (arXiv:2405.21060 §6): quadratic attention-like
matmuls *within* a chunk (MXU work) and a `lax.scan` over chunk states
(sequential part shrinks by the chunk length).  All decays are computed in
log-space; `cum` is non-positive so every exp() is <= 1 (numerically safe).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def ssd_init(key, d_model, *, expand, d_state, head_dim, conv_width,
             dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * d_state   # conv runs over [x, B, C] jointly
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (gate), xBC, dt]
        "w_in": dense_init(ks[0], (d_model, d_inner + conv_ch + n_heads), dtype),
        "conv_w": dense_init(ks[1], (conv_width, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "D": jnp.ones((n_heads,), dtype),
        "w_out": dense_init(ks[2], (d_inner, d_model), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x [B,S,C], w [W,C] -> [B,S,C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],                     # [W, 1, C] depthwise
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b


def _split_proj(params, x, cfg_dims):
    d_inner, d_state, n_heads = cfg_dims
    proj = x @ params["w_in"]
    conv_ch = d_inner + 2 * d_state
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner:d_inner + conv_ch]
    dt = proj[..., d_inner + conv_ch:]
    return z, xBC, dt


def ssd_apply(params, x, *, expand, d_state, head_dim, chunk, conv_width):
    """Sequence mode. x [B,S,d] -> y [B,S,d]."""
    Bsz, S, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    z, xBC, dt = _split_proj(params, x, (d_inner, d_state, n_heads))
    xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"], params["conv_b"]))
    xs = xBC[..., :d_inner].reshape(Bsz, S, n_heads, head_dim)
    Bmat = xBC[..., d_inner:d_inner + d_state]                 # [B,S,N]
    Cmat = xBC[..., d_inner + d_state:]                        # [B,S,N]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # [H] < 0

    y = _ssd_chunked(xs, Bmat, Cmat, dt, A, chunk)
    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(Bsz, S, d_inner)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"]


def _ssd_chunked(xs, Bmat, Cmat, dt, A, chunk):
    """Core chunked SSD. xs [B,S,H,P]; B/C [B,S,N]; dt [B,S,H]; A [H]."""
    Bsz, S, H, P = xs.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    def c(x_):  # chunkify leading seq axis -> [nc, B, Q, ...]
        return jnp.moveaxis(x_.reshape(Bsz, nc, Q, *x_.shape[2:]), 1, 0)

    xc, Bc, Cc, dtc = c(xs), c(Bmat), c(Cmat), c(dt)
    a = dtc * A[None, None, None, :]                 # [nc,B,Q,H], <= 0
    cum = jnp.cumsum(a, axis=2)                      # within-chunk log decay

    def body(h_prev, inp):
        x_q, B_q, C_q, dt_q, a_q, cum_q = inp
        # intra-chunk: attention-like lower-triangular mix
        scores = jnp.einsum("bqn,bkn->bqk", C_q, B_q,
                            preferred_element_type=jnp.float32)
        # mask in LOG space before the exp: the upper triangle has positive
        # log-decay (exp -> inf) whose gradient would be NaN even after a
        # post-hoc where(); -1e30 exps to exactly 0 with zero gradient.
        diff = cum_q[:, :, None, :] - cum_q[:, None, :, :]            # [B,Q,K,H]
        tri = jnp.tril(jnp.ones((x_q.shape[1], x_q.shape[1]), bool))
        decay = jnp.exp(jnp.where(tri[None, :, :, None], diff, -1e30))
        dtx = dt_q[..., None] * x_q.astype(jnp.float32)               # [B,K,H,P]
        y_intra = jnp.einsum("bqk,bqkh,bkhp->bqhp", scores, decay, dtx)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", C_q.astype(jnp.float32),
                             jnp.exp(cum_q), h_prev)
        # new carried state
        w_k = jnp.exp(cum_q[:, -1:, :] - cum_q) * dt_q                # [B,K,H]
        S_c = jnp.einsum("bkh,bkhp,bkn->bhpn", w_k,
                         x_q.astype(jnp.float32), B_q.astype(jnp.float32))
        h_new = jnp.exp(cum_q[:, -1])[:, :, None, None] * h_prev + S_c
        return h_new, (y_intra + y_inter).astype(xs.dtype)

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, yc = jax.lax.scan(body, h0, (xc, Bc, Cc, dtc, a, cum))
    return jnp.moveaxis(yc, 0, 1).reshape(Bsz, S, H, P)


# ---------------------------------------------------------------------------
# Decode (single token, carried state)
# ---------------------------------------------------------------------------

def ssd_init_cache(batch, d_model, *, expand, d_state, head_dim, conv_width,
                   dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * d_state
    return {
        "h": jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, conv_ch), dtype),
    }


def ssd_decode(params, x, cache, *, expand, d_state, head_dim, conv_width):
    """x [B,1,d] -> (y [B,1,d], new_cache)."""
    Bsz, _, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    z, xBC, dt = _split_proj(params, x, (d_inner, d_state, n_heads))
    # conv over stored window + current input
    win = jnp.concatenate([cache["conv"], xBC], axis=1)        # [B,W,ch]
    conv_out = jnp.einsum("bwc,wc->bc", win, params["conv_w"]) + params["conv_b"]
    xBC = jax.nn.silu(conv_out)[:, None, :]
    new_conv = win[:, 1:, :]

    xs = xBC[..., :d_inner].reshape(Bsz, n_heads, head_dim)
    Bv = xBC[:, 0, d_inner:d_inner + d_state]                  # [B,N]
    Cv = xBC[:, 0, d_inner + d_state:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))  # [B,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * A[None, :])                          # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtv, xs.astype(jnp.float32),
                     Bv.astype(jnp.float32))
    h = decay[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, Cv.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"], {"h": h, "conv": new_conv}


def ssd_reference(params, x, *, expand, d_state, head_dim, conv_width):
    """Step-by-step scan oracle (no chunking) for tests."""
    Bsz, S, d_model = x.shape
    cache = ssd_init_cache(Bsz, d_model, expand=expand, d_state=d_state,
                           head_dim=head_dim, conv_width=conv_width,
                           dtype=x.dtype)
    ys = []
    for t in range(S):
        y, cache = ssd_decode(params, x[:, t:t + 1], cache, expand=expand,
                              d_state=d_state, head_dim=head_dim,
                              conv_width=conv_width)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
