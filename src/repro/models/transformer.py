"""Unified transformer-family model covering the assigned architecture pool.

One parameterised stack supports: dense GQA (global / sliding-window /
local:global patterns), MoE FFNs (with optional dense residual), Mamba-2 SSD
blocks, RG-LRU hybrid blocks, Qwen2-VL M-RoPE with stub vision embeddings,
and the Whisper encoder-decoder with stub audio-frame embeddings.

Layers are grouped into the pattern's minimal repeating *cycle* and executed
with ``lax.scan`` over full cycles (stacked params, leading axis = number of
cycles) + an unrolled tail — this keeps HLO size O(cycle) instead of
O(n_layers), which matters when lowering 38-layer models for 512 devices.

Everything is pure: ``params`` and ``cache`` are pytrees (dicts).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSD,
                                ArchConfig)
from repro.models import attention as attn
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (dense_init, embed_init, mlp_apply, mlp_init,
                                 norm_apply, norm_init,
                                 sinusoidal_position_at, sinusoidal_positions)
from repro.models.moe import moe_apply, moe_init
from repro.models.rope import apply_mrope, apply_rope


# ---------------------------------------------------------------------------
# Pattern -> cycles
# ---------------------------------------------------------------------------

def pattern_cycle(pattern):
    """Minimal c with pattern[i] == pattern[i % c] for all i."""
    n = len(pattern)
    for c in range(1, n + 1):
        if all(pattern[i] == pattern[i % c] for i in range(n)):
            return c
    return n


def cycle_split(pattern):
    c = pattern_cycle(pattern)
    n_full = len(pattern) // c
    rem = len(pattern) - n_full * c
    return c, n_full, rem


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _norm_kind(cfg: ArchConfig) -> str:
    return "layernorm" if cfg.family == "audio" else "rmsnorm"


def _layer_init(key, cfg: ArchConfig, kind: str, *, cross: bool,
                dtype) -> Dict[str, Any]:
    nk = _norm_kind(cfg)
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": norm_init(nk, cfg.d_model, dtype)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL, "enc"):
        p["attn"] = attn.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, dtype)
        if cross:
            p["lnx"] = norm_init(nk, cfg.d_model, dtype)
            p["xattn"] = attn.attn_init(ks[1], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, dtype)
        p["ln2"] = norm_init(nk, cfg.d_model, dtype)
        if cfg.n_experts:
            p["moe"] = moe_init(ks[2], cfg.d_model, cfg.n_experts,
                                cfg.moe_d_ff, cfg.act, dtype,
                                dense_residual=cfg.dense_residual,
                                d_ff=cfg.d_ff)
        else:
            p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    elif kind == RGLRU:
        p["rglru"] = rglru_mod.rglru_init(ks[0], cfg.d_model, cfg.lru_width,
                                          dtype=dtype)
        p["ln2"] = norm_init(nk, cfg.d_model, dtype)
        p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    elif kind == SSD:
        p["ssd"] = ssd_mod.ssd_init(ks[0], cfg.d_model, expand=cfg.ssm_expand,
                                    d_state=cfg.ssm_state,
                                    head_dim=cfg.ssm_head_dim,
                                    conv_width=cfg.ssm_conv_width, dtype=dtype)
    else:
        raise ValueError(kind)
    return p


def _layer_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      dtype, *, cross: bool):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        L = max_len if kind == ATTN_GLOBAL else min(cfg.sliding_window, max_len)
        c = {
            "k": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
        if cross:
            F = cfg.n_audio_frames
            c["xk"] = jnp.zeros((batch, F, cfg.n_kv_heads, cfg.head_dim), dtype)
            c["xv"] = jnp.zeros((batch, F, cfg.n_kv_heads, cfg.head_dim), dtype)
        return c
    if kind == SSD:
        return ssd_mod.ssd_init_cache(batch, cfg.d_model,
                                      expand=cfg.ssm_expand,
                                      d_state=cfg.ssm_state,
                                      head_dim=cfg.ssm_head_dim,
                                      conv_width=cfg.ssm_conv_width,
                                      dtype=dtype)
    if kind == RGLRU:
        return rglru_mod.rglru_init_cache(batch, cfg.lru_width,
                                          dtype=dtype)
    raise ValueError(kind)


def _apply_rope_any(cfg: ArchConfig, q, k, positions, mrope_pos):
    if cfg.family == "audio" or cfg.rope_theta <= 0:
        return q, k  # whisper uses absolute sinusoidal positions
    if cfg.mrope and mrope_pos is not None:
        return apply_mrope(q, k, mrope_pos, theta=cfg.rope_theta,
                           head_dim=cfg.head_dim,
                           sections=cfg.mrope_sections)
    return apply_rope(q, k, positions, theta=cfg.rope_theta,
                      head_dim=cfg.head_dim,
                      partial_pct=cfg.partial_rotary_pct)


def _layer_seq(cfg: ArchConfig, kind: str, p, h, aux, *, positions,
               mrope_pos, enc_out, want_cache, max_len):
    """Sequence-mode layer. Returns (h, aux, cache_or_None)."""
    nk = _norm_kind(cfg)
    cache = None
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        q, k, v = attn.project_qkv(p["attn"], hn, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim)
        q, k = _apply_rope_any(cfg, q, k, positions, mrope_pos)
        window = cfg.sliding_window if kind == ATTN_LOCAL else None
        if cfg.attn_impl == "pallas":
            # Pallas flash TRAIN kernel (custom_vjp): probability tiles
            # stay in VMEM in both directions (kernels/flash_attn.py).
            # interpret=True on CPU; compiles natively on TPU.
            from repro.kernels.flash_attn import make_flash_attention
            interp = jax.devices()[0].platform != "tpu"
            o = make_flash_attention(causal=True, window=window,
                                     interpret=interp)(q, k, v)
        elif cfg.remat == "attn":
            # store only (q, k, v); recompute the blocked softmax in the
            # backward — otherwise the kv-block scan saves its probability
            # tiles as residuals and the S x S matrix hits HBM (§Perf)
            o = jax.checkpoint(
                lambda q_, k_, v_: attn.flash_attention(q_, k_, v_,
                                                        window=window))(q, k, v)
        else:
            o = attn.flash_attention(q, k, v, window=window)
        h = h + attn.project_out(p["attn"], o)
        if want_cache:
            cache = _seq_kv_to_cache(cfg, kind, k, v, max_len)
        if "xattn" in p:
            hx = norm_apply(nk, p["lnx"], h, cfg.norm_eps)
            qx, kx, vx = attn.project_qkv(p["xattn"], hx, cfg.n_heads,
                                          cfg.n_kv_heads, cfg.head_dim)
            _, ekx, evx = attn.project_qkv(p["xattn"], enc_out, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.head_dim)
            ox = attn.flash_attention(qx, ekx, evx, causal=False)
            h = h + attn.project_out(p["xattn"], ox)
            if want_cache:
                cache["xk"], cache["xv"] = ekx, evx
        hn2 = norm_apply(nk, p["ln2"], h, cfg.norm_eps)
        if cfg.n_experts:
            if cfg.moe_dispatch == "a2a":
                from repro.models.moe_dispatch import moe_apply_a2a
                ff, a = moe_apply_a2a(p["moe"], hn2, top_k=cfg.top_k,
                                      act=cfg.act,
                                      capacity_factor=cfg.moe_capacity,
                                      dense_residual=cfg.dense_residual)
            else:
                ff, a = moe_apply(p["moe"], hn2, top_k=cfg.top_k, act=cfg.act,
                                  capacity_factor=cfg.moe_capacity,
                                  dense_residual=cfg.dense_residual,
                                  shard_capacity=cfg.moe_shard_capacity)
            aux = aux + a
        else:
            ff = mlp_apply(p["ffn"], hn2, cfg.act)
        h = h + ff
    elif kind == RGLRU:
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        h = h + rglru_mod.rglru_apply(p["rglru"], hn)
        hn2 = norm_apply(nk, p["ln2"], h, cfg.norm_eps)
        h = h + mlp_apply(p["ffn"], hn2, cfg.act)
        if want_cache:
            cache = rglru_mod.rglru_init_cache(h.shape[0], cfg.lru_width,
                                               dtype=h.dtype)
            # NOTE: state after a full-sequence associative scan is the last
            # h; recompute cheaply for serving prefill:
            cache = _rglru_seq_cache(p["rglru"], hn, cache)
    elif kind == SSD:
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        if want_cache:
            y, cache = _ssd_seq_with_cache(cfg, p["ssd"], hn)
        else:
            y = ssd_mod.ssd_apply(p["ssd"], hn, expand=cfg.ssm_expand,
                                  d_state=cfg.ssm_state,
                                  head_dim=cfg.ssm_head_dim,
                                  chunk=cfg.ssm_chunk,
                                  conv_width=cfg.ssm_conv_width)
        h = h + y
    else:
        raise ValueError(kind)
    return h, aux, cache


def _seq_kv_to_cache(cfg, kind, k, v, max_len):
    """Store the sequence's K/V into a fixed-size cache buffer."""
    B, S = k.shape[:2]
    if kind == ATTN_GLOBAL:
        L = max_len
        pad = L - S
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    # local: keep last `window` positions, ring-aligned so that
    # buffer[t % L] == kv at position t.
    L = min(cfg.sliding_window, max_len)
    if S <= L:
        pad = L - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": k, "v": v}
    last_k, last_v = k[:, S - L:], v[:, S - L:]
    shift = S % L  # roll so entry for position t sits at t % L
    return {"k": jnp.roll(last_k, shift, axis=1),
            "v": jnp.roll(last_v, shift, axis=1)}


def _rglru_seq_cache(p, hn, cache):
    """Compute the post-sequence RG-LRU state + conv window for serving."""
    u = hn @ p["w_x"]
    conv_tail = u[:, -(cache["conv"].shape[1]):, :]
    uc = rglru_mod._causal_conv(u, p["conv_w"], p["conv_b"])
    log_a, b = rglru_mod._gates(p, uc)
    a = jnp.exp(log_a)

    def combine(l, r):
        return l[0] * r[0], r[0] * l[1] + r[1]

    _, hseq = jax.lax.associative_scan(combine, (a, b), axis=1)
    return {"h": hseq[:, -1], "conv": conv_tail}


def _ssd_seq_with_cache(cfg, p, hn):
    """SSD over the sequence, also returning the final (state, conv) cache.

    Runs the step-wise state once more is wasteful; instead reuse the chunked
    scan but capture the final chunk state by re-running the last chunk's
    state update — cheap relative to the full pass.
    """
    y = ssd_mod.ssd_apply(p, hn, expand=cfg.ssm_expand, d_state=cfg.ssm_state,
                          head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                          conv_width=cfg.ssm_conv_width)
    B = hn.shape[0]
    cache = ssd_mod.ssd_init_cache(B, cfg.d_model, expand=cfg.ssm_expand,
                                   d_state=cfg.ssm_state,
                                   head_dim=cfg.ssm_head_dim,
                                   conv_width=cfg.ssm_conv_width,
                                   dtype=hn.dtype)
    # final state via a single pass of the recurrence on the last token only
    # is NOT exact; for serving correctness we run the step recurrence over
    # the final chunk seeded by the chunked scan's penultimate state.  For
    # the framework's serve path, prefill uses `prefill_exact_cache=True`
    # in serve.py; the dry-run only needs shapes.
    d_inner = cfg.ssm_expand * cfg.d_model
    proj = hn @ p["w_in"]
    conv_ch = d_inner + 2 * cfg.ssm_state
    xBC = proj[..., d_inner:d_inner + conv_ch]
    W1 = cache["conv"].shape[1]
    cache["conv"] = xBC[:, -W1:, :]
    # exact final state: decay-weighted sum over the whole sequence
    xBCc = jax.nn.silu(ssd_mod._causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs = xBCc[..., :d_inner].reshape(B, hn.shape[1], -1, cfg.ssm_head_dim)
    Bmat = xBCc[..., d_inner:d_inner + cfg.ssm_state]
    dt = jax.nn.softplus(proj[..., d_inner + conv_ch:].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = dt * A[None, None, :]
    rev_cum = jnp.cumsum(a[:, ::-1], axis=1)[:, ::-1] - a  # sum_{j>t} a_j
    w = jnp.exp(rev_cum) * dt                              # [B,S,H]
    cache["h"] = jnp.einsum("bsh,bshp,bsn->bhpn", w, xs.astype(jnp.float32),
                            Bmat.astype(jnp.float32))
    return y, cache


def _layer_decode(cfg: ArchConfig, kind: str, p, h, cache, *, pos,
                  positions, mrope_pos):
    """Decode-mode layer: h [B,1,d]. Returns (h, new_cache)."""
    nk = _norm_kind(cfg)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        q, k, v = attn.project_qkv(p["attn"], hn, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim)
        q, k = _apply_rope_any(cfg, q, k, positions, mrope_pos)
        L = cache["k"].shape[1]
        slot = pos % L if kind == ATTN_LOCAL else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        valid = jnp.minimum(pos + 1, L)
        o = attn.decode_attention(q, ck, cv, cache_len=valid)
        h = h + attn.project_out(p["attn"], o)
        new_cache = dict(cache, k=ck, v=cv)
        if "xattn" in p:
            hx = norm_apply(nk, p["lnx"], h, cfg.norm_eps)
            qx, _, _ = attn.project_qkv(p["xattn"], hx, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim)
            ox = attn.decode_attention(qx, cache["xk"], cache["xv"])
            h = h + attn.project_out(p["xattn"], ox)
        hn2 = norm_apply(nk, p["ln2"], h, cfg.norm_eps)
        if cfg.n_experts:
            # full capacity at decode: T = B tokens, never drop any
            ff, _ = moe_apply(p["moe"], hn2, top_k=cfg.top_k, act=cfg.act,
                              dense_residual=cfg.dense_residual,
                              full_capacity=True)
        else:
            ff = mlp_apply(p["ffn"], hn2, cfg.act)
        h = h + ff
        return h, new_cache
    if kind == RGLRU:
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        y, new_cache = rglru_mod.rglru_decode(p["rglru"], hn, cache)
        h = h + y
        hn2 = norm_apply(nk, p["ln2"], h, cfg.norm_eps)
        return h + mlp_apply(p["ffn"], hn2, cfg.act), new_cache
    if kind == SSD:
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        y, new_cache = ssd_mod.ssd_decode(p["ssd"], hn, cache,
                                          expand=cfg.ssm_expand,
                                          d_state=cfg.ssm_state,
                                          head_dim=cfg.ssm_head_dim,
                                          conv_width=cfg.ssm_conv_width)
        return h + y, new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Dict[str, Any]:
    c, n_full, rem = cycle_split(cfg.block_pattern)
    keys = jax.random.split(key, cfg.n_layers + cfg.n_enc_layers + 4)
    cross = cfg.n_enc_layers > 0

    cycles = []
    for j in range(c):
        layers = [_layer_init(keys[i * c + j], cfg, cfg.block_pattern[j],
                              cross=cross, dtype=dtype)
                  for i in range(n_full)]
        cycles.append(_stack(layers) if n_full > 1 else
                      jax.tree.map(lambda x: x[None], layers[0]))
    tail = tuple(
        _layer_init(keys[n_full * c + j], cfg, cfg.block_pattern[n_full * c + j],
                    cross=cross, dtype=dtype)
        for j in range(rem))

    ek = keys[cfg.n_layers]
    params: Dict[str, Any] = {
        "embed": embed_init(ek, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(_norm_kind(cfg), cfg.d_model, dtype),
        "cycles": tuple(cycles),
        "tail": tail,
    }
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": dense_init(keys[cfg.n_layers + 1], (cfg.d_model, cfg.vocab_size),
                            dtype)}
    if cfg.family == "vlm":
        params["vis_proj"] = {
            "w": dense_init(keys[cfg.n_layers + 2], (cfg.d_model, cfg.d_model),
                            dtype)}
    if cfg.n_enc_layers:
        enc_layers = [
            _layer_init(keys[cfg.n_layers + 3 + i], cfg, "enc", cross=False,
                        dtype=dtype)
            for i in range(cfg.n_enc_layers)]
        params["enc"] = {
            "layers": _stack(enc_layers),
            "norm": norm_init(_norm_kind(cfg), cfg.d_model, dtype),
            "in_proj": {"w": dense_init(keys[-1], (cfg.d_model, cfg.d_model),
                                        dtype)},
        }
    return params


# ---------------------------------------------------------------------------
# Forward: sequence mode (train / prefill)
# ---------------------------------------------------------------------------

def _run_encoder(cfg, params, frames):
    """Whisper encoder over stub frame embeddings [B,F,d]."""
    nk = _norm_kind(cfg)
    h = frames @ params["enc"]["in_proj"]["w"]
    h = h + sinusoidal_positions(frames.shape[1], cfg.d_model, h.dtype)[None]

    def body(h, p):
        hn = norm_apply(nk, p["ln1"], h, cfg.norm_eps)
        q, k, v = attn.project_qkv(p["attn"], hn, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim)
        o = attn.flash_attention(q, k, v, causal=False)
        h = h + attn.project_out(p["attn"], o)
        hn2 = norm_apply(nk, p["ln2"], h, cfg.norm_eps)
        return h + mlp_apply(p["ffn"], hn2, cfg.act), None

    h, _ = jax.lax.scan(body, h, params["enc"]["layers"])
    return norm_apply(nk, params["enc"]["norm"], h, cfg.norm_eps)


def _embed_inputs(cfg, params, batch):
    tokens = batch["tokens"]
    h = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        ve = batch["vision_embeds"] @ params["vis_proj"]["w"]
        nv = ve.shape[1]
        h = jnp.concatenate([ve.astype(h.dtype), h[:, nv:]], axis=1)
    if cfg.family == "audio":
        h = h + sinusoidal_positions(h.shape[1], cfg.d_model, h.dtype)[None]
    return h


def forward_seq(cfg: ArchConfig, params, batch, *, want_cache=False,
                want_logits=True, max_cache_len: Optional[int] = None):
    """batch: {'tokens': [B,S] int32, 'vision_embeds'?, 'audio_frames'?,
    'mrope_positions'? [3,B,S]} -> {'logits','features','aux','cache'?}.
    """
    h = _embed_inputs(cfg, params, batch)
    B, S = h.shape[:2]
    max_len = max_cache_len or S
    positions = jnp.arange(S)
    mrope_pos = batch.get("mrope_positions")
    if cfg.mrope and mrope_pos is None:
        mrope_pos = jnp.broadcast_to(positions, (3, B, S))
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _run_encoder(cfg, params, batch["audio_frames"])

    c, n_full, rem = cycle_split(cfg.block_pattern)
    kinds = cfg.block_pattern[:c]
    aux0 = jnp.zeros((), jnp.float32)

    def cycle_body(carry, layer_params):
        h, aux = carry
        caches = []
        for j, kind in enumerate(kinds):
            p = jax.tree.map(lambda x: x, layer_params[j])
            h, aux, cache = _layer_seq(cfg, kind, p, h, aux,
                                       positions=positions,
                                       mrope_pos=mrope_pos, enc_out=enc_out,
                                       want_cache=want_cache, max_len=max_len)
            caches.append(cache)
        return (h, aux), tuple(caches) if want_cache else None

    if cfg.remat == "layer" and not want_cache:
        # classic activation checkpointing over the layer-cycle scan: the
        # backward recomputes each cycle from its carry instead of storing
        # every intermediate activation (memory O(n_cycles * [B,S,d]))
        cycle_body = jax.checkpoint(cycle_body)
    (h, aux), cycle_caches = jax.lax.scan(cycle_body, (h, aux0),
                                          params["cycles"])
    tail_caches = []
    for j in range(rem):
        kind = cfg.block_pattern[n_full * c + j]
        h, aux, cache = _layer_seq(cfg, kind, params["tail"][j], h, aux,
                                   positions=positions, mrope_pos=mrope_pos,
                                   enc_out=enc_out, want_cache=want_cache,
                                   max_len=max_len)
        tail_caches.append(cache)

    feats = norm_apply(_norm_kind(cfg), params["final_norm"], h, cfg.norm_eps)
    out = {"features": feats, "aux": aux}
    if want_logits:
        out["logits"] = head_apply(cfg, params, feats)
    if want_cache:
        out["cache"] = {"cycles": cycle_caches, "tail": tuple(tail_caches)}
    return out


def head_apply(cfg: ArchConfig, params, feats):
    if cfg.tie_embeddings:
        return feats @ params["embed"]["table"].T
    return feats @ params["head"]["w"]


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32):
    c, n_full, rem = cycle_split(cfg.block_pattern)
    cross = cfg.n_enc_layers > 0
    cycles = []
    for j in range(c):
        kind = cfg.block_pattern[j]
        one = _layer_cache_init(cfg, kind, batch, max_len, dtype, cross=cross)
        cycles.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape), one))
    tail = tuple(
        _layer_cache_init(cfg, cfg.block_pattern[n_full * c + j], batch,
                          max_len, dtype, cross=cross)
        for j in range(rem))
    return {"cycles": tuple(cycles), "tail": tail}


def decode_step(cfg: ArchConfig, params, tokens, cache, pos):
    """tokens [B,1] int32; pos scalar int32 (position of this token).

    Returns (logits [B,1,V], new_cache).
    """
    h = jnp.take(params["embed"]["table"], tokens, axis=0)
    if cfg.family == "audio":
        h = h + sinusoidal_position_at(jnp.asarray(pos), cfg.d_model,
                                       h.dtype)[None, None]
    B = h.shape[0]
    positions = jnp.full((B, 1), pos)
    mrope_pos = jnp.broadcast_to(jnp.full((B, 1), pos), (3, B, 1)) \
        if cfg.mrope else None

    c, n_full, rem = cycle_split(cfg.block_pattern)
    kinds = cfg.block_pattern[:c]

    def cycle_body(h, xs):
        layer_params, layer_cache = xs
        new_caches = []
        for j, kind in enumerate(kinds):
            h, nc = _layer_decode(cfg, kind, layer_params[j], h,
                                  layer_cache[j], pos=pos,
                                  positions=positions, mrope_pos=mrope_pos)
            new_caches.append(nc)
        return h, tuple(new_caches)

    h, new_cycle_caches = jax.lax.scan(cycle_body, h,
                                       (params["cycles"], cache["cycles"]))
    new_tail = []
    for j in range(rem):
        kind = cfg.block_pattern[n_full * c + j]
        h, nc = _layer_decode(cfg, kind, params["tail"][j], h,
                              cache["tail"][j], pos=pos, positions=positions,
                              mrope_pos=mrope_pos)
        new_tail.append(nc)

    feats = norm_apply(_norm_kind(cfg), params["final_norm"], h, cfg.norm_eps)
    logits = head_apply(cfg, params, feats)
    return logits, {"cycles": new_cycle_caches, "tail": tuple(new_tail)}


def _cache_max_len(cfg, cache):
    for j, kind in enumerate(cfg.block_pattern[:pattern_cycle(cfg.block_pattern)]):
        if kind == ATTN_GLOBAL:
            return cache["cycles"][j]["k"].shape[2]
        if kind == ATTN_LOCAL:
            return cache["cycles"][j]["k"].shape[2]
    return cfg.max_seq_len
