"""``repro.obs`` — observability for the federated engine.

Three layers, all off by default and bitwise-invisible when off:

* :mod:`repro.obs.telemetry` — on-device taps whose per-round signals
  ride the existing metrics stack and the round's existing psum (zero
  extra collectives, zero extra host syncs);
* :mod:`repro.obs.runlog` — host-side structured span/event/counter sink
  streaming JSONL (:class:`RunLog`), with a zero-allocation disabled path;
* :mod:`repro.obs.report` — fold a run's RunLog + CommLog records into a
  round-time breakdown and telemetry trend report.

``runlog`` and ``report`` are stdlib+numpy only; ``telemetry`` needs jax.
Nothing here imports the rest of ``repro`` — this package sits at the
bottom of the import graph so ``repro.fl.comm`` and ``repro.engine`` can
both use it without cycles.
"""
from repro.obs.report import build_report, render
from repro.obs.runlog import (NULL_RUNLOG, NullRunLog, RunLog, as_runlog,
                              json_safe)
from repro.obs.telemetry import (TELEMETRY_PREFIX, ClientTapCtx, RoundTapCtx,
                                 Telemetry, TelemetryTap, make_telemetry,
                                 register_tap, registered_taps)

__all__ = [
    "RunLog", "NullRunLog", "NULL_RUNLOG", "as_runlog", "json_safe",
    "Telemetry", "TelemetryTap", "ClientTapCtx", "RoundTapCtx",
    "make_telemetry", "register_tap", "registered_taps", "TELEMETRY_PREFIX",
    "build_report", "render",
]
