"""Run reports: RunLog + CommLog records -> where the round time went.

The tier-2 bench gate can tell you rounds/sec dropped; this module tells
you *why*.  :func:`build_report` folds a run's two record streams —

* the :class:`repro.obs.runlog.RunLog` JSONL (spans/events/counters the
  engine emits: chunk dispatch, eval dispatch, checkpoint saves, prefetch
  staging, queue waits), and
* the :meth:`repro.fl.comm.CommLog.to_records` per-round history (bytes
  and metrics, ``tele/`` telemetry included)

— into one plain dict: a round-time breakdown (dispatch vs metrics-drain
vs prefetch-stall vs eval vs checkpoint, each as seconds and a fraction
of the run's wall time), bytes/round, warning events, and first/last/mean
trends for every telemetry series.  :func:`render` pretty-prints it;
``benchmarks/obs_report.py`` is the CLI and ``benchmarks/bench_engine.py``
embeds the breakdown in its artifact.

Only stdlib + the runlog serializer here — reports must be buildable
anywhere the JSONL can be read, jax not required.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

__all__ = ["span_totals", "round_time_breakdown", "telemetry_summary",
           "bytes_per_round", "ef_page_summary", "schedule_summary",
           "build_report", "render"]

# span names charged to the dispatch thread's wall clock, in report order
# (ef.page.writeback is NOT here: it runs on the lane's worker thread and
# only costs the dispatch thread via the ef.page.stall_s counter)
_BREAKDOWN_SPANS = ("chunk.dispatch", "eval.dispatch", "checkpoint.save",
                    "ef.page.gather")


def span_totals(records: List[Dict]) -> Dict[str, Dict[str, float]]:
    """Per-span-name totals: count, total seconds, max seconds."""
    out: Dict[str, Dict[str, float]] = {}
    for r in records:
        if r.get("kind") != "span":
            continue
        t = out.setdefault(r["name"], {"count": 0, "total_s": 0.0,
                                       "max_s": 0.0})
        t["count"] += 1
        t["total_s"] += r.get("dur", 0.0)
        t["max_s"] = max(t["max_s"], r.get("dur", 0.0))
    for t in out.values():
        t["total_s"] = round(t["total_s"], 4)
        t["max_s"] = round(t["max_s"], 4)
    return out


def _counter_last(records: List[Dict], name: str) -> Optional[float]:
    val = None
    for r in records:
        if r.get("kind") == "counter" and r.get("name") == name:
            val = r.get("value")
    return val


def _wall_s(records: List[Dict]) -> Optional[float]:
    """run.start -> run.end wall time; falls back to the record span."""
    t0 = t1 = None
    for r in records:
        if r.get("kind") == "event" and r.get("name") == "run.start":
            t0 = r.get("t")
        if r.get("kind") == "event" and r.get("name") == "run.end":
            t1 = r.get("t")
    if t0 is not None and t1 is not None:
        return t1 - t0
    ts = [r.get("t", r.get("t0")) for r in records
          if r.get("t", r.get("t0")) is not None]
    return (max(ts) - min(ts)) if ts else None


def round_time_breakdown(records: List[Dict]) -> Dict[str, Any]:
    """Where the dispatch thread's wall time went, from one run's records.

    ``dispatch`` / ``eval`` / ``checkpoint`` come from their spans;
    ``metrics_drain`` and ``prefetch_stall`` from the engine's end-of-run
    counters (``metrics.wait_s`` / ``prefetch.wait_s``); ``other`` is the
    wall-time remainder — on a healthy run, mostly the time the host sat
    idle while superstep chunks trained on device.
    """
    spans = span_totals(records)
    wall = _wall_s(records)
    parts = {
        "dispatch_s": spans.get("chunk.dispatch", {}).get("total_s", 0.0),
        "eval_s": spans.get("eval.dispatch", {}).get("total_s", 0.0),
        "checkpoint_s": spans.get("checkpoint.save", {}).get("total_s", 0.0),
        "ef_gather_s": spans.get("ef.page.gather", {}).get("total_s", 0.0),
        "ef_stall_s": _counter_last(records, "ef.page.stall_s") or 0.0,
        "metrics_drain_s": _counter_last(records, "metrics.wait_s") or 0.0,
        "prefetch_stall_s": _counter_last(records, "prefetch.wait_s") or 0.0,
    }
    out: Dict[str, Any] = {"wall_s": round(wall, 4) if wall else None,
                           **{k: round(v, 4) for k, v in parts.items()}}
    if wall and wall > 0:
        accounted = sum(parts.values())
        out["other_s"] = round(max(wall - accounted, 0.0), 4)
        out["fractions"] = {
            k[:-2]: round(v / wall, 4) for k, v in parts.items()}
    chunks = spans.get("chunk.dispatch", {})
    if chunks.get("count"):
        out["chunks"] = int(chunks["count"])
        out["compiles"] = sum(
            1 for r in records if r.get("kind") == "span"
            and r["name"] == "chunk.dispatch" and r.get("compile"))
    return out


def ef_page_summary(records: List[Dict]) -> Dict[str, Any]:
    """Cohort-paged EF store accounting (empty when the run was dense).

    Folds the pager's end-of-run counters (page hit/miss rows, rows
    written back, rows patched on device) with its two span families:
    ``ef.page.gather`` runs on the dispatch thread (charged to the round
    loop), ``ef.page.writeback`` on the lane's worker thread (overlapped
    — only its ``stall_s`` share blocks dispatch).
    """
    out: Dict[str, Any] = {}
    for name in ("hits", "misses", "writeback_rows", "patched_rows"):
        v = _counter_last(records, f"ef.page.{name}")
        if v is not None:
            out[name] = int(v)
    stall = _counter_last(records, "ef.page.stall_s")
    if stall is not None:
        out["stall_s"] = round(float(stall), 4)
    spans = span_totals(records)
    for key, span in (("gather", "ef.page.gather"),
                      ("writeback", "ef.page.writeback")):
        if span in spans:
            out[f"{key}_s"] = spans[span]["total_s"]
            out[f"{key}_count"] = int(spans[span]["count"])
    rows = out.get("hits", 0) + out.get("misses", 0)
    if rows:
        out["hit_rate"] = round(out.get("hits", 0) / rows, 4)
    return out


def telemetry_summary(comm_records: List[Dict],
                      prefix: str = "tele/") -> Dict[str, Dict]:
    """First/last/mean/max trend per telemetry series in the history."""
    series: Dict[str, List[float]] = {}
    for rec in comm_records:
        for k, v in rec.items():
            if k.startswith(prefix) and isinstance(v, (int, float)) \
                    and math.isfinite(v):
                series.setdefault(k, []).append(float(v))
    return {k: {"first": round(vs[0], 6), "last": round(vs[-1], 6),
                "mean": round(sum(vs) / len(vs), 6),
                "max": round(max(vs), 6), "rounds": len(vs)}
            for k, vs in series.items() if vs}


def schedule_summary(comm_records: List[Dict]) -> Dict[str, Any]:
    """The adaptive-compression controller's realized schedule, from the
    per-round effective fields (``level`` + ``eff_topk_frac`` /
    ``eff_quant_bits`` — CommLog record schema v2).  Empty for static
    runs, whose records carry no ``level``."""
    levels = [(r.get("round", i + 1), int(r["level"]))
              for i, r in enumerate(comm_records) if "level" in r]
    if not levels:
        return {}
    counts: Dict[int, int] = {}
    for _, lvl in levels:
        counts[lvl] = counts.get(lvl, 0) + 1
    switches = [{"round": rd, "level": lvl}
                for i, (rd, lvl) in enumerate(levels)
                if i == 0 or lvl != levels[i - 1][1]]
    eff_keys = ("eff_topk_frac", "eff_quant_bits")
    per_level: Dict[int, Dict] = {}
    for r in comm_records:
        if "level" in r:
            per_level.setdefault(int(r["level"]), {
                k: r[k] for k in eff_keys if k in r})
    return {"rounds": len(levels),
            "level_rounds": {str(k): v for k, v in sorted(counts.items())},
            "levels": {str(k): v for k, v in sorted(per_level.items())},
            "switches": switches[:50]}


def bytes_per_round(comm_records: List[Dict]) -> Dict[str, Any]:
    """Wire accounting across the run (the paper's x-axis)."""
    if not comm_records:
        return {}
    up = [r.get("bytes_up", 0) for r in comm_records]
    down = [r.get("bytes_down", 0) for r in comm_records]
    ideal = [r.get("bytes_up_ideal", 0) for r in comm_records]
    out = {"rounds": len(comm_records),
           "bytes_up_per_round": round(sum(up) / len(up), 1),
           "bytes_down_per_round": round(sum(down) / len(down), 1),
           "total_mb_up": round(sum(up) / 1e6, 3),
           "total_mb_down": round(sum(down) / 1e6, 3)}
    if sum(up) and sum(ideal):
        out["uplink_compression"] = round(sum(ideal) / sum(up), 2)
    return out


def build_report(runlog_records: Optional[List[Dict]] = None,
                 comm_records: Optional[List[Dict]] = None) -> Dict:
    """Fold the two record streams into one report dict (either may be
    None/empty — the report carries whatever the run collected)."""
    report: Dict[str, Any] = {}
    if runlog_records:
        report["round_time"] = round_time_breakdown(runlog_records)
        report["spans"] = span_totals(runlog_records)
        ef = ef_page_summary(runlog_records)
        if ef:
            report["ef_page"] = ef
        warns = [r for r in runlog_records
                 if r.get("kind") == "event" and r.get("level") == "warning"]
        if warns:
            report["warnings"] = warns
    if comm_records:
        # accept CommLog.to_records() verbatim: keep only round records
        # (raw history dicts carry no "kind" and pass through)
        comm_records = [r for r in comm_records
                        if r.get("kind", "round") == "round"]
    if comm_records:
        report["bytes"] = bytes_per_round(comm_records)
        tele = telemetry_summary(comm_records)
        if tele:
            report["telemetry"] = tele
        sched = schedule_summary(comm_records)
        if sched:
            report["schedule"] = sched
    return report


def render(report: Dict) -> str:
    """Report dict -> a terminal-friendly text block."""
    lines: List[str] = []
    rt = report.get("round_time")
    if rt:
        lines.append("== round-time breakdown ==")
        wall = rt.get("wall_s")
        lines.append(f"wall: {wall}s  chunks: {rt.get('chunks', '?')} "
                     f"(compiled {rt.get('compiles', '?')})")
        for k in ("dispatch_s", "eval_s", "checkpoint_s", "ef_gather_s",
                  "ef_stall_s", "metrics_drain_s", "prefetch_stall_s",
                  "other_s"):
            if k in rt:
                frac = (report["round_time"].get("fractions", {})
                        .get(k[:-2]))
                pct = f"  ({frac * 100:.1f}%)" if frac is not None else ""
                lines.append(f"  {k[:-2]:>15s}: {rt[k]:9.4f}s{pct}")
    ef = report.get("ef_page")
    if ef:
        lines.append("== ef page store ==")
        rows = ef.get("hits", 0) + ef.get("misses", 0)
        hr = f"  hit rate {ef['hit_rate'] * 100:.1f}%" \
            if "hit_rate" in ef else ""
        lines.append(f"  rows gathered: {rows} "
                     f"(hits {ef.get('hits', 0)}, "
                     f"misses {ef.get('misses', 0)}){hr}")
        lines.append(f"  written back: {ef.get('writeback_rows', 0)} rows "
                     f"in {ef.get('writeback_count', 0)} flushes "
                     f"({ef.get('writeback_s', 0.0):.4f}s worker-thread)")
        lines.append(f"  device-patched: {ef.get('patched_rows', 0)} rows  "
                     f"gather {ef.get('gather_s', 0.0):.4f}s  "
                     f"dispatch stall {ef.get('stall_s', 0.0):.4f}s")
    b = report.get("bytes")
    if b:
        lines.append("== bytes ==")
        lines.append(
            f"  up {b.get('bytes_up_per_round', 0):.0f} B/round "
            f"({b.get('total_mb_up', 0)} MB total), "
            f"down {b.get('bytes_down_per_round', 0):.0f} B/round"
            + (f", uplink compression {b['uplink_compression']}x"
               if "uplink_compression" in b else ""))
    tele = report.get("telemetry")
    if tele:
        lines.append("== telemetry trends ==")
        for k in sorted(tele):
            t = tele[k]
            lines.append(f"  {k:>24s}: first={t['first']:.5g} "
                         f"last={t['last']:.5g} mean={t['mean']:.5g}")
    sched = report.get("schedule")
    if sched:
        lines.append("== compression schedule ==")
        lines.append("  rounds/level: " + "  ".join(
            f"L{k}:{v}" for k, v in sched["level_rounds"].items()))
        sw = sched.get("switches", [])
        lines.append("  switches: " + (" -> ".join(
            f"r{s['round']}=L{s['level']}" for s in sw) if sw else "none"))
    warns = report.get("warnings")
    if warns:
        lines.append(f"== warnings ({len(warns)}) ==")
        for w in warns[:20]:
            lines.append(f"  {w.get('name')}: "
                         + " ".join(f"{k}={v}" for k, v in w.items()
                                    if k not in ("kind", "name", "t",
                                                 "level")))
    return "\n".join(lines) if lines else "(empty report)"
