"""``RunLog``: structured host-side span tracing and event logging.

The engine's host-side timeline was scattered across ad-hoc counters —
``HostPrefetcher.wait_s``, ``MetricsPump.wait_s``, a handful of
``ServerResult.stats`` entries — none of which say *when* anything
happened or how the pieces nest.  ``RunLog`` formalizes it as an
append-only stream of schema'd records:

* ``span``    — a named interval on the monotonic clock (``t0``/``dur``
  seconds since the log's origin) with an ``id`` and the enclosing span's
  ``parent`` id, tracked per thread so the prefetch worker's staging
  spans interleave correctly with the dispatch thread's chunk spans;
* ``event``   — a point-in-time marker (run start/end, non-finite metric
  warnings, checkpoint writes);
* ``counter`` — a named numeric sample (queue waits, staging-pool hits).

Records are plain dicts serialized by :func:`json_safe` (numpy scalars
and small arrays included), streamed to a JSONL file as they are emitted
when the log is constructed with a path, and always kept in memory for
:meth:`records` / :meth:`save`.  ``RunLog.load`` round-trips the file.

The disabled path is :data:`NULL_RUNLOG` — a singleton whose methods do
nothing and whose ``span`` returns one shared no-op context manager, so
instrumented code calls the same API unconditionally and a run without
observability allocates nothing per call.  ``as_runlog`` resolves the
user-facing knob (None | path | RunLog) to one of the two.

This module sits at the bottom of the import graph: stdlib + numpy only,
so ``repro.fl.comm`` and ``repro.engine`` can both use the serializer
without cycles.
"""
from __future__ import annotations

import io
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Union

import numpy as np

__all__ = ["RunLog", "NullRunLog", "NULL_RUNLOG", "as_runlog", "json_safe"]


def json_safe(v: Any) -> Any:
    """One value -> something ``json.dump`` accepts.

    numpy scalars become Python numbers, small arrays become lists,
    dict/list/tuple recurse; anything else falls back to ``str`` rather
    than raising mid-run (a telemetry sink must never kill the run it
    observes).
    """
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (np.bool_, np.integer)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, dict):
        return {str(k): json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [json_safe(x) for x in v]
    if hasattr(v, "ndim"):                      # ndarray / jax array
        arr = np.asarray(v)
        return arr.item() if arr.ndim == 0 else arr.tolist()
    return str(v)


class _Span:
    """Context manager recording one timed interval into its RunLog."""

    __slots__ = ("_log", "name", "attrs", "_t0", "_id", "_parent")

    def __init__(self, log: "RunLog", name: str, attrs: Dict):
        self._log = log
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._t0 = time.monotonic()
        self._id, self._parent = self._log._push_span()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.monotonic() - self._t0
        self._log._pop_span()
        rec = {"kind": "span", "name": self.name, "id": self._id,
               "parent": self._parent,
               "t0": round(self._t0 - self._log._origin, 6),
               "dur": round(dur, 6)}
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        rec.update({k: json_safe(v) for k, v in self.attrs.items()})
        self._log._append(rec)
        return False


class RunLog:
    """Append-only structured event sink (see module docstring).

    ``path=None`` keeps records in memory only; a path streams each
    record as one JSON line the moment it is emitted, so a crashed run
    still leaves its timeline on disk.
    """

    enabled = True

    def __init__(self, path: Optional[str] = None):
        self._origin = time.monotonic()
        self._records: List[Dict] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._next_id = 0
        self._file: Optional[io.TextIOBase] = None
        self.path = path
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)),
                        exist_ok=True)
            self._file = open(path, "w", buffering=1)

    # -- span bookkeeping (thread-local nesting) ------------------------
    def _stack(self) -> List[int]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _push_span(self):
        with self._lock:
            sid = self._next_id
            self._next_id += 1
        st = self._stack()
        parent = st[-1] if st else None
        st.append(sid)
        return sid, parent

    def _pop_span(self):
        st = self._stack()
        if st:
            st.pop()

    def _append(self, rec: Dict):
        with self._lock:
            self._records.append(rec)
            if self._file is not None:
                self._file.write(json.dumps(rec) + "\n")

    def _now(self) -> float:
        return round(time.monotonic() - self._origin, 6)

    # -- public API -----------------------------------------------------
    def span(self, name: str, **attrs) -> _Span:
        """``with runlog.span("chunk.dispatch", r0=0, r1=8): ...``"""
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs):
        rec = {"kind": "event", "name": name, "t": self._now()}
        rec.update({k: json_safe(v) for k, v in attrs.items()})
        self._append(rec)

    def counter(self, name: str, value, **attrs):
        rec = {"kind": "counter", "name": name, "t": self._now(),
               "value": json_safe(value)}
        rec.update({k: json_safe(v) for k, v in attrs.items()})
        self._append(rec)

    def warning(self, name: str, **attrs):
        """An ``event`` tagged ``level="warning"`` (non-finite metrics,
        dropped work) so reports can surface it without string-matching."""
        self.event(name, level="warning", **attrs)

    def records(self) -> List[Dict]:
        with self._lock:
            return list(self._records)

    def save(self, path: Optional[str] = None) -> str:
        """Write every record as JSONL; defaults to the streaming path."""
        path = path or self.path
        if not path:
            raise ValueError("RunLog.save needs a path (none bound)")
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with self._lock:
            with open(path, "w") as f:
                for rec in self._records:
                    f.write(json.dumps(rec) + "\n")
        return path

    def close(self):
        if self._file is not None:
            self._file.close()
            self._file = None

    @staticmethod
    def load(path: str) -> List[Dict]:
        """JSONL file -> list of records (inverse of save/streaming)."""
        out = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
        return out


class _NullSpan:
    """Shared no-op context manager: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullRunLog:
    """Disabled sink: same API as RunLog, every method a no-op.

    ``span`` returns ONE shared context manager instance so the
    instrumented hot loop costs a method call and nothing else — pinned
    by the zero-allocation smoke test in ``tests/test_obs.py``.
    """

    enabled = False
    path = None

    def span(self, *a, **k):
        return _NULL_SPAN

    def event(self, *a, **k):
        pass

    def counter(self, *a, **k):
        pass

    def warning(self, *a, **k):
        pass

    def records(self) -> List[Dict]:
        return []

    def close(self):
        pass


NULL_RUNLOG = NullRunLog()


def as_runlog(runlog: Union[None, str, RunLog]) -> Union[RunLog, NullRunLog]:
    """Resolve the user-facing knob: None -> the shared null sink, a path
    -> a streaming RunLog owned by the caller, a RunLog -> itself."""
    if runlog is None:
        return NULL_RUNLOG
    if isinstance(runlog, (RunLog, NullRunLog)):
        return runlog
    return RunLog(str(runlog))
