"""Zero-sync on-device telemetry taps for the federated round functions.

The signals the adaptive-compression and capacity-planning roadmap items
need — delta norms before/after the wire codec, EF residual mass, the
residual/delta ratio, compression error, the round's example total — are
all computed on device every round and then thrown away.  This module
turns them into *taps*: small traceable hooks the round factories in
``repro.core.rounds`` evaluate alongside training, whose outputs ride the
EXISTING stacked-``[K]`` metrics path through the superstep scan and the
``MetricsPump``.  Telemetry therefore costs

* **zero extra host syncs** — tap values land in the same deferred
  metrics stack every other per-round metric uses; and
* **zero extra collectives** — per-client tap sums are packed into the
  psum the round already performs (the contribution-sum tree in unfused
  sharded mode, the PR 5 single fused psum in fused mode; ``psum`` of a
  tree is one collective regardless of leaf count, and elementwise
  reduction means the pre-existing leaves keep their exact values, so a
  telemetry-on run stays bitwise-equal to telemetry-off).

Tap protocol (registered like ``Algorithm`` / ``make_codec`` plugins):

* ``client_sums(ctx)`` runs once per client inside the round's
  vmap/scan and returns a flat ``{key: f32 scalar}`` dict of
  *psum-pending sums* — summed over the round's clients (and shards)
  before finalization.  Keys are namespaced ``"{tap.name}.{key}"``.
* ``finish(summed, ctx)`` runs replicated after the sums complete and
  maps them to the emitted metrics (prefix ``tele/``) — ratios and
  normalizations belong here, never in ``client_sums`` (a quotient does
  not sum).

``kinds`` declares which round flavours a tap understands
(``"plain"`` / ``"compressed"``) and ``requires`` which
:class:`ClientTapCtx` fields it reads, so :func:`make_telemetry` only
activates taps whose inputs exist (the EF tap needs a stateful uplink).

Everything is f32 end to end: tap sums ride the engine's fused psum
buffer, which is single-dtype by contract.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ClientTapCtx", "RoundTapCtx", "TelemetryTap", "Telemetry",
           "register_tap", "registered_taps", "make_telemetry",
           "TELEMETRY_PREFIX"]

TELEMETRY_PREFIX = "tele/"

# guards the residual/delta ratio against a zero-delta round; f32 tiny
_EPS = 1e-20


def _sq_sum(tree) -> jnp.ndarray:
    """Σ x² over every leaf of a pytree, as one f32 scalar."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)


@dataclass(frozen=True)
class ClientTapCtx:
    """What one client's round computation exposes to ``client_sums``.

    Fields are None when the round flavour does not produce them; a tap
    lists the ones it reads in ``requires`` and is skipped when any is
    unavailable.  All trees are this client's (un-vmapped) values.
    """

    n_examples: Any = None      # scalar — this client's example count
    loss: Any = None            # scalar — local training loss
    model: Any = None           # tree — trained local trainable (plain)
    global_model: Any = None    # tree — the model clients started from
    delta: Any = None           # tree — PRE-compression update (compressed)
    decoded: Any = None         # tree — POST-compression decoded update
    ef: Any = None              # tree — the client's NEW EF residual
    pmask: Any = None           # scalar — 0/1 participation mask
    staleness: Any = None       # scalar — rounds late (participation)
    level: Any = None           # scalar — effective ladder level (control)
    eff_bytes: Any = None       # scalar — effective uplink payload bytes


@dataclass(frozen=True)
class RoundTapCtx:
    """Round-level statics available to ``finish`` (no traced values)."""

    n_clients: int = 1          # C — the FULL round's sampled clients
    n_shards: int = 1           # client shards the round runs across


class TelemetryTap:
    """Base tap: subclass, set ``name``/``kinds``/``requires``, implement
    the two hooks.  Stateless by contract — one instance serves every
    round fn build."""

    name: str = "?"
    kinds: Tuple[str, ...] = ("plain", "compressed")
    requires: Tuple[str, ...] = ()

    def client_sums(self, ctx: ClientTapCtx) -> Dict[str, jnp.ndarray]:
        return {}

    def finish(self, summed: Dict[str, jnp.ndarray],
               ctx: RoundTapCtx) -> Dict[str, jnp.ndarray]:
        return {}


class DeltaNormTap(TelemetryTap):
    """RMS per-client update norm before and after the uplink codec, plus
    the compression error between them — the compression controller's
    primary signal (CFedAvg retunes on exactly this)."""

    name = "delta"
    kinds = ("compressed",)
    requires = ("delta", "decoded")

    def client_sums(self, ctx):
        return {"pre_sq": _sq_sum(ctx.delta),
                "post_sq": _sq_sum(ctx.decoded),
                "err_sq": _sq_sum(jax.tree.map(
                    lambda a, b: a.astype(jnp.float32)
                    - b.astype(jnp.float32), ctx.delta, ctx.decoded))}

    def finish(self, summed, ctx):
        c = jnp.float32(ctx.n_clients)
        return {"delta_norm_pre": jnp.sqrt(summed["delta.pre_sq"] / c),
                "delta_norm_post": jnp.sqrt(summed["delta.post_sq"] / c),
                "compress_err": jnp.sqrt(summed["delta.err_sq"] / c)}


class EFResidualTap(TelemetryTap):
    """RMS error-feedback residual norm and the residual/delta mass
    ratio: how much update the codec is deferring round over round.  A
    ratio trending up means the codec is too aggressive for the current
    delta distribution — the retuning signal ROADMAP item 4 names."""

    name = "ef"
    kinds = ("compressed",)
    requires = ("ef", "delta")

    def client_sums(self, ctx):
        # carries its own delta mass so the tap works standalone (taps
        # must not read each other's sums — selection is per-tap)
        return {"sq": _sq_sum(ctx.ef), "delta_sq": _sq_sum(ctx.delta)}

    def finish(self, summed, ctx):
        c = jnp.float32(ctx.n_clients)
        return {"ef_norm": jnp.sqrt(summed["ef.sq"] / c),
                "ef_delta_ratio": jnp.sqrt(
                    summed["ef.sq"]
                    / jnp.maximum(summed["ef.delta_sq"], _EPS))}


class UpdateNormTap(TelemetryTap):
    """RMS per-client drift of the trained local model from the global
    one (the uncompressed round's analogue of the delta norm)."""

    name = "update"
    kinds = ("plain",)
    requires = ("model", "global_model")

    def client_sums(self, ctx):
        return {"sq": _sq_sum(jax.tree.map(
            lambda a, g: a.astype(jnp.float32) - g.astype(jnp.float32),
            ctx.model, ctx.global_model))}

    def finish(self, summed, ctx):
        return {"update_norm": jnp.sqrt(
            summed["update.sq"] / jnp.float32(ctx.n_clients))}


class WeightTap(TelemetryTap):
    """The round's aggregate example total (the FedAvg normalizer) and
    the per-shard client count — the per-host balance signals the pod
    launch (ROADMAP item 1) needs."""

    name = "weights"
    kinds = ("plain", "compressed")
    requires = ("n_examples",)

    def client_sums(self, ctx):
        return {"total": jnp.asarray(ctx.n_examples, jnp.float32)}

    def finish(self, summed, ctx):
        return {"weight_total": summed["weights.total"],
                "clients": jnp.float32(ctx.n_clients),
                "clients_per_shard": jnp.float32(
                    ctx.n_clients // max(ctx.n_shards, 1))}


class ParticipationTap(TelemetryTap):
    """Partial-cohort health: how many of the sampled lanes contributed,
    how many were dropped/late out of the round, and the mean staleness
    of the contributions that did land (buffered-async discounting).
    Active only when the participation axis is on (the engine adds
    ``pmask``/``staleness`` to ``available``), so full-sync/chaos-off
    builds stay byte-identical."""

    name = "participation"
    kinds = ("plain", "compressed")
    requires = ("pmask", "staleness")

    def client_sums(self, ctx):
        m = jnp.asarray(ctx.pmask, jnp.float32)
        return {"arrived": m,
                "stale_sum": jnp.asarray(ctx.staleness, jnp.float32) * m}

    def finish(self, summed, ctx):
        arrived = summed["participation.arrived"]
        return {"effective_cohort": arrived,
                "dropped_clients": jnp.float32(ctx.n_clients) - arrived,
                "mean_staleness": summed["participation.stale_sum"]
                / jnp.maximum(arrived, 1.0)}


class ControllerTap(TelemetryTap):
    """The adaptive-compression schedule (repro.control): the round's
    effective ladder level and per-client effective uplink payload bytes.
    Every client of a round encodes at the SAME level, so the psum-mean
    is exact regardless of participation masking.  Active only when a
    controller is on (the engine adds ``level``/``eff_bytes`` to
    ``available``), so static builds stay byte-identical."""

    name = "controller"
    kinds = ("compressed",)
    requires = ("level", "eff_bytes")

    def client_sums(self, ctx):
        return {"level": jnp.asarray(ctx.level, jnp.float32),
                "bytes": jnp.asarray(ctx.eff_bytes, jnp.float32)}

    def finish(self, summed, ctx):
        c = jnp.float32(ctx.n_clients)
        return {"level": summed["controller.level"] / c,
                "effective_bytes": summed["controller.bytes"] / c}


_TAPS: Dict[str, TelemetryTap] = {}


def register_tap(tap: TelemetryTap) -> TelemetryTap:
    """Add a tap to the registry (codec/algorithm plugins call this the
    same way they call ``register_algorithm``); re-registering a name
    replaces it."""
    if not tap.name or tap.name == "?":
        raise ValueError("telemetry taps need a non-default name")
    _TAPS[tap.name] = tap
    return tap


def registered_taps() -> Tuple[str, ...]:
    return tuple(sorted(_TAPS))


for _t in (DeltaNormTap(), EFResidualTap(), UpdateNormTap(), WeightTap(),
           ParticipationTap(), ControllerTap()):
    register_tap(_t)


@dataclass(frozen=True)
class Telemetry:
    """The taps active for one round-fn build, pre-filtered by kind and
    input availability; what the round factories actually consume."""

    taps: Tuple[TelemetryTap, ...]
    round_ctx: RoundTapCtx = field(default_factory=RoundTapCtx)

    def client_sums(self, ctx: ClientTapCtx) -> Dict[str, jnp.ndarray]:
        """Flat namespaced psum-pending sums for one client."""
        out: Dict[str, jnp.ndarray] = {}
        for tap in self.taps:
            for k, v in tap.client_sums(ctx).items():
                out[f"{tap.name}.{k}"] = jnp.asarray(v, jnp.float32)
        return out

    def finish(self, summed: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
        """Summed (psum-completed) tap values -> emitted ``tele/`` metrics."""
        out: Dict[str, Any] = {}
        for tap in self.taps:
            for k, v in tap.finish(summed, self.round_ctx).items():
                out[TELEMETRY_PREFIX + k] = v
        return out


def make_telemetry(kind: str, *, n_clients: int = 1, n_shards: int = 1,
                   available: FrozenSet[str] = frozenset(),
                   taps: Optional[Sequence[str]] = None
                   ) -> Optional[Telemetry]:
    """Build the :class:`Telemetry` for one round-fn flavour.

    ``kind`` is ``"plain"`` or ``"compressed"``; ``available`` names the
    optional :class:`ClientTapCtx` fields the round will populate beyond
    the always-present ``n_examples``/``loss`` (the engine passes
    ``{"ef"}`` only for stateful uplinks).  ``taps=None`` takes every
    registered tap that fits; an explicit name list selects (and
    validates) a subset.  Returns None when nothing applies — callers
    treat that exactly like telemetry-off.
    """
    if kind not in ("plain", "compressed"):
        raise ValueError(f"telemetry kind {kind!r} must be 'plain' or "
                         "'compressed'")
    base = {"n_examples", "loss"}
    base |= ({"model", "global_model"} if kind == "plain"
             else {"delta", "decoded", "global_model"})
    have = base | set(available)
    if taps is None:
        names = registered_taps()
    else:
        unknown = set(taps) - set(_TAPS)
        if unknown:
            raise KeyError(f"unknown telemetry taps {sorted(unknown)}; "
                           f"registered: {registered_taps()}")
        names = tuple(taps)
    chosen = tuple(
        _TAPS[n] for n in names
        if kind in _TAPS[n].kinds and set(_TAPS[n].requires) <= have)
    if not chosen:
        return None
    return Telemetry(taps=chosen,
                     round_ctx=RoundTapCtx(n_clients=n_clients,
                                           n_shards=n_shards))
