from repro.optim.optimizers import (adam_init, adam_update, make_optimizer,
                                    sgd_init, sgd_update)  # noqa: F401
from repro.optim.schedules import exp_decay_per_round  # noqa: F401
