"""Minimal pytree optimizers (no optax dependency): SGD(+momentum), Adam."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum=0.0):
    if momentum == 0.0:
        return {"t": jnp.zeros((), jnp.int32)}
    return {"t": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params)}


def sgd_update(params, grads, state, *, lr, momentum=0.0, weight_decay=0.0):
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    if momentum == 0.0:
        new = jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype),
                           params, grads)
        return new, {"t": state["t"] + 1}
    mu = jax.tree.map(lambda m, g: (momentum * m + g).astype(m.dtype),
                      state["mu"], grads)
    new = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
    return new, {"t": state["t"] + 1, "mu": mu}


def adam_init(params):
    return {"t": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params)}


def adam_update(params, grads, state, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                weight_decay=0.0):
    t = state["t"] + 1
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    m = jax.tree.map(lambda m_, g: (b1 * m_ + (1 - b1) * g).astype(m_.dtype),
                     state["m"], grads)
    v = jax.tree.map(
        lambda v_, g: (b2 * v_ + (1 - b2) * g * g).astype(v_.dtype),
        state["v"], grads)
    tf = t.astype(jnp.float32)
    c1 = 1.0 - b1 ** tf
    c2 = 1.0 - b2 ** tf
    new = jax.tree.map(
        lambda p, m_, v_: (p - lr * (m_ / c1)
                           / (jnp.sqrt(v_ / c2) + eps)).astype(p.dtype),
        params, m, v)
    return new, {"t": t, "m": m, "v": v}


def make_optimizer(kind: str, momentum: float = 0.0):
    """Returns (init_fn(params), update_fn(params, grads, state, lr))."""
    if kind == "sgd":
        return (lambda p: sgd_init(p, momentum),
                lambda p, g, s, lr: sgd_update(p, g, s, lr=lr,
                                               momentum=momentum))
    if kind == "adam":
        return adam_init, lambda p, g, s, lr: adam_update(p, g, s, lr=lr)
    raise ValueError(kind)
