"""Learning-rate schedules.  The paper uses a per-round exponential decay
(0.985/round for artificial non-IID, 0.99/round for permuted MNIST)."""
from __future__ import annotations

import jax.numpy as jnp


def exp_decay_per_round(base_lr: float, decay: float):
    def lr_at(round_idx):
        return base_lr * decay ** jnp.asarray(round_idx, jnp.float32)
    return lr_at
