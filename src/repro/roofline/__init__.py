from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,  # noqa: F401
                                     Roofline, analyze, model_flops)
from repro.roofline.hlo import (collective_bytes,  # noqa: F401
                                collective_op_counts, collective_summary,
                                entry_io_aliases, entry_param_shapes)
