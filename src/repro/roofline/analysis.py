"""Three-term roofline from a compiled (AOT) step.

All primary numbers come from the structural HLO analyzer
(`repro.roofline.hlo.analyze_entry`), which multiplies loop bodies by their
trip counts — XLA's own ``cost_analysis()`` counts each ``while`` body once,
which under-reports scanned layers by ~n_layers; its raw numbers are kept in
the report for transparency.

Post-SPMD HLO shapes are PER-DEVICE, so analyzer outputs are per-chip:

    compute    = flops_per_chip / 197 TFLOP/s (bf16)
    memory     = hbm_bytes_per_chip / 819 GB/s
    collective = collective_bytes_per_chip / 50 GB/s (ICI link)

MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); the two-stream algorithms
add a frozen-global forward (+2 N D) which we count in MODEL_FLOPS_2STREAM
so the useful-ratio separates genuine technique overhead from waste.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from repro.configs.base import ArchConfig, InputShape
from repro.launch.specs import fl_plan
from repro.roofline.hlo import analyze_entry

PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    model_flops: float = 0.0
    model_flops_2stream: float = 0.0
    xla_cost_flops: float = 0.0       # raw cost_analysis (loop bodies x1)
    xla_cost_bytes: float = 0.0
    peak_memory_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the dominant term."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_ratio=self.useful_ratio, mfu_bound=self.mfu_bound)
        return d


def model_flops(cfg: ArchConfig, shape: InputShape, mesh,
                two_stream: bool = True) -> Dict[str, float]:
    n = cfg.active_param_count()
    if shape.kind == "train":
        plan = fl_plan(cfg, shape, mesh)
        tokens = (plan.n_clients * plan.local_steps * plan.client_batch
                  * shape.seq_len)
        base = float(6 * n * tokens)
        return {"model_flops": base,
                "model_flops_2stream": base + (2.0 * n * tokens
                                               if two_stream else 0.0)}
    if shape.kind == "prefill":
        f = float(2 * n * shape.global_batch * shape.seq_len)
    else:
        f = float(2 * n * shape.global_batch)   # decode: 1 token/seq
    return {"model_flops": f, "model_flops_2stream": f}


def analyze(compiled, cfg: ArchConfig, shape: InputShape, mesh_name: str,
            chips: int, mesh=None, two_stream: bool = True) -> Roofline:
    text = compiled.as_text()
    cost = analyze_entry(text)

    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]

    peak = 0.0
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "argument_size_in_bytes", 0))
    except Exception:
        pass

    mf = model_flops(cfg, shape, mesh, two_stream)
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_chip=cost.flops,
        hbm_bytes_per_chip=cost.bytes,
        coll_bytes_per_chip=cost.total_coll_bytes,
        coll_breakdown=dict(cost.coll_bytes),
        coll_counts=dict(cost.coll_counts),
        model_flops=mf["model_flops"],
        model_flops_2stream=mf["model_flops_2stream"],
        xla_cost_flops=float(xla_cost.get("flops", 0.0)),
        xla_cost_bytes=float(xla_cost.get("bytes accessed", 0.0)),
        peak_memory_bytes=peak)
