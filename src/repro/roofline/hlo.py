"""Structural HLO analyzer: loop-aware FLOPs / HBM bytes / collective bytes.

Why not ``compiled.cost_analysis()``?  XLA's cost analysis counts each
computation ONCE — a ``lax.scan`` over 30 layers contributes 1/30th of its
true cost.  Since this framework deliberately scans over layer cycles,
clients and attention blocks, we parse the scheduled HLO text ourselves:

* computations are parsed into op lists; operands in scheduled HLO are bare
  ``%names``, so shapes are resolved through a per-computation symbol table
  (header parameters + op results);
* ``while`` trip counts are recovered from the loop-condition computation
  (the ``compare(iv, constant(N))`` pattern lax.scan emits);
* costs roll up through the call graph (entry -> while bodies x trips).

Cost model per op (shapes in post-SPMD HLO are PER-DEVICE shapes, so all
results are per-chip):
* ``dot``: FLOPs = 2 * prod(result) * contraction_size; bytes = operands +
  result.
* ``convolution``: FLOPs ~= 2 * prod(result) * kernel_elems / C_out.
* ``fusion``: bytes = operands + result — exactly XLA's fused-kernel HBM
  traffic model.  Elementwise ops outside fusions are ignored (they fuse in
  practice).
* collectives: bytes = operand payload a chip moves.
* data movement ops (dynamic-(update-)slice, gather, scatter, reduce, sort,
  copy, transpose, concatenate): bytes = operands + result.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_MOVE_OPS = ("dynamic-slice", "dynamic-update-slice", "gather", "scatter",
             "reduce", "sort", "copy", "transpose", "concatenate", "reverse",
             "pad", "slice")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\]")
_OPCODE_RE = re.compile(r"\b([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_INT_RE = re.compile(r"\bconstant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 0)


def _shapes_bytes(shapes: List[Tuple[str, str]]) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in shapes)


@dataclass
class Op:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, str]]
    operand_names: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    params: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)
    consts: Dict[str, int] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll_bytes.items()},
                    {k: v * m for k, v in self.coll_counts.items()})

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))


_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")


def parse_computations(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        if s.endswith("{"):
            m = _HDR_RE.match(s)
            if m:
                cur = Computation(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                # header params: "pname: TYPE[dims], ..."
                for pm in re.finditer(r"([\w.\-]+):\s*(\(?[^,()]*(?:\([^)]*\))?)",
                                      m.group(3)):
                    pname = pm.group(1)
                    shapes = _SHAPE_RE.findall(pm.group(2))
                    if shapes:
                        cur.params[pname] = shapes
                        cur.symbols[pname] = shapes
                continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in s:
            continue
        name_part, _, rhs = s.partition("=")
        name = name_part.replace("ROOT", "").strip().lstrip("%")
        rhs = rhs.strip()
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        opcode = om.group(1)
        result_shapes = _SHAPE_RE.findall(rhs[:om.start()])
        # operands: %names inside the first balanced paren group
        depth = 0
        end = om.end()
        for i in range(om.end() - 1, len(rhs)):
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = rhs[om.end():end]
        attrs = rhs[end:]
        operands = _OPERAND_RE.findall(operand_text)
        op = Op(name=name, opcode=opcode, result_shapes=result_shapes,
                operand_names=operands, attrs=attrs)
        cur.ops.append(op)
        cur.symbols[name] = result_shapes
        if opcode == "constant":
            cm = _CONST_INT_RE.search(rhs)
            if cm:
                cur.consts[name] = int(cm.group(1))
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comps, entry


def _operand_shapes(comp: Computation, op: Op) -> List[Tuple[str, str]]:
    out: List[Tuple[str, str]] = []
    for n in op.operand_names:
        out.extend(comp.symbols.get(n, []))
    return out


def _dot_flops(comp: Computation, op: Op) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs_shapes = comp.symbols.get(op.operand_names[0], []) \
        if op.operand_names else []
    if not m or not lhs_shapes:
        return 0.0
    dims_str = lhs_shapes[0][1]
    lhs = [int(d) for d in dims_str.split(",")] if dims_str.strip() else []
    contract = 1
    for idx in m.group(1).split(","):
        if idx.strip() and int(idx) < len(lhs):
            contract *= lhs[int(idx)]
    out = 0
    for dt, dims in op.result_shapes:
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        out += n
    return 2.0 * out * contract


def _conv_flops(comp: Computation, op: Op) -> float:
    shapes = _operand_shapes(comp, op)
    if len(shapes) < 2:
        return 0.0
    kdims = shapes[1][1]
    kshape = [int(d) for d in kdims.split(",")] if kdims.strip() else [1]
    kn = 1
    for d in kshape:
        kn *= d
    out_n = 0
    for dt, dims in op.result_shapes:
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        out_n += n
    c_out = kshape[-1] if kshape else 1
    return 2.0 * out_n * (kn / max(c_out, 1))


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> float:
    cond = comps.get(cond_name)
    if cond is None:
        return 1.0
    # preferred: the constant operand of the ROOT compare
    for op in cond.ops:
        if op.opcode == "compare":
            for n in op.operand_names:
                if n in cond.consts:
                    return float(max(cond.consts[n], 1))
    if cond.consts:
        return float(max(max(cond.consts.values()), 1))
    return 1.0


def analyze_entry(hlo_text: str) -> Cost:
    comps, entry = parse_computations(hlo_text)
    cache: Dict[str, Cost] = {}

    def cost_of(name: str, depth=0) -> Cost:
        if name in cache:
            return cache[name]
        comp = comps.get(name)
        total = Cost()
        if comp is None or depth > 60:
            return total
        cache[name] = total
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                m = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                b = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = _trip_count(comps, m.group(1)) if m else 1.0
                if b:
                    total += cost_of(b.group(1), depth + 1).scaled(trips)
                if m:
                    total += cost_of(m.group(1), depth + 1).scaled(trips)
                continue
            matched_coll = None
            for coll in COLLECTIVE_OPS:
                if oc == coll or oc == coll + "-start":
                    matched_coll = coll
                    break
            if matched_coll:
                payload = float(_shapes_bytes(_operand_shapes(comp, op)))
                total += Cost(0.0, payload, {matched_coll: payload},
                              {matched_coll: 1.0})
                continue
            if oc.endswith("-done"):
                continue
            if oc == "dot":
                total += Cost(_dot_flops(comp, op),
                              float(_shapes_bytes(op.result_shapes) +
                                    _shapes_bytes(_operand_shapes(comp, op))))
            elif oc == "convolution":
                total += Cost(_conv_flops(comp, op),
                              float(_shapes_bytes(op.result_shapes) +
                                    _shapes_bytes(_operand_shapes(comp, op))))
            elif oc in ("fusion", "custom-call"):
                total += Cost(0.0,
                              float(_shapes_bytes(op.result_shapes) +
                                    _shapes_bytes(_operand_shapes(comp, op))))
                cm = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if cm:
                    inner = cost_of(cm.group(1), depth + 1)
                    total += Cost(inner.flops, 0.0, dict(inner.coll_bytes),
                                  dict(inner.coll_counts))
            elif oc in ("call", "conditional", "async-start"):
                for cname in re.findall(
                        r"(?:to_apply|called_computations|calls)=\{?%?([\w.\-]+)",
                        op.attrs):
                    if cname in comps:
                        total += cost_of(cname, depth + 1)
            elif oc in ("dynamic-slice", "gather", "slice"):
                # traffic = the slice read + written, NOT the whole source
                total += Cost(0.0, 2.0 * _shapes_bytes(op.result_shapes))
            elif oc == "dynamic-update-slice":
                # read-modify-write of the updated region only
                upd = (comp.symbols.get(op.operand_names[1], [])
                       if len(op.operand_names) > 1 else [])
                total += Cost(0.0, 2.0 * _shapes_bytes(upd))
            elif oc == "scatter":
                upd = (comp.symbols.get(op.operand_names[-1], [])
                       if op.operand_names else [])
                total += Cost(0.0, 2.0 * _shapes_bytes(upd))
            elif oc in _MOVE_OPS:
                total += Cost(0.0,
                              float(_shapes_bytes(op.result_shapes) +
                                    _shapes_bytes(_operand_shapes(comp, op))))
        cache[name] = total
        return total

    return cost_of(entry)


# Simple interfaces ---------------------------------------------------------

def collective_bytes(hlo_text: str) -> Dict[str, int]:
    return {k: int(v) for k, v in analyze_entry(hlo_text).coll_bytes.items()}


def collective_op_counts(hlo_text: str) -> Dict[str, int]:
    return {k: int(v) for k, v in analyze_entry(hlo_text).coll_counts.items()}


def collective_summary(hlo_text: str) -> Dict[str, Tuple[int, int]]:
    """Trip-weighted ``{collective: (op_count, payload_bytes)}`` of a
    compiled module, in ONE parse (``collective_bytes`` +
    ``collective_op_counts`` each re-walk the text).  The analyzer's
    collective-bytes pass cross-checks this against the jaxpr-level
    :func:`repro.analysis.collective_execution_model`."""
    cost = analyze_entry(hlo_text)
    return {k: (int(cost.coll_counts.get(k, 0)), int(v))
            for k, v in cost.coll_bytes.items()}


def entry_io_aliases(hlo_text: str) -> List[Tuple[Tuple[int, ...], int]]:
    """The module's ``input_output_alias`` map: ``(output_index_path,
    parameter_number)`` pairs, one per donated-and-aliased buffer.  Empty
    when the executable aliases nothing (donation dropped or absent)."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, len(hlo_text)):   # balanced-brace scan: entries
        if hlo_text[j] == "{":          # themselves contain {} groups
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    else:
        return []
    body = hlo_text[i + 1:j]
    return [(tuple(int(t) for t in out.split(",") if t.strip()), int(param))
            for out, param in re.findall(r"\{([\d,\s]*)\}:\s*\((\d+)",
                                         body)]


def entry_param_shapes(hlo_text: str) -> List[Tuple[str, str]]:
    """Ordered ``(dtype, dims)`` of the ENTRY parameters, from the
    module's ``entry_computation_layout`` header — parameter number i is
    element i (the per-device shapes under SPMD partitioning)."""
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", hlo_text,
                  re.M | re.S)
    if not m:
        return []
    return _SHAPE_RE.findall(m.group(1))


def flops_breakdown(hlo_text: str, top: int = 25) -> List[Tuple[str, float, float]]:
    """Trip-weighted (computation, flops, bytes) hot list for perf work.

    Walks the call graph like analyze_entry but attributes each
    computation's OWN ops (not its callees) scaled by the product of
    enclosing trip counts — a poor man's profile of the compiled step.
    """
    comps, entry = parse_computations(hlo_text)
    own: Dict[str, Cost] = {}
    mult: Dict[str, float] = {}

    def own_cost(name: str) -> Cost:
        if name in own:
            return own[name]
        comp = comps.get(name)
        total = Cost()
        if comp is None:
            own[name] = total
            return total
        for op in comp.ops:
            if op.opcode == "dot":
                total += Cost(_dot_flops(comp, op),
                              float(_shapes_bytes(op.result_shapes) +
                                    _shapes_bytes(_operand_shapes(comp, op))))
            elif op.opcode == "convolution":
                total += Cost(_conv_flops(comp, op), 0.0)
            elif op.opcode in ("fusion", "custom-call"):
                total += Cost(0.0,
                              float(_shapes_bytes(op.result_shapes) +
                                    _shapes_bytes(_operand_shapes(comp, op))))
        own[name] = total
        return total

    def walk(name: str, m: float, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 60:
            return
        mult[name] = mult.get(name, 0.0) + m
        for op in comp.ops:
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = _trip_count(comps, cm.group(1)) if cm else 1.0
                if bm:
                    walk(bm.group(1), m * trips, depth + 1)
            elif op.opcode in ("fusion", "custom-call", "call", "conditional"):
                for cname in re.findall(
                        r"(?:to_apply|called_computations|calls)=\{?%?([\w.\-]+)",
                        op.attrs):
                    if cname in comps:
                        walk(cname, m, depth + 1)

    walk(entry, 1.0)
    rows = []
    for name, m in mult.items():
        c = own_cost(name)
        if c.flops or c.bytes:
            rows.append((name, c.flops * m, c.bytes * m))
    rows.sort(key=lambda r: -r[1])
    return rows[:top]
