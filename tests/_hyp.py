"""Optional-hypothesis shim: `from _hyp import given, settings, st`.

When hypothesis is installed this re-exports the real API.  When it is not
(it is an optional dev extra), property tests are skipped at collection time
while the plain parametrized tests in the same module keep running — tier-1
collection never hard-errors on the missing dependency.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the installed extras
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: any call returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")
