import os

# Tests must see the single real CPU device (the 512-device override is
# strictly local to repro.launch.dryrun) — EXCEPT when the sharded-engine
# equivalence tests are deliberately run on a forced multi-device host
# (CI's forced-4-device job and the subprocess grid in tests/test_engine.py
# set REPRO_ALLOW_FORCED_DEVICES=1 alongside XLA_FLAGS).
if os.environ.get("REPRO_ALLOW_FORCED_DEVICES") != "1":
    assert "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", "")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
