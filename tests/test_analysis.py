"""repro.analysis: pass registry, jaxpr substrate, invariant passes and
seeded-violation mutation tests (the analyzer must CATCH planted bugs —
a green run proves nothing if the passes are vacuous)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from test_engine import _forced_host_env

from repro.analysis import (AnalysisPass, Finding, SuperstepSpec,
                            count_collectives, default_matrix,
                            lower_superstep, make_pass, register_pass,
                            registered_passes, round_body, run_analysis,
                            scan_bodies)
from repro.analysis import registry as _registry

BUILTIN_PASSES = ("collective-bytes", "collectives", "donation", "dtype",
                  "host-sync", "source-lint")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_builtin_passes_registered():
    assert registered_passes() == BUILTIN_PASSES
    for name in BUILTIN_PASSES:
        p = make_pass(name)
        assert p.name == name
        assert p.scope in ("lowered", "source")
        assert p.description


def test_registry_round_trip_and_validation():
    @register_pass
    class _TmpPass(AnalysisPass):
        name = "tmp-test-pass"
        scope = "source"

        def run(self, target):
            return [self.finding("x", "y")]

    try:
        assert "tmp-test-pass" in registered_passes()
        f = make_pass("tmp-test-pass").run(None)[0]
        assert isinstance(f, Finding) and f.pass_name == "tmp-test-pass"
    finally:
        _registry._PASSES.pop("tmp-test-pass")

    with pytest.raises(KeyError):
        make_pass("no-such-pass")
    with pytest.raises(ValueError, match="non-empty"):
        register_pass(type("Nameless", (AnalysisPass,), {}))
    with pytest.raises(ValueError, match="scope"):
        register_pass(type("BadScope", (AnalysisPass,),
                           {"name": "bad-scope", "scope": "nope"}))
    with pytest.raises(ValueError, match="already registered"):
        register_pass(type("Dup", (AnalysisPass,),
                           {"name": "collectives", "scope": "lowered"}))
    with pytest.raises(TypeError):
        register_pass(object)


# ---------------------------------------------------------------------------
# Jaxpr substrate
# ---------------------------------------------------------------------------

def test_count_collectives_and_round_body():
    def f(x):
        def inner(c, t):
            def innermost(c2, t2):
                return c2 * t2, t2
            c2, _ = jax.lax.scan(innermost, c, jnp.arange(3.0))
            return c2 + t, t

        return jax.lax.scan(inner, x, jnp.arange(4.0))

    jaxpr = jax.make_jaxpr(f)(0.0)
    assert count_collectives(jaxpr) == 0
    assert len(scan_bodies(jaxpr)) == 2
    body = round_body(jaxpr)   # depth picks the OUTER scan (length 4)
    assert len(scan_bodies(body)) == 1
    assert round_body(jax.make_jaxpr(jnp.sin)(0.0)) is None


def test_count_collectives_sees_nested_psum():
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.engine.sharded import _unchecked_shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))

    def f(x):
        def body(c, _):
            return c + jax.lax.psum(c, "data"), c
        return jax.lax.scan(body, x, None, length=3)

    wrapped = _unchecked_shard_map(f, mesh, P(), P())
    jaxpr = jax.make_jaxpr(wrapped)(jnp.float32(1.0))
    assert count_collectives(jaxpr) == 1
    assert count_collectives(jaxpr, names=("psum",)) == 1
    assert count_collectives(jaxpr, names=("all_gather",)) == 0
    assert count_collectives(round_body(jaxpr)) == 1


# ---------------------------------------------------------------------------
# Known-good unsharded points: every lowered pass is clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["plain", "topk"])
def test_unsharded_superstep_clean(codec):
    low = lower_superstep(SuperstepSpec(codec=codec))
    for name in ("collectives", "host-sync", "dtype"):
        findings = make_pass(name).run(low)
        assert not findings, (name, [str(f) for f in findings])


def test_unsharded_compiled_passes_clean():
    low = lower_superstep(SuperstepSpec(codec="topk"))
    for name in ("donation", "collective-bytes"):
        findings = make_pass(name).run(low)
        assert not findings, (name, [str(f) for f in findings])


def test_runner_report():
    rep = run_analysis([SuperstepSpec(codec="plain")],
                       passes=["collectives", "host-sync", "source-lint"])
    assert rep.ok
    assert set(rep.points) == {"client_parallel/plain/unsharded",
                               "src/repro"}
    js = rep.to_json()
    assert js["ok"] and js["n_points"] == 2 and js["findings"] == []


def test_default_matrix_presets():
    quick = default_matrix("quick")
    full = default_matrix("full")
    assert len({s.point for s in quick}) == len(quick)
    assert len({s.point for s in full}) == len(full)
    assert set(quick) <= set(full)
    assert any(s.sharded and not s.fused for s in quick)
    assert any(s.ef_store == "host" for s in quick)
    assert any(s.controller != "static" for s in quick)
    unsharded = default_matrix("quick", sharded=False)
    assert unsharded and all(not s.sharded for s in unsharded)


# ---------------------------------------------------------------------------
# Seeded violations (in-process, unsharded)
# ---------------------------------------------------------------------------

def test_mutation_host_callback_caught():
    def add_cb(fn):
        def g(*args):
            jax.debug.callback(lambda: None)
            return fn(*args)
        return g

    low = lower_superstep(SuperstepSpec(codec="topk"), inner_wrap=add_cb)
    findings = make_pass("host-sync").run(low)
    assert findings, "host-sync pass missed a planted debug callback"
    assert any("debug_callback" in f.message for f in findings)


def test_mutation_f64_leaf_caught():
    def add_f64(fn):
        def g(*args):
            leaves, td = jax.tree.flatten(fn(*args))
            poisoned = jnp.asarray(leaves[0], jnp.float64) * 1.000001
            leaves[0] = poisoned.astype(leaves[0].dtype)
            return jax.tree.unflatten(td, leaves)
        return g

    with jax.experimental.enable_x64():
        low = lower_superstep(SuperstepSpec(codec="topk"),
                              inner_wrap=add_f64)
        findings = make_pass("dtype").run(low)
    assert findings, "dtype pass missed a planted float64 value"
    assert any("float64" in f.message for f in findings)


def test_mutation_broken_donation_caught():
    from repro.engine.superstep import donation_argnums
    low = lower_superstep(SuperstepSpec(codec="topk"), donate=())
    _ = low.compiled_text       # compile WITHOUT any donation...
    low.donate_argnums = donation_argnums(
        compressed=True, participation=False, controller=False,
        host_staged=False)      # ...then claim the engine's donations
    findings = make_pass("donation").run(low)
    assert findings, "donation pass missed donation being dropped"
    assert any("aliases 0 buffer" in f.message for f in findings)


def test_mutation_fake_wire_model_caught():
    low = lower_superstep(SuperstepSpec(codec="topk"))
    low.wire_up = low.ideal_model_bytes * 2     # codec "expands" the wire
    findings = make_pass("collective-bytes").run(low)
    assert any("above the ideal" in f.message for f in findings)
    low2 = lower_superstep(SuperstepSpec(codec="topk",
                                         controller="ef_ratio"))
    low2.level_bytes = tuple(reversed(low2.level_bytes))
    findings = make_pass("collective-bytes").run(low2)
    assert any("not ascending" in f.message for f in findings)


# ---------------------------------------------------------------------------
# Sharded: known-good + seeded violations under forced 2 devices
# ---------------------------------------------------------------------------

_SHARDED_ANALYSIS_SCRIPT = textwrap.dedent("""
    import jax
    import jax.numpy as jnp
    assert jax.device_count() == 2, jax.devices()
    from repro.analysis import SuperstepSpec, lower_superstep, make_pass

    spec = SuperstepSpec(codec="topk", sharded=True)

    # known good: every lowered pass is clean on the fused sharded point
    low = lower_superstep(spec)
    for name in ("collectives", "host-sync", "dtype", "donation",
                 "collective-bytes"):
        fs = make_pass(name).run(low)
        assert not fs, (name, [str(f) for f in fs])

    # seeded: an EXTRA psum smuggled into the superstep body
    def add_psum(fn):
        def g(*args):
            out = fn(*args)
            extra = jax.lax.psum(jnp.float32(1.0), "data")
            leaves, td = jax.tree.flatten(out)
            leaves = ([leaves[0] + (extra * 0).astype(leaves[0].dtype)]
                      + leaves[1:])
            return jax.tree.unflatten(td, leaves)
        return g
    low2 = lower_superstep(spec, inner_wrap=add_psum)
    fs = make_pass("collectives").run(low2)
    assert any("3 collective equations" in f.message for f in fs), \\
        [str(f) for f in fs]

    # seeded: compile without donation, then claim the engine's argnums
    from repro.engine.superstep import donation_argnums
    low3 = lower_superstep(spec, donate=())
    _ = low3.compiled_text
    low3.donate_argnums = donation_argnums(
        compressed=True, participation=False, controller=False,
        host_staged=False)
    fs = make_pass("donation").run(low3)
    assert any("aliases 0 buffer" in f.message for f in fs), \\
        [str(f) for f in fs]

    # seeded: a second psum inside the ROUND body via a host callback-free
    # wrap is not reachable from outside the scan, but a non-psum
    # collective at superstep level must also trip the flavour check
    def add_gather(fn):
        def g(*args):
            out = fn(*args)
            extra = jax.lax.all_gather(jnp.float32(1.0), "data")
            leaves, td = jax.tree.flatten(out)
            leaves = ([leaves[0] + (extra.sum() * 0).astype(leaves[0].dtype)]
                      + leaves[1:])
            return jax.tree.unflatten(td, leaves)
        return g
    low4 = lower_superstep(spec, inner_wrap=add_gather)
    fs = make_pass("collectives").run(low4)
    assert any("non-psum" in f.message for f in fs), [str(f) for f in fs]
    print("SHARDED-ANALYSIS-OK")
""")


def test_sharded_passes_and_mutations():
    """Acceptance: on a forced 2-device host every lowered pass is green
    for the fused sharded topk point, and planted violations (extra
    psum, non-psum collective, dropped donation) are each caught."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _SHARDED_ANALYSIS_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-ANALYSIS-OK" in out.stdout


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_list_and_source_pass():
    from repro.analysis.cli import main
    assert main(["--list-passes"]) == 0
    assert main(["--passes", "source-lint", "--quiet"]) == 0
    assert main(["--passes", "no-such-pass", "--quiet"]) == 2


def test_cli_unsharded_scope(tmp_path):
    from repro.analysis.cli import main
    import json
    report = tmp_path / "report.json"
    rc = main(["--scope", "unsharded", "--passes",
               "collectives,host-sync,dtype", "--quiet",
               "--report", str(report)])
    assert rc == 0
    data = json.loads(report.read_text())
    assert data["ok"] and data["n_points"] >= 5
