"""repro.fl.api: the Algorithm registry + FederatedTrainer facade.

Pins the api_redesign contract:

* the configs-layer ``ALGORITHM_NAMES`` literal and the live registry
  cannot drift (mirror of the ``CODEC_NAMES`` sync test);
* the equivalence grid is parametrized over the REGISTRY — every
  registered algorithm (the out-of-core FedProx plugin included)
  reproduces the reference loop through the engine, with codecs on;
* the facade is behaviour-preserving: ``FederatedTrainer.fit`` resumed
  from a checkpoint equals one uninterrupted fit, and the back-compat
  ``run_federated(**old_kwargs)`` wrapper stays bitwise-equal to the
  facade on the same seed;
* the new-client probe's jitted ``deploy_logits`` eval equals the old
  uncompiled per-epoch evaluation;
* no ``fl.algorithm ==`` string branch survives outside the plugin
  modules (the grep gate that keeps the registry honest).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNN_CONFIGS
from repro.configs.base import ALGORITHM_NAMES as CONFIG_ALGORITHM_NAMES
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import iid_partition
from repro.data.synth import class_images
from repro.fl.api import (ALGORITHM_NAMES, Algorithm, CheckpointOptions,
                          EngineOptions, EvalOptions, FederatedTrainer,
                          RunOptions, make_algorithm, register_algorithm)
from repro.fl.server import run_federated, run_federated_reference
from repro.models.registry import make_bundle

_BUNDLE = None


def _bundle():
    global _BUNDLE
    if _BUNDLE is None:
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                                  input_shape=(8, 8, 1), conv_channels=(4,),
                                  fc_units=(8,), dropout=0.0)
        _BUNDLE = make_bundle(cfg)
    return _BUNDLE


def _data(seed=3, n_clients=4):
    x, y = class_images(12, n_classes=4, shape=(8, 8, 1), seed=0)
    return FederatedDataset(iid_partition(x, y, n_clients),
                            {"x": x[:16], "y": y[:16]}, seed=seed)


def _fl(algo, **kw):
    return FLConfig(algorithm=algo, clients_per_round=2, local_steps=2,
                    local_batch=4, lr=0.05, fusion_op="conv", **kw)


def _assert_same(a, b):
    for x, y in zip(jax.tree.leaves(a.global_state),
                    jax.tree.leaves(b.global_state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a.comm.history == b.comm.history
    assert a.comm.bytes_up == b.comm.bytes_up
    assert a.comm.bytes_down == b.comm.bytes_down


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_algorithm_names_in_sync():
    """configs/base.py mirrors the registry literally (codec-style)."""
    assert set(CONFIG_ALGORITHM_NAMES) == set(ALGORITHM_NAMES)
    with pytest.raises(ValueError, match="unknown algorithm"):
        FLConfig(algorithm="fedsgd")


def test_registry_lookup_and_duplicate_guard():
    assert make_algorithm("fedavg").name == "fedavg"
    with pytest.raises(ValueError, match="unknown algorithm"):
        make_algorithm("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_algorithm(make_algorithm("fedavg"))


def test_runtime_registered_plugin_validates_in_config():
    """A plugin registered at runtime — the RingFed/CFedAvg extension
    path — is accepted by FLConfig without editing the configs layer."""

    class _Probe(Algorithm):
        name = "x-probe"

        def local_loss(self, bundle, fl, trainable, global_model, batch,
                       cached_feats_g=None, *, impl="auto"):
            from repro.fl.api.plugins import FedAvg
            return FedAvg.local_loss(self, bundle, fl, trainable,
                                     global_model, batch, cached_feats_g,
                                     impl=impl)

    register_algorithm(_Probe())
    try:
        assert FLConfig(algorithm="x-probe").algorithm == "x-probe"
    finally:
        from repro.fl.api import algorithm as _mod
        _mod._REGISTRY.pop("x-probe")


def test_builtin_plugin_shapes():
    """The hooks describe the state the round fns thread."""
    fusion = make_algorithm("fedfusion")
    assert fusion.extra_state == ("fusion",) and fusion.two_stream
    for name in ("fedavg", "fedl2", "fedprox"):
        a = make_algorithm(name)
        assert a.extra_state == () and not a.two_stream
    assert make_algorithm("fedmmd").two_stream


# ---------------------------------------------------------------------------
# Registry-parametrized equivalence grid (engine == reference loop)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", sorted(ALGORITHM_NAMES))
def test_registry_engine_reproduces_reference(algo):
    """Every registered algorithm — fedprox included — goes through the
    chunked engine bitwise-equal to the reference loop, with an uplink
    codec enabled (EF threading exercised)."""
    bundle = _bundle()
    fl = _fl(algo, uplink_codec="topk", topk_frac=0.1)
    ref = run_federated_reference(bundle, fl, _data(), rounds=4, seed=1,
                                  eval_every=2)
    eng = run_federated(bundle, fl, _data(), rounds=4, seed=1, eval_every=2,
                        superstep_rounds=2)
    _assert_same(ref, eng)


# ---------------------------------------------------------------------------
# FederatedTrainer facade
# ---------------------------------------------------------------------------

def test_run_federated_backcompat_equals_facade():
    """The old 13-kwarg entry point is a thin wrapper: bitwise-equal to
    driving the facade directly with the grouped options."""
    bundle = _bundle()
    fl = _fl("fedmmd", uplink_codec="int8")
    old = run_federated(bundle, fl, _data(), rounds=4, seed=1, eval_every=2,
                        eval_examples=16, superstep_rounds=2)
    trainer = FederatedTrainer(bundle, fl, _data(), RunOptions(
        seed=1, eval=EvalOptions(every=2, examples=16),
        engine=EngineOptions(superstep_rounds=2)))
    new = trainer.fit(4)
    _assert_same(old, new)
    assert trainer.result is new
    # the facade's evaluate() reads the trained state it owns
    metrics = trainer.evaluate()
    assert set(metrics) == {"acc", "loss"}
    np.testing.assert_allclose(metrics["acc"],
                               new.comm.history[-1]["acc"], rtol=1e-6)


def test_trainer_fit_resume_equals_uninterrupted(tmp_path):
    """fit(4) interrupted + fit(8) resumed == one uninterrupted fit(8)."""
    bundle = _bundle()
    fl = _fl("fedfusion", uplink_codec="topk", topk_frac=0.1)

    def opts(d):
        return RunOptions(seed=1, eval=EvalOptions(every=4),
                          checkpoint=CheckpointOptions(dir=str(d), every=2),
                          engine=EngineOptions(superstep_rounds=3))

    FederatedTrainer(bundle, fl, _data(), opts(tmp_path / "a")).fit(4)
    resumed = FederatedTrainer(bundle, fl, _data(),
                               opts(tmp_path / "a")).fit(8)
    full = FederatedTrainer(bundle, fl, _data(), opts(tmp_path / "b")).fit(8)
    for x, y in zip(jax.tree.leaves(resumed.global_state),
                    jax.tree.leaves(full.global_state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert resumed.comm.rounds == 4      # only rounds 5..8 ran


def test_trainer_refit_same_instance_resumes(tmp_path):
    """The in-process interrupt shape: ONE trainer (one dataset instance,
    rng already advanced — possibly past the checkpoint via prefetch) is
    re-invoked.  skip_round_sampling re-seeds, so this too equals the
    uninterrupted run."""
    bundle = _bundle()
    fl = _fl("fedavg", uplink_codec="topk", topk_frac=0.1)
    opts = RunOptions(seed=1, eval=EvalOptions(every=4),
                      checkpoint=CheckpointOptions(dir=str(tmp_path / "a"),
                                                   every=2),
                      engine=EngineOptions(superstep_rounds=3))
    trainer = FederatedTrainer(bundle, fl, _data(), opts)
    trainer.fit(4)          # "interrupted" at round 4 (checkpointed)
    resumed = trainer.fit(8)   # SAME instance: dataset rng is mid-stream
    full = FederatedTrainer(
        bundle, fl, _data(),
        dataclasses.replace(opts, checkpoint=CheckpointOptions(
            dir=str(tmp_path / "b"), every=2))).fit(8)
    for x, y in zip(jax.tree.leaves(resumed.global_state),
                    jax.tree.leaves(full.global_state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_trainer_requires_fit_before_state():
    trainer = FederatedTrainer(_bundle(), _fl("fedavg"), _data())
    with pytest.raises(RuntimeError, match="fit"):
        _ = trainer.global_state


def test_trainer_newclient_probe_runs():
    bundle = _bundle()
    fl = _fl("fedfusion")
    trainer = FederatedTrainer(bundle, fl, _data(), RunOptions(
        seed=1, eval=EvalOptions(every=4),
        engine=EngineOptions(superstep_rounds=2)))
    trainer.fit(2)
    x, y = class_images(6, n_classes=4, shape=(8, 8, 1), seed=9)
    accs = trainer.newclient_probe({"x": x, "y": y}, epochs=2)
    assert len(accs) == 2 and all(np.isfinite(a) for a in accs)


# ---------------------------------------------------------------------------
# FedProx: the out-of-core plugin, end to end
# ---------------------------------------------------------------------------

def test_fedprox_prox_term_penalizes_drift():
    from repro.core.local import make_local_loss
    bundle = _bundle()
    fl = _fl("fedprox", prox_mu=1.0)
    params = bundle.init(jax.random.PRNGKey(0))
    drifted = jax.tree.map(lambda x: x + 0.1, params)
    batch = {"x": jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 1)),
             "y": jax.random.randint(jax.random.PRNGKey(2), (4,), 0, 10)}
    loss_fn = make_local_loss(bundle, fl)
    _, aux0 = loss_fn({"model": params}, params, batch)
    _, aux1 = loss_fn({"model": drifted}, params, batch)
    assert float(aux0["prox"]) < 1e-6
    assert float(aux1["prox"]) > 1e-3


def test_fedprox_trains_end_to_end_with_codecs():
    """Acceptance: the plugin built purely from hooks trains through the
    engine with uplink+downlink codecs enabled and moves the model."""
    bundle = _bundle()
    fl = _fl("fedprox", uplink_codec="topk", downlink_codec="int8",
             topk_frac=0.2)
    trainer = FederatedTrainer(bundle, fl, _data(), RunOptions(
        seed=1, eval=EvalOptions(every=2, examples=16),
        engine=EngineOptions(superstep_rounds=2)))
    res = trainer.fit(4)
    from repro.core.rounds import init_global_state
    init = init_global_state(bundle, fl, jax.random.PRNGKey(1))
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(res.global_state["model"]),
        jax.tree.leaves(init["model"])))
    assert moved > 1e-3
    assert all(np.isfinite(h["local_loss"]) for h in res.comm.history)
    assert res.comm.bytes_up < res.comm.bytes_down  # topk uplink compressed


_FEDPROX_MESH_SCRIPT = textwrap.dedent("""
    import sys
    import jax
    assert jax.device_count() == 2, jax.devices()
    import numpy as np
    from test_api import _bundle, _data, _fl
    from repro.fl.api import EngineOptions, EvalOptions, FederatedTrainer, \\
        RunOptions
    from repro.launch.mesh import make_engine_mesh

    fl = _fl("fedprox", uplink_codec="topk", topk_frac=0.1)
    def run(mesh):
        opts = RunOptions(seed=1, eval=EvalOptions(every=2, examples=16),
                          engine=EngineOptions(superstep_rounds=2,
                                               mesh=mesh))
        return FederatedTrainer(_bundle(), fl, _data(), opts).fit(4)
    single = run(None)
    sharded = run(make_engine_mesh())
    for a, b in zip(jax.tree.leaves(single.global_state),
                    jax.tree.leaves(sharded.global_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    assert single.comm.bytes_up == sharded.comm.bytes_up
    print("FEDPROX-MESH-OK")
""")


def test_fedprox_forced_2device_mesh_matches_single():
    """Acceptance: fedprox through the client-parallel shard_map engine
    (forced 2-device CPU host) is allclose to single-device."""
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    env = dict(os.environ)
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in t]
    env["XLA_FLAGS"] = " ".join(
        kept + ["--xla_force_host_platform_device_count=2"])
    env["REPRO_ALLOW_FORCED_DEVICES"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _FEDPROX_MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "FEDPROX-MESH-OK" in out.stdout


# ---------------------------------------------------------------------------
# Registry-parametrized sharded smoke (CI's forced-4-device job)
# ---------------------------------------------------------------------------

_multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs a forced multi-device host "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=N + "
           "REPRO_ALLOW_FORCED_DEVICES=1)")


@_multidevice
@pytest.mark.parametrize("algo", sorted(ALGORITHM_NAMES))
def test_registry_sharded_smoke(algo):
    """Every registered algorithm runs client-parallel under shard_map,
    allclose to the single-device engine (byte accounting identical)."""
    from repro.launch.mesh import make_engine_mesh
    from test_engine import assert_results_close
    bundle = _bundle()
    fl = FLConfig(algorithm=algo, clients_per_round=4, local_steps=2,
                  local_batch=4, lr=0.05, fusion_op="conv",
                  uplink_codec="topk", topk_frac=0.1)
    single = run_federated(bundle, fl, _data(n_clients=8), rounds=4, seed=1,
                           eval_every=2, superstep_rounds=2)
    sharded = run_federated(bundle, fl, _data(n_clients=8), rounds=4, seed=1,
                            eval_every=2, superstep_rounds=2,
                            mesh=make_engine_mesh())
    assert_results_close(single, sharded)


# ---------------------------------------------------------------------------
# New-client probe: jitted deploy_logits eval == the old eager evaluation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["fedavg", "fedfusion"])
def test_newclient_jitted_eval_matches_eager(algo):
    """The per-epoch eval now runs jitted through Algorithm.deploy_logits;
    the accuracy trajectory must equal the pre-jit op-by-op evaluation
    (argmax-based accuracy is robust to fusion-order float drift), so
    benchmarks/fig6_newclient.py output is unchanged."""
    from repro.core import accuracy, make_local_trainer
    from repro.core.fusion import fusion_apply
    from repro.core.rounds import init_global_state
    from repro.fl.newclient import newclient_convergence

    bundle = _bundle()
    fl = _fl(algo)
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    x, y = class_images(8, n_classes=4, shape=(8, 8, 1), seed=5)
    client = {"x": x, "y": y}
    got = newclient_convergence(bundle, fl, state, client, epochs=3,
                                batch=4, lr=0.05, seed=7)

    # eager replica of the pre-redesign loop (uncompiled eval, string branch)
    rng = np.random.default_rng(7)
    trainer = jax.jit(make_local_trainer(bundle, fl))
    n = len(x)
    steps = n // 4
    st = dict(state)
    want = []
    eval_batch = {k: jnp.asarray(v) for k, v in client.items()}
    for _ in range(3):
        idx = rng.permutation(n)[: steps * 4].reshape(steps, 4)
        batches = {k: jnp.asarray(v[idx]) for k, v in client.items()}
        trainable, _ = trainer(st["model"], st.get("fusion"), batches,
                               jnp.float32(0.05))
        st = {"model": trainable["model"]}
        if algo == "fedfusion":
            st["fusion"] = trainable["fusion"]
        out = bundle.apply(st["model"], eval_batch)
        logits = out["logits"]
        if algo == "fedfusion":
            fused = fusion_apply(fl.fusion_op, st["fusion"],
                                 out["features"], out["features"])
            logits = bundle.head(st["model"], fused)
        want.append(float(accuracy(logits, bundle.labels(eval_batch))))
    assert got == want


# ---------------------------------------------------------------------------
# Lint gate: the registry stays the only algorithm dispatch
# ---------------------------------------------------------------------------

def test_source_lint_clean():
    """The ``repro.analysis`` source-lint pass is clean over src/repro:
    no registry-bypassing ``fl.algorithm ==`` branches outside the plugin
    modules (the old grep gate, now AST-based), no bare asserts in
    library code, no non-lazy function-local imports — and its allowlist
    stays EMPTY."""
    from repro.analysis import make_pass
    from repro.analysis.lint import ALLOWLIST
    assert ALLOWLIST == (), "the lint allowlist must stay empty"
    findings = make_pass("source-lint").run(None)
    assert not findings, "\n".join(str(f) for f in findings)
