"""Blocked flash attention vs the naive O(S^2) oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.attention import (decode_attention, flash_attention,
                                    reference_attention)


def _mk(key, B, Sq, Sk, H, KV, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("S,window,causal,qb,kb", [
    (64, None, True, 16, 16),
    (64, 16, True, 16, 16),
    (96, 32, True, 32, 16),
    (50, None, False, 16, 16),   # non-aligned + bidirectional
    (33, 8, True, 16, 16),       # non-aligned + window
    (128, None, True, 128, 128),  # single block
])
def test_flash_matches_reference(S, window, causal, qb, kb):
    q, k, v = _mk(jax.random.PRNGKey(0), 2, S, S, 4, 2, 16)
    got = flash_attention(q, k, v, window=window, q_block=qb, kv_block=kb,
                          causal=causal)
    want = reference_attention(q, k, v, window=window, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    S=st.integers(4, 80),
    H=st.sampled_from([1, 2, 4, 6]),
    ratio=st.sampled_from([1, 2]),
    hd=st.sampled_from([8, 16, 32]),
    window=st.sampled_from([None, 4, 16]),
)
def test_flash_property_sweep(S, H, ratio, hd, window):
    if H % ratio:
        return
    KV = H // ratio
    q, k, v = _mk(jax.random.PRNGKey(S), 1, S, S, H, KV, hd)
    got = flash_attention(q, k, v, window=window, q_block=16, kv_block=16)
    want = reference_attention(q, k, v, window=window)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


def test_flash_gqa_equals_repeated_mha():
    """GQA with repeated KV == MHA on the expanded heads."""
    q, k, v = _mk(jax.random.PRNGKey(3), 2, 32, 32, 4, 2, 16)
    got = flash_attention(q, k, v, q_block=16, kv_block=16)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    # repeat pattern: head h uses kv group h // rep -> repeat matches
    want = flash_attention(
        q.reshape(2, 32, 2, 2, 16).reshape(2, 32, 4, 16),
        k_rep, v_rep, q_block=16, kv_block=16)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_matches_last_row_of_seq():
    """Decode of token t == row t of full-sequence attention."""
    S = 40
    q, k, v = _mk(jax.random.PRNGKey(4), 2, S, S, 4, 2, 16)
    full = reference_attention(q, k, v)
    got = decode_attention(q[:, S - 1:S], k, v, cache_len=S)
    np.testing.assert_allclose(got[:, 0], full[:, S - 1], atol=2e-5, rtol=2e-5)


def test_decode_respects_cache_len():
    S, valid = 64, 37
    q, k, v = _mk(jax.random.PRNGKey(5), 1, S, S, 2, 2, 8)
    got = decode_attention(q[:, valid - 1:valid], k, v, cache_len=valid)
    want = reference_attention(q[:, :valid], k[:, :valid], v[:, :valid])
    np.testing.assert_allclose(got[:, 0], want[:, valid - 1], atol=2e-5,
                               rtol=2e-5)


def test_flash_q_offset_prefill_continuation():
    """Attention over [0,S) == concat(prefill [0,P), continuation [P,S))."""
    S, P = 48, 32
    q, k, v = _mk(jax.random.PRNGKey(6), 1, S, S, 2, 1, 8)
    full = reference_attention(q, k, v)
    part = flash_attention(q[:, P:], k, v, q_offset=P, q_block=16,
                           kv_block=16)
    np.testing.assert_allclose(part, full[:, P:], atol=2e-5, rtol=2e-5)
