"""repro.compress: codec round trips, wire accounting, kernel parity,
error-feedback convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import CODEC_NAMES, IdentityCodec, QuantCodec, \
    SketchCodec, TopKCodec, make_codec
from repro.configs import CNN_CONFIGS
from repro.configs.base import CODEC_NAMES as CONFIG_CODEC_NAMES, FLConfig
from repro.core.rounds import (init_global_state, make_compressed_round_fn,
                               make_round_fn)
from repro.fl.comm import CommLog, tree_bytes
from repro.kernels import ops, ref
from repro.models.registry import make_bundle


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return {"w": jax.random.normal(k1, (37, 24)),
            "b": jax.random.normal(k2, (11,)),
            "deep": {"v": jax.random.normal(k3, (130,))}}


# ---------------------------------------------------------------------------
# Round trips
# ---------------------------------------------------------------------------

def test_identity_roundtrip_exact_and_raw_bytes():
    t = _tree()
    c = IdentityCodec().bind(t)
    p, _ = c.encode(t)
    for a, b in zip(jax.tree.leaves(c.decode(p)), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert c.nbytes(p) == tree_bytes(t)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("stochastic", [False, True])
def test_quant_roundtrip_within_one_step(bits, stochastic):
    t = _tree()
    c = QuantCodec(bits, impl="jnp").bind(t)
    key = jax.random.PRNGKey(3) if stochastic else None
    p, _ = c.encode(t, None, key)
    dec = c.decode(p)
    qmax = 127 if bits == 8 else 7
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
        scale = float(jnp.max(jnp.abs(b))) / qmax
        assert float(jnp.max(jnp.abs(a - b))) <= scale * (1 + 1e-5)


def test_quant_stochastic_rounding_is_unbiased():
    x = {"w": jnp.full((4096,), 0.3)}
    c = QuantCodec(4, impl="jnp").bind(x)
    p, _ = c.encode(x, None, jax.random.PRNGKey(0))
    dec = c.decode(p)["w"]
    # codes straddle 0.3/scale; the mean must land near 0.3, not on a grid
    # point (deterministic rounding would give max|err| for every element)
    assert abs(float(jnp.mean(dec)) - 0.3) < 0.005


def test_topk_full_frac_roundtrip_exact():
    t = _tree()
    c = TopKCodec(1.0, impl="jnp").bind(t)
    p, _ = c.encode(t, c.init_state())
    for a, b in zip(jax.tree.leaves(c.decode(p)), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_topk_keeps_largest_and_ef_accumulates_remainder():
    t = {"w": jnp.asarray([0.1, -3.0, 0.2, 2.0, -0.05])}
    c = TopKCodec(0.4, impl="jnp").bind(t)   # k = 2 of 5
    st = c.init_state()
    p, new_st = c.encode(t, st)
    dec = c.decode(p)["w"]
    np.testing.assert_allclose(np.asarray(dec), [0, -3.0, 0, 2.0, 0],
                               atol=1e-7)
    # residual holds exactly what was dropped: decoded + residual == input
    np.testing.assert_allclose(np.asarray(dec) + np.asarray(new_st[0]),
                               np.asarray(t["w"]), atol=1e-7)


def test_mask_full_frac_roundtrip_exact():
    t = _tree()
    c = SketchCodec(1.0, mode="mask", impl="jnp").bind(t)
    p, _ = c.encode(t, None, jax.random.PRNGKey(5))
    for a, b in zip(jax.tree.leaves(c.decode(p)), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lowrank_sketch_is_unbiased():
    """E[U G^T] = X over independent sketch seeds."""
    x = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 32))}
    c = SketchCodec(0.25, mode="lowrank", impl="jnp").bind(x)
    acc = np.zeros((16, 32))
    n = 300
    for s in range(n):
        p, _ = c.encode(x, None, jax.random.PRNGKey(1000 + s))
        acc += np.asarray(c.decode(p)["w"])
    err = np.abs(acc / n - np.asarray(x["w"])).max()
    # single-decode error is ~9 here; the 300-seed mean must collapse
    # toward 0 (it would stay ~9 if the estimator were biased)
    assert err < 0.8, err


# ---------------------------------------------------------------------------
# Wire accounting
# ---------------------------------------------------------------------------

def test_nbytes_monotone_in_topk_frac():
    t = _tree()
    sizes = []
    for frac in (0.01, 0.1, 0.5, 1.0):
        c = TopKCodec(frac, impl="jnp").bind(t)
        sizes.append(c.wire_bytes())
    assert sizes == sorted(sizes) and sizes[0] < sizes[-1]


def test_nbytes_monotone_in_quant_bits():
    t = _tree()
    b4 = QuantCodec(4, impl="jnp").bind(t).wire_bytes()
    b8 = QuantCodec(8, impl="jnp").bind(t).wire_bytes()
    assert b4 < b8 < tree_bytes(t)


def test_wire_bytes_matches_concrete_payload():
    t = _tree()
    for name in CODEC_NAMES:
        c = make_codec(name, topk_frac=0.2).bind(t)
        p, _ = c.encode(t, c.init_state(),
                        jax.random.PRNGKey(0) if c.uses_key else None)
        assert c.wire_bytes() == c.nbytes(p), name


def test_config_codec_names_in_sync():
    assert set(CONFIG_CODEC_NAMES) == set(CODEC_NAMES)
    with pytest.raises(ValueError, match="uplink_codec"):
        FLConfig(uplink_codec="gzip")


def test_make_codec_rejects_out_of_range_params():
    """Construction-time rejection, not just FLConfig validation: codecs
    built directly (tests, benchmarks, plugins) get the same errors."""
    for frac in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError, match="topk_frac"):
            make_codec("topk", topk_frac=frac)
        with pytest.raises(ValueError, match="topk_frac"):
            make_codec("mask", topk_frac=frac)
    for bits in (2, 16, 0):
        with pytest.raises(ValueError, match="quant_bits"):
            make_codec("quant", quant_bits=bits)
    with pytest.raises(ValueError, match="unknown codec"):
        make_codec("gzip")
    # direct constructors carry the same guards
    with pytest.raises(ValueError, match="frac"):
        TopKCodec(0.0)
    with pytest.raises(ValueError, match="bits"):
        QuantCodec(3)


def test_commlog_wire_bytes_below_idealized():
    state = {"model": {"w": jnp.zeros((1000,), jnp.float32)}}
    c = make_codec("int8").bind(state["model"])
    wire = c.wire_bytes()
    assert wire < tree_bytes(state["model"])
    log = CommLog()
    log.log_round(state, 4, {}, wire_up=wire, wire_down=wire)
    assert log.bytes_up == 4 * wire
    assert log.bytes_up < log.history[0]["bytes_up_ideal"]
    # uncompressed default unchanged
    raw = CommLog()
    raw.log_round(state, 4, {})
    assert raw.bytes_up == 4 * tree_bytes(state["model"])


def test_commlog_mirror_downlink_charges_all_clients():
    """A mirror-stream downlink is a multicast: every client of the
    federation receives every round's update, not just the sampled ones."""
    state = {"model": {"w": jnp.zeros((1000,), jnp.float32)}}
    log = CommLog()
    log.log_round(state, 4, {}, wire_down=100, n_down=64)
    assert log.bytes_down == 64 * 100
    assert log.bytes_up == 4 * tree_bytes(state["model"])
    # fusion module goes to the round's participants only, not the stream
    state_f = dict(state, fusion={"w": jnp.zeros((10,), jnp.float32)})
    log2 = CommLog()
    log2.log_round(state_f, 4, {}, wire_down=100, n_down=64)
    assert log2.bytes_down == 64 * 100 + 4 * 40


# ---------------------------------------------------------------------------
# Pallas kernels vs jnp references
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("n", [64, 1024, 2050 * 2])
def test_quant_pack_pallas_matches_ref_exactly(bits, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + bits))
    x = jax.random.normal(k1, (n,))
    noise = jax.random.uniform(k2, (n,))
    scale = jnp.max(jnp.abs(x)) / (127 if bits == 8 else 7)
    want = ref.quant_pack_ref(x, scale, noise, bits=bits)
    got = ops.quantize_pack(x, scale, noise, bits=bits,
                            impl="pallas_interpret")
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # unpack parity on the float side
    w = ref.quant_unpack_ref(want, scale, bits=bits, n=n)
    g = ops.quantize_unpack(got, scale, bits=bits, n=n,
                            impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5)


@pytest.mark.parametrize("n,k", [(100, 10), (1500, 1), (4096, 400)])
def test_topk_select_pallas_matches_ref(n, k):
    x = jax.random.normal(jax.random.PRNGKey(k), (n,))
    thresh = jnp.sort(jnp.abs(x))[-k]
    want = ref.topk_select_ref(x, thresh)
    got = ops.topk_threshold_select(x, thresh, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert int(jnp.sum(got != 0)) == k


# ---------------------------------------------------------------------------
# Round integration
# ---------------------------------------------------------------------------

def _tiny_setup(algorithm="fedavg"):
    cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                              input_shape=(12, 12, 1), conv_channels=(4, 8),
                              fc_units=(16,), dropout=0.0)
    bundle = make_bundle(cfg)
    fl = FLConfig(algorithm=algorithm, clients_per_round=4, local_steps=2,
                  local_batch=8, lr=0.05)
    return bundle, fl


def _round_inputs(key, n_clients=4, steps=2, batch=8):
    kx, ky = jax.random.split(key)
    batches = {"x": jax.random.normal(kx, (n_clients, steps, batch,
                                           12, 12, 1)),
               "y": jax.random.randint(ky, (n_clients, steps, batch), 0, 10)}
    sizes = jnp.asarray([40.0, 30.0, 20.0, 10.0])
    return batches, sizes


@pytest.mark.parametrize("mode", ["client_parallel", "client_sequential"])
def test_identity_codecs_reproduce_plain_round(mode):
    """encode/decode through identity == the classic FedAvg round."""
    bundle, fl = _tiny_setup()
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batches, sizes = _round_inputs(jax.random.PRNGKey(1))
    plain = make_round_fn(bundle, fl, mode)
    up = IdentityCodec().bind(state["model"])
    down = IdentityCodec().bind(state["model"])
    comp = make_compressed_round_fn(bundle, fl, mode, up, down)
    ef = jax.tree.map(lambda z: jnp.stack([z] * 4), up.init_state())
    want, wm = plain(state, batches, sizes, 0.05)
    got, gm, _, _ = comp(state, batches, sizes, 0.05, ef, state["model"],
                         jax.random.PRNGKey(2))
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(float(wm["local_loss"]),
                               float(gm["local_loss"]), atol=1e-6)


def test_sparse_downlink_broadcasts_update_not_weights():
    """A top-k downlink must NOT hand clients a mostly-zero model: the
    broadcast stream compresses the model *update* against a mirror, so
    the decoded broadcast stays close to the true model."""
    bundle, fl = _tiny_setup()
    fl = dataclasses.replace(fl, downlink_codec="topk", topk_frac=0.05)
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    from repro.compress import make_codec
    up = IdentityCodec().bind(state["model"])
    down = make_codec("topk", topk_frac=0.05).bind(state["model"])
    comp = make_compressed_round_fn(bundle, fl, "client_parallel", up, down)
    ef = jax.tree.map(lambda z: jnp.stack([z] * 4), up.init_state())
    batches, sizes = _round_inputs(jax.random.PRNGKey(1))
    new_state, _, _, mirror = comp(state, batches, sizes, 0.05, ef,
                                   state["model"], jax.random.PRNGKey(2))
    # round 1: model == mirror, update is zero -> clients saw the full
    # model, not a 5%-sparse one
    for m, b in zip(jax.tree.leaves(state["model"]),
                    jax.tree.leaves(mirror)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(m), atol=1e-6)
    # server model stays full-precision (update applied to global, the
    # mirror stream tracks it)
    new_state2, _, _, mirror2 = comp(new_state, batches, sizes, 0.05, ef,
                                     mirror, jax.random.PRNGKey(3))
    nz = sum(int(jnp.sum(l != 0)) for l in jax.tree.leaves(new_state2["model"]))
    total = sum(l.size for l in jax.tree.leaves(new_state2["model"]))
    assert nz > 0.5 * total   # dense, not top-k-sparse


def test_mirror_stream_converges_to_static_target():
    """The stateless top-k mirror stream must converge to the model (an
    EF residual on top of the mirror gap double-counts dropped mass and
    provably diverges — the round fn therefore encodes statelessly)."""
    model = {"w": jax.random.normal(jax.random.PRNGKey(0), (100,))}
    c = TopKCodec(0.05, impl="jnp").bind(model)
    mirror = jax.tree.map(jnp.zeros_like, model)
    for _ in range(60):
        upd = jax.tree.map(lambda m, w: m - w, model, mirror)
        p, _ = c.encode(upd, c.init_state())   # stateless, as rounds.py does
        mirror = jax.tree.map(lambda w, d: w + d, mirror, c.decode(p))
    gap = float(jnp.max(jnp.abs(model["w"] - mirror["w"])))
    assert gap < 1e-5, gap


def test_error_feedback_converges_within_2x_rounds():
    """Top-k+EF on synthetic non-IID reaches the identity-codec loss
    milestone within 2x the rounds (the EF convergence guarantee)."""
    from repro.data.federated import FederatedDataset
    from repro.data.partition import artificial_noniid_partition
    from repro.data.synth import class_images
    from repro.fl.server import run_federated

    cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                              conv_channels=(8, 16), fc_units=(64,),
                              dropout=0.0)
    bundle = make_bundle(cfg)
    x, y = class_images(24, seed=0, template_seed=0, noise=0.2)
    parts = artificial_noniid_partition(x, y, 8)
    xt, yt = class_images(8, seed=1, template_seed=0, noise=0.2)

    def rounds_to_loss(codec, rounds):
        data = FederatedDataset(parts, {"x": xt, "y": yt}, seed=7)
        fl = FLConfig(algorithm="fedavg", clients_per_round=4,
                      local_steps=4, local_batch=32, lr=0.06,
                      uplink_codec=codec, topk_frac=0.1)
        res = run_federated(bundle, fl, data, rounds=rounds, seed=0,
                            eval_every=10_000)
        for h in res.comm.history:
            if h["local_loss"] <= 1.2:
                return h["round"]
        return -1

    r_id = rounds_to_loss("identity", 12)
    assert r_id > 0, "identity baseline never hit the loss milestone"
    r_ef = rounds_to_loss("topk", 2 * r_id)
    assert 0 < r_ef <= 2 * r_id, (r_id, r_ef)
