"""Assigned-architecture configs: exact values + reduced-variant invariants."""
import pytest

from repro.configs import ARCH_CONFIGS, CNN_CONFIGS, INPUT_SHAPES, get_config
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSD

# (name, family, L, d_model, H, KV, d_ff, vocab)
ASSIGNED = [
    ("arctic-480b", "moe", 35, 7168, 56, 8, 4864, 32000),
    ("granite-moe-1b-a400m", "moe", 24, 1024, 16, 8, 512, 49155),
    ("smollm-135m", "dense", 30, 576, 9, 3, 1536, 49152),
    ("qwen2-vl-7b", "vlm", 28, 3584, 28, 4, 18944, 152064),
    ("h2o-danube-3-4b", "dense", 24, 3840, 32, 8, 10240, 32000),
    ("recurrentgemma-9b", "hybrid", 38, 4096, 16, 1, 12288, 256000),
    ("gemma3-1b", "dense", 26, 1152, 4, 1, 6912, 262144),
    ("whisper-large-v3", "audio", 32, 1280, 20, 20, 5120, 51866),
    ("mamba2-130m", "ssm", 24, 768, 0, 0, 0, 50280),
    ("stablelm-3b", "dense", 32, 2560, 32, 32, 6912, 50304),
]


@pytest.mark.parametrize("name,family,L,d,H,KV,dff,V", ASSIGNED)
def test_assigned_values(name, family, L, d, H, KV, dff, V):
    cfg = get_config(name)
    assert cfg.family == family
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if family != "ssm":
        assert cfg.n_heads == H
        assert cfg.n_kv_heads == KV
    assert cfg.d_ff == dff
    assert cfg.vocab_size == V
    assert cfg.source, f"{name} missing citation"


def test_pool_covers_all_ten():
    assert len(ARCH_CONFIGS) == 10
    assert {c.family for c in ARCH_CONFIGS.values()} == {
        "moe", "dense", "vlm", "hybrid", "audio", "ssm"}


def test_moe_settings():
    a = get_config("arctic-480b")
    assert (a.n_experts, a.top_k, a.dense_residual) == (128, 2, True)
    g = get_config("granite-moe-1b-a400m")
    assert (g.n_experts, g.top_k) == (32, 8)


def test_block_patterns():
    assert set(get_config("mamba2-130m").block_pattern) == {SSD}
    rg = get_config("recurrentgemma-9b").block_pattern
    assert rg[:3] == (RGLRU, RGLRU, ATTN_LOCAL)       # 1:2 attn:recurrent
    g3 = get_config("gemma3-1b").block_pattern
    assert g3[:6] == (ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,)  # 5:1 local:global
    assert set(get_config("h2o-danube-3-4b").block_pattern) == {ATTN_LOCAL}


def test_whisper_is_encdec():
    w = get_config("whisper-large-v3")
    assert w.n_enc_layers == 32
    assert w.n_audio_frames == 1500


def test_mamba2_state():
    m = get_config("mamba2-130m")
    assert m.ssm_state == 128
    assert m.is_attention_free


@pytest.mark.parametrize("name", sorted(ARCH_CONFIGS))
def test_reduced_invariants(name):
    cfg = get_config(name)
    r = cfg.reduced()
    assert r.n_layers == 2
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.vocab_size <= 512
    # same family + same block kinds exercised
    assert r.family == cfg.family
    assert set(r.block_pattern) <= set(cfg.block_pattern)
    if cfg.n_heads:
        assert r.n_heads % r.n_kv_heads == 0
        assert r.d_model % r.n_heads == 0
    if cfg.mrope:
        assert sum(r.mrope_sections) == r.head_dim // 2


def test_param_counts_plausible():
    # order-of-magnitude sanity vs the names
    assert 1.0e8 < get_config("smollm-135m").param_count() < 1.9e8
    assert 1.0e8 < get_config("mamba2-130m").param_count() < 2.0e8
    assert 2.5e9 < get_config("stablelm-3b").param_count() < 4.5e9
    assert 3.0e9 < get_config("h2o-danube-3-4b").param_count() < 5.0e9
    assert 6e9 < get_config("qwen2-vl-7b").param_count() < 9.5e9
    assert 7e9 < get_config("recurrentgemma-9b").param_count() < 11e9
    arctic = get_config("arctic-480b")
    assert 3.5e11 < arctic.param_count() < 5.6e11
    assert arctic.active_param_count() < 0.1 * arctic.param_count()
    gr = get_config("granite-moe-1b-a400m")
    assert gr.param_count() < 2.2e9
    assert gr.active_param_count() < gr.param_count()


def test_input_shapes_exact():
    assert (INPUT_SHAPES["train_4k"].seq_len,
            INPUT_SHAPES["train_4k"].global_batch) == (4096, 256)
    assert (INPUT_SHAPES["prefill_32k"].seq_len,
            INPUT_SHAPES["prefill_32k"].global_batch) == (32768, 32)
    assert (INPUT_SHAPES["decode_32k"].seq_len,
            INPUT_SHAPES["decode_32k"].global_batch) == (32768, 128)
    assert (INPUT_SHAPES["long_500k"].seq_len,
            INPUT_SHAPES["long_500k"].global_batch) == (524288, 1)


def test_cnn_configs_match_paper():
    m = CNN_CONFIGS["cnn_mnist"]
    assert m.conv_channels == (32, 64) and m.fc_units == (512,)
    c = CNN_CONFIGS["cnn_cifar"]
    assert c.conv_channels == (64, 64) and c.fc_units == (384, 192)
    assert c.pool_size == 3 and c.pool_stride == 2
