"""repro.control: in-superstep adaptive compression controllers.

The load-bearing contracts:

* ``controller="static"`` is the BITWISE oracle — an engine run with the
  controller axis present but static is identical to the pre-controller
  engine (final model, CommLog history, resumed ef.npz), single-device
  and forced-2-device sharded.
* An active controller adds ZERO collectives: the fused sharded round
  keeps exactly one psum per round with controller + telemetry +
  participation/chaos args on (jaxpr-asserted).
* Controller state checkpoints (ctrl.npz): interrupt+resume is
  bitwise-equal to an uninterrupted run, across ef_store layouts.
* Level masking is exact: the capacity-bound codec at the top level
  traces byte-identical payloads to the static encode, and a masked
  level transmits exactly the top-k_l entries with an exact EF residual.
* CommLog charges the effective per-round bytes of the scheduled level.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compress import IdentityCodec, make_codec
from repro.configs.base import (CONTROLLER_NAMES, FLConfig, _LADDER_CODECS)
from repro.control import (LADDER_CODECS, Controller, LadderSpec,
                           ladder_kind, ladder_values, make_controller,
                           register_controller, registered_controllers)
from repro.control.controller import _REGISTRY
from repro.core.rounds import init_global_state
from repro.fl.comm import CommLog
from repro.fl.server import run_federated, run_federated_reference

from test_engine import (_assert_same, _bundle, _data, _fl_for, _reference,
                         _forced_host_env)


# ---------------------------------------------------------------------------
# Registry (the make_codec / make_algorithm / make_policy idiom)
# ---------------------------------------------------------------------------

def test_registry_builtins_and_errors():
    names = registered_controllers()
    assert names == tuple(sorted(names))
    assert set(names) == {"static", "ef_ratio", "bytes_budget", "loss_trend"}
    assert isinstance(make_controller("ef_ratio"), Controller)
    with pytest.raises(ValueError, match="unknown controller"):
        make_controller("nope")
    with pytest.raises(ValueError, match="already registered"):
        register_controller("static", Controller)


def test_register_controller_plugin():
    class Custom(Controller):
        name = "testctl"

    register_controller("testctl", Custom)
    try:
        assert "testctl" in registered_controllers()
        register_controller("testctl", Custom, overwrite=True)
        # config validation falls back to the live registry for plugins
        fl = FLConfig(controller="testctl", uplink_codec="topk")
        assert fl.controller == "testctl"
    finally:
        _REGISTRY.pop("testctl", None)


def test_config_controller_names_in_sync():
    assert set(CONTROLLER_NAMES) == set(registered_controllers())
    assert tuple(_LADDER_CODECS) == tuple(LADDER_CODECS)


def test_config_controller_validation():
    with pytest.raises(ValueError, match="unknown controller"):
        FLConfig(controller="bogus")
    with pytest.raises(ValueError, match="ladder-capable"):
        FLConfig(controller="ef_ratio")          # identity uplink
    with pytest.raises(ValueError, match="ascending"):
        FLConfig(controller="ef_ratio", uplink_codec="topk",
                 topk_frac=0.2, ladder=(0.2, 0.1))
    with pytest.raises(ValueError, match="ctrl_band"):
        FLConfig(ctrl_band=(2.0, 0.5))
    with pytest.raises(ValueError, match="ctrl_budget_frac"):
        FLConfig(ctrl_budget_frac=0.0)
    with pytest.raises(ValueError, match="ctrl_ema"):
        FLConfig(ctrl_ema=1.0)


# ---------------------------------------------------------------------------
# Ladder helpers + LadderSpec
# ---------------------------------------------------------------------------

def test_ladder_kind():
    assert ladder_kind("topk") == "topk_frac"
    assert ladder_kind("topk_noef") == "topk_frac"
    assert ladder_kind("int8") == "quant_bits"
    assert ladder_kind("quant") == "quant_bits"
    with pytest.raises(ValueError, match="no compression ladder"):
        ladder_kind("identity")


def test_ladder_values_defaults():
    fl = FLConfig(uplink_codec="topk", topk_frac=0.2)
    assert ladder_values(fl) == (0.05, 0.1, 0.2)
    assert ladder_values(FLConfig(uplink_codec="int8")) == (4, 8)
    # int4 fixes its capacity by NAME, whatever quant_bits says
    assert ladder_values(FLConfig(uplink_codec="int4")) == (4,)
    assert ladder_values(FLConfig(uplink_codec="quant",
                                  quant_bits=4)) == (4,)
    fl = FLConfig(uplink_codec="topk", topk_frac=0.2, ladder=(0.1, 0.2))
    assert ladder_values(fl) == (0.1, 0.2)


def test_ladder_values_validation():
    with pytest.raises(ValueError, match="must equal topk_frac"):
        ladder_values(FLConfig(uplink_codec="topk", topk_frac=0.2,
                               ladder=(0.05, 0.1)))
    with pytest.raises(ValueError, match="bits in"):
        ladder_values(FLConfig(uplink_codec="int8", ladder=(2, 8)))
    with pytest.raises(ValueError, match="capacity bits"):
        ladder_values(FLConfig(uplink_codec="int4", ladder=(4, 8)))


def test_ladder_spec_validation():
    with pytest.raises(ValueError, match="length mismatch"):
        LadderSpec(kind="topk_frac", values=(0.1, 0.2), bytes_up=(8,))
    with pytest.raises(ValueError, match="at least one level"):
        LadderSpec(kind="topk_frac", values=(), bytes_up=())
    spec = LadderSpec(kind="topk_frac", values=(0.1, 0.2),
                      bytes_up=(80, 160))
    assert spec.n_levels == 2
    np.testing.assert_array_equal(np.asarray(spec.bytes_table()),
                                  [80.0, 160.0])


# ---------------------------------------------------------------------------
# Codec level ladders: masking is exact, capacity level == static bitwise
# ---------------------------------------------------------------------------

def _small_tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (40,)),
            "b": jax.random.normal(k2, (11,))}


def test_topk_ladder_top_level_is_static_bitwise():
    t = _small_tree()
    c = make_codec("topk", topk_frac=0.4).bind(t)
    c.set_ladder((0.1, 0.2, 0.4))
    st = c.init_state()
    p_static, s_static = c.encode(t, st)
    p_top, s_top = c.encode(t, st, level=jnp.asarray(2, jnp.int32))
    for a, b in zip(jax.tree.leaves(p_static), jax.tree.leaves(p_top)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(s_static), jax.tree.leaves(s_top)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_topk_ladder_masked_level_exact():
    """Level 0 transmits exactly the top-k_0 entries (capacity-shaped
    payload, rest masked to zero) and the EF residual keeps exactly what
    was not transmitted: decode(payload) + residual == input + old EF."""
    t = {"w": jax.random.normal(jax.random.PRNGKey(3), (50,))}
    c = make_codec("topk", topk_frac=0.4).bind(t)   # k_cap = 20
    c.set_ladder((0.1, 0.2, 0.4))                   # k_0 = 5
    st = c.init_state()
    p, new_st = c.encode(t, st, level=jnp.asarray(0, jnp.int32))
    dec = np.asarray(c.decode(p)["w"])
    g = np.asarray(t["w"])
    k0 = 5
    keep = np.argsort(-np.abs(g))[:k0]
    want = np.zeros_like(g)
    want[keep] = g[keep]
    np.testing.assert_array_equal(dec, want)
    # payload stays capacity-shaped; only k_0 slots are non-zero
    assert p[0]["val"].shape == (20,)
    assert int(np.sum(np.asarray(p[0]["val"]) != 0)) == k0
    # EF exactness
    np.testing.assert_allclose(dec + np.asarray(new_st[0]), g, atol=1e-7)


def test_topk_set_ladder_validation_and_level_bytes():
    t = _small_tree()
    c = make_codec("topk", topk_frac=0.4).bind(t)
    with pytest.raises(ValueError, match="ascending"):
        c.set_ladder((0.4, 0.2))
    with pytest.raises(ValueError, match="capacity frac"):
        c.set_ladder((0.1, 0.2))
    with pytest.raises(ValueError, match="set_ladder first"):
        c.level_bytes()
    c.set_ladder((0.1, 0.2, 0.4))
    lb = c.level_bytes()
    assert list(lb) == sorted(lb) and len(lb) == 3
    assert lb[-1] == c.wire_bytes()      # top level IS the static wire
    # 8 bytes per kept (idx, val) pair, k = max(1, round(frac * n))
    assert lb[0] == 8 * (max(1, round(0.1 * 40)) + max(1, round(0.1 * 11)))


def test_quant_ladder_levels():
    t = _small_tree()
    c = make_codec("int8").bind(t)
    c.set_ladder((4, 8))
    lb = c.level_bytes()
    assert lb[0] < lb[1] == c.wire_bytes()
    # capacity level == static bitwise (packed codes and scales)
    p_static, _ = c.encode(t)
    p_top, _ = c.encode(t, level=jnp.asarray(1, jnp.int32))
    for a, b in zip(jax.tree.leaves(p_static), jax.tree.leaves(p_top)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # level 0 = effective 4-bit: error within one 4-bit step per leaf
    p0, _ = c.encode(t, level=jnp.asarray(0, jnp.int32))
    dec = c.decode(p0)
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
        step = float(jnp.max(jnp.abs(b))) / 7
        assert float(jnp.max(jnp.abs(a - b))) <= step * (1 + 1e-5)
    with pytest.raises(ValueError, match="capacity bits"):
        make_codec("int4").bind(t).set_ladder((4, 8))


def test_identity_codec_has_no_ladder():
    t = _small_tree()
    c = IdentityCodec().bind(t)
    with pytest.raises(ValueError, match="no compression ladder"):
        c.set_ladder((0.1, 1.0))
    with pytest.raises(ValueError, match="no compression ladder"):
        c.level_bytes()
    with pytest.raises(NotImplementedError):
        c.encode(t, level=jnp.asarray(0, jnp.int32))


# ---------------------------------------------------------------------------
# Decision rules (pure traced updates, no engine)
# ---------------------------------------------------------------------------

def _spec3():
    return LadderSpec(kind="topk_frac", values=(0.05, 0.1, 0.2),
                      bytes_up=(100, 200, 400))


def test_static_controller_is_a_noop():
    c = make_controller("static").setup(_spec3(), FLConfig())
    st = c.init_state()
    assert int(st["level"]) == 2                 # capacity level
    assert c.update(st, {"local_loss": 1.0}) is st


def test_ef_ratio_controller_escalates_and_clips():
    fl = FLConfig(uplink_codec="topk", ctrl_band=(0.5, 2.0), ctrl_ema=0.0)
    c = make_controller("ef_ratio").setup(_spec3(), fl)
    st = c.init_state()
    assert int(st["level"]) == 0                 # starts cheapest
    for _ in range(5):                           # ratio way above band
        st = c.update(st, {"tele/ef_delta_ratio": jnp.float32(10.0)})
    assert int(st["level"]) == 2                 # clipped at capacity
    for _ in range(5):                           # below band -> tighten
        st = c.update(st, {"tele/ef_delta_ratio": jnp.float32(0.0)})
    assert int(st["level"]) == 0                 # clipped at 0
    st = c.update(st, {"tele/ef_delta_ratio": jnp.float32(1.0)})
    assert int(st["level"]) == 0                 # inside the band: hold


def test_bytes_budget_controller_tracks_spend():
    fl = FLConfig(uplink_codec="topk", ctrl_budget_frac=0.5)
    c = make_controller("bytes_budget").setup(_spec3(), fl)
    st = c.init_state()
    levels = []
    for _ in range(8):
        levels.append(int(st["level"]))
        st = c.update(st, {})
    assert all(0 <= l <= 2 for l in levels)
    # the running spend is exactly the sum of the played levels' bytes
    want = sum((100, 200, 400)[l] for l in levels)
    assert float(st["spent"]) == want
    assert float(st["rounds"]) == 8
    # budget = 0.5 * 400 = 200 bytes/round on average, so the long-run
    # spend stays at or under it
    assert float(st["spent"]) <= 200 * 8 + 400


def test_loss_trend_controller_plateau_loosens():
    fl = FLConfig(uplink_codec="topk", ctrl_ema=0.0)
    c = make_controller("loss_trend").setup(_spec3(), fl)
    st = c.init_state()
    st = c.update(st, {"local_loss": jnp.float32(2.0)})
    assert int(st["level"]) == 0                 # first round: no signal
    st = c.update(st, {"local_loss": jnp.float32(1.0)})
    assert int(st["level"]) == 0                 # falling fast: stay cheap
    st = c.update(st, {"local_loss": jnp.float32(1.0)})
    assert int(st["level"]) == 1                 # plateau: loosen


# ---------------------------------------------------------------------------
# Engine: static == the pre-controller oracle, BITWISE
# ---------------------------------------------------------------------------

_COMPRESSED_CASES = ("topk", "quant+downtopk", "fusion-topk")


@pytest.mark.parametrize("mode", ["client_parallel", "client_sequential"])
@pytest.mark.parametrize("case", _COMPRESSED_CASES)
def test_static_controller_engine_bitwise(mode, case):
    """An engine run with controller='static' spelled out reproduces the
    reference loop exactly — the controller axis must not perturb the
    pre-controller traced program by a single bit."""
    bundle = _bundle()
    ref = _reference(bundle, mode, case)
    fl = dataclasses.replace(_fl_for(case), controller="static")
    eng = run_federated(bundle, fl, _data(), rounds=6, seed=1,
                        eval_every=2, mode=mode, superstep_rounds=4)
    _assert_same(ref, eng)
    # static short-circuits: no controller in the engine at all
    assert eng.stats["controller"] is None
    assert eng.stats["ladder"] is None


def test_static_controller_checkpoint_resume_bitwise(tmp_path):
    """Interrupt+resume with controller='static': same two-phase bitwise
    contract as the pre-controller engine, resumed ef.npz included."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=4, lr=0.05, uplink_codec="topk",
                  topk_frac=0.1, controller="static")
    dr = _data()
    run_federated_reference(bundle, fl, dr, rounds=4, seed=1, eval_every=4,
                            checkpoint_dir=str(tmp_path / "ref"),
                            checkpoint_every=2)
    ref = run_federated_reference(bundle, fl, dr, rounds=8, seed=1,
                                  eval_every=4,
                                  checkpoint_dir=str(tmp_path / "ref"),
                                  checkpoint_every=2)
    de = _data()
    run_federated(bundle, fl, de, rounds=4, seed=1, eval_every=4,
                  checkpoint_dir=str(tmp_path / "eng"), checkpoint_every=2,
                  superstep_rounds=3)
    eng = run_federated(bundle, fl, de, rounds=8, seed=1, eval_every=4,
                        checkpoint_dir=str(tmp_path / "eng"),
                        checkpoint_every=2, superstep_rounds=3)
    _assert_same(ref, eng)
    # static short-circuits the controller: no ctrl.npz is written
    assert not os.path.exists(str(tmp_path / "eng" / "ctrl.npz"))


# ---------------------------------------------------------------------------
# Engine: adaptive schedules + effective-bytes accounting
# ---------------------------------------------------------------------------

def _adaptive_fl(controller="ef_ratio", **kw):
    return FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                    local_batch=4, lr=0.05, uplink_codec="topk",
                    topk_frac=0.2, controller=controller, **kw)


@pytest.mark.parametrize("controller",
                         ["ef_ratio", "bytes_budget", "loss_trend"])
def test_adaptive_engine_schedule_and_accounting(controller):
    """Every built-in controller runs in the jitted superstep; the
    history carries the per-round level + effective codec fields and
    CommLog charges the scheduled level's wire bytes, not capacity's."""
    bundle = _bundle()
    fl = _adaptive_fl(controller)
    res = run_federated(bundle, fl, _data(), rounds=6, seed=1,
                        eval_every=2, superstep_rounds=3)
    assert res.stats["controller"] == controller
    assert res.stats["ladder"] == [0.05, 0.1, 0.2]
    # the effective per-level wire bytes, from the same codec the engine
    # binds
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    lb = make_codec("topk", topk_frac=0.2).bind(state["model"]) \
        .set_ladder((0.05, 0.1, 0.2)).level_bytes()
    assert len(res.comm.history) == 6
    for h in res.comm.history:
        lvl = h["level"]
        assert lvl in (0, 1, 2)
        assert h["eff_topk_frac"] == (0.05, 0.1, 0.2)[lvl]
        assert h["bytes_up"] == fl.clients_per_round * lb[lvl]
        assert h["tele/level"] == lvl
        assert h["tele/effective_bytes"] == lb[lvl]
    assert res.comm.bytes_up == sum(h["bytes_up"]
                                    for h in res.comm.history)


def test_adaptive_chunk_size_invariant():
    """The controller state rides the scan carry: K=1 (no scan), K=3 and
    K=6 produce the identical schedule and model."""
    bundle = _bundle()
    fl = _adaptive_fl()
    runs = [run_federated(bundle, fl, _data(), rounds=6, seed=1,
                          eval_every=2, superstep_rounds=k)
            for k in (1, 3, 6)]
    _assert_same(runs[0], runs[1])
    _assert_same(runs[0], runs[2])


def test_adaptive_checkpoint_resume_bitwise(tmp_path):
    """ctrl.npz: interrupt at round 4, resume to 8 — model, history and
    the schedule itself match the uninterrupted run bitwise, with the
    controller state restored from the checkpoint (not re-initialized),
    across ef_store layouts."""
    bundle = _bundle()
    fl = _adaptive_fl()
    kw = dict(seed=1, eval_every=4, superstep_rounds=3)
    oracle = run_federated(bundle, fl, _data(), rounds=8, **kw)
    for store in ("device", "host"):
        d = str(tmp_path / store)
        run_federated(bundle, fl, _data(), rounds=4, checkpoint_dir=d,
                      checkpoint_every=2, ef_store=store, **kw)
        assert os.path.exists(os.path.join(d, "ctrl.npz"))
        resumed = run_federated(bundle, fl, _data(), rounds=8,
                                checkpoint_dir=d, checkpoint_every=2,
                                **kw)
        for a, b in zip(jax.tree.leaves(oracle.global_state),
                        jax.tree.leaves(resumed.global_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # the resumed run replays rounds 5..8 exactly — schedule, losses
        # and taps bitwise; only the fresh CommLog's own counters differ
        strip = lambda h: {k: v for k, v in h.items()
                           if k not in ("round", "cum_bytes_up")}
        assert [strip(h) for h in resumed.comm.history] \
            == [strip(h) for h in oracle.comm.history[4:]]
        assert [h["level"] for h in resumed.comm.history] \
            == [h["level"] for h in oracle.comm.history[4:]]


def test_adaptive_with_participation_and_telemetry():
    """Controller + partial participation + explicit telemetry compose in
    one superstep (the chaos-bearing arg layout with a trailing
    ctrl_state)."""
    bundle = _bundle()
    fl = _adaptive_fl(participation="deadline")
    res = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                        eval_every=2, superstep_rounds=2, telemetry=True)
    assert all("level" in h for h in res.comm.history)
    assert all("tele/ef_delta_ratio" in h for h in res.comm.history)


def test_controller_tap_unavailable_raises():
    """ef_ratio needs the 'ef' telemetry tap, which needs a stateful
    error-feedback uplink — int8 has none, and the engine says so instead
    of silently feeding the controller garbage."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=4, lr=0.05, uplink_codec="int8",
                  controller="ef_ratio")
    with pytest.raises(ValueError, match="telemetry taps"):
        run_federated(bundle, fl, _data(), rounds=2, seed=1)


def test_reference_loop_rejects_controller():
    bundle = _bundle()
    with pytest.raises(NotImplementedError, match="engine feature"):
        run_federated_reference(bundle, _adaptive_fl(), _data(), rounds=2,
                                seed=1)


def test_commlog_effective_fields_schema():
    """Schema v2: round records may carry the effective codec fields; old
    records (no controller) parse and serialize exactly as before."""
    state = {"model": {"w": jnp.zeros((100,), jnp.float32)}}
    log = CommLog()
    log.log_round(state, 2, {"local_loss": 1.0}, wire_up=80,
                  effective={"level": 0, "eff_topk_frac": 0.05})
    log.log_round(state, 2, {"local_loss": 0.9}, wire_up=160)  # no ctrl
    recs = log.to_records()
    assert recs[0]["level"] == 0 and recs[0]["eff_topk_frac"] == 0.05
    assert "level" not in recs[1]
    assert recs[-1]["kind"] == "summary" and recs[-1]["schema"] == 2


# ---------------------------------------------------------------------------
# Sharded: forced-2-device static bitwise + adaptive smoke
# ---------------------------------------------------------------------------

_SHARDED_CTRL_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax
    assert jax.device_count() == 2, jax.devices()
    from test_engine import (_assert_same, _bundle, _sharded_data,
                             _sharded_fl, assert_results_close)
    from repro.fl.server import run_federated
    from repro.launch.mesh import make_engine_mesh

    mesh = make_engine_mesh()
    for case in ("topk", "fusion-topk"):
        mode, fl = _sharded_fl(case)
        fl = dataclasses.replace(fl, controller="static")
        kw = dict(rounds=4, seed=1, eval_every=2, mode=mode,
                  superstep_rounds=2)
        single = run_federated(_bundle(), fl, _sharded_data(), **kw)
        sharded = run_federated(_bundle(), fl, _sharded_data(), mesh=mesh,
                                **kw)
        assert_results_close(single, sharded)
        # fused one-psum round == three-collective oracle BITWISE, with
        # the controller axis present but static
        unfused = run_federated(_bundle(), fl, _sharded_data(), mesh=mesh,
                                fused_collective=False, **kw)
        _assert_same(unfused, sharded)
        print(f"static case {case}: OK")

    # adaptive on the mesh: replicated controller state, effective-bytes
    # accounting intact under shard_map
    mode, fl = _sharded_fl("topk")
    fl = dataclasses.replace(fl, controller="ef_ratio")
    res = run_federated(_bundle(), fl, _sharded_data(), rounds=4, seed=1,
                        eval_every=2, mode=mode, superstep_rounds=2,
                        mesh=mesh)
    assert all("level" in h and "eff_topk_frac" in h
               for h in res.comm.history)
    assert res.comm.bytes_up == sum(h["bytes_up"]
                                    for h in res.comm.history)
    fused = run_federated(_bundle(), fl, _sharded_data(), rounds=4, seed=1,
                          eval_every=2, mode=mode, superstep_rounds=2,
                          mesh=mesh, fused_collective=False)
    _assert_same(fused, res)
    print("adaptive sharded: OK")
    print("SHARDED-CTRL-OK")
""")


def test_sharded_static_controller_bitwise_forced_host():
    """Forced-2-device: controller='static' on the mesh matches the
    single-device run (allclose) and the fused round stays bitwise-equal
    to the unfused oracle; an adaptive run works end-to-end sharded."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _SHARDED_CTRL_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-CTRL-OK" in out.stdout


_CTRL_ONE_PSUM_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax
    import jax.numpy as jnp
    assert jax.device_count() == 2, jax.devices()
    from test_engine import _bundle, _sharded_fl
    from repro.analysis import count_collectives, round_body
    from repro.compress import make_codec
    from repro.control import LadderSpec, ladder_values, make_controller
    from repro.core.rounds import init_global_state
    from repro.engine.sharded import client_sharding, make_sharded_superstep
    from repro.launch.mesh import make_engine_mesh
    from repro.obs.telemetry import make_telemetry

    mesh = make_engine_mesh()
    shard = client_sharding(mesh)
    mode, fl = _sharded_fl("topk")
    fl = dataclasses.replace(fl, controller="ef_ratio")
    bundle = _bundle()
    uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac)
    downlink = make_codec(fl.downlink_codec)
    state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                           jax.random.PRNGKey(0))
    uplink.bind(state["model"])
    downlink.bind(state["model"])
    ladder = ladder_values(fl)
    uplink.set_ladder(ladder)
    spec = LadderSpec(kind="topk_frac", values=ladder,
                      bytes_up=uplink.level_bytes())
    ctrl = make_controller("ef_ratio").setup(spec, fl)
    K, C, S, B = 4, fl.clients_per_round, fl.local_steps, fl.local_batch
    n_loc = 8 // shard.n_shards
    ef = [jax.ShapeDtypeStruct(
              ((n_loc + 1) * shard.n_shards,) + z.shape, z.dtype)
          for z in jax.eval_shape(uplink.init_state)]
    args = (state, ef, state["model"],
            {"x": jax.ShapeDtypeStruct((K, C, S, B, 8, 8, 1), jnp.float32),
             "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32)},
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.int32),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
            # participation / chaos args (pmask, pstale)
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            ctrl.init_state())

    tele = make_telemetry("compressed", n_clients=C,
                          n_shards=shard.n_shards,
                          available=frozenset(("ef", "level", "eff_bytes")))
    assert any(t.name == "controller" for t in tele.taps), tele.taps
    fn = make_sharded_superstep(bundle, fl, mode, K, mesh, uplink=uplink,
                                downlink=downlink, fused_collective=True,
                                telemetry=tele, participation=True,
                                controller=ctrl)
    jaxpr = jax.make_jaxpr(fn)(*args)
    body = round_body(jaxpr)
    per_round, total = count_collectives(body), count_collectives(jaxpr)
    assert per_round == 1, f"controller round body has {per_round} psums"
    assert total == 2, f"controller superstep has {total} psums"
    print(f"controller+telemetry+participation fused: "
          f"{per_round} psum/round ({total} total)")
    print("CTRL-ONE-PSUM-OK")
""")


def test_fused_superstep_one_psum_with_controller():
    """Acceptance: with a controller, full telemetry AND the
    participation/chaos args all active, the fused sharded round STILL
    executes exactly ONE psum per round — the controller update reads
    psum-completed scalars and adds zero collectives (jaxpr-asserted)."""
    env = _forced_host_env(2)
    out = subprocess.run([sys.executable, "-c", _CTRL_ONE_PSUM_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "CTRL-ONE-PSUM-OK" in out.stdout
