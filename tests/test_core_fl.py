"""The paper's core: two-stream losses, fusion modules, round semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.core.aggregate import normalize_weights, weighted_mean
from repro.core.fusion import fusion_aggregate, fusion_apply, fusion_init
from repro.core.local import make_local_loss, make_local_trainer
from repro.core.rounds import init_global_state, make_round_fn
from repro.models.registry import make_bundle


def _cnn_bundle():
    import dataclasses
    cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"], input_shape=(12, 12, 1),
                              conv_channels=(4, 8), fc_units=(16,), dropout=0.0)
    return make_bundle(cfg)


def _cnn_batch(key, n=8):
    kx, ky = jax.random.split(key)
    return {"x": jax.random.normal(kx, (n, 12, 12, 1)),
            "y": jax.random.randint(ky, (n,), 0, 10)}


# ---------------------------------------------------------------------------
# Fusion modules (paper §3.2)
# ---------------------------------------------------------------------------

def test_fusion_conv_init_is_stream_average():
    """W0 ~= 0.5*[I;I]: at init the conv operator averages the streams."""
    C = 16
    p = fusion_init("conv", C, jax.random.PRNGKey(0))
    fg = jax.random.normal(jax.random.PRNGKey(1), (4, C))
    fl = jax.random.normal(jax.random.PRNGKey(2), (4, C))
    got = fusion_apply("conv", p, fg, fl, impl="jnp")
    np.testing.assert_allclose(got, 0.5 * (fg + fl), atol=0.05)


@pytest.mark.parametrize("op", ["multi", "single"])
def test_fusion_gates_interpolate(op):
    C = 8
    p = fusion_init(op, C, jax.random.PRNGKey(0))
    fg = jnp.ones((2, C))
    fl = -jnp.ones((2, C))
    # lam = 0.5 at init -> exact midpoint
    np.testing.assert_allclose(fusion_apply(op, p, fg, fl), 0.0, atol=1e-6)
    # lam = 1 -> global stream only
    p1 = jax.tree.map(jnp.ones_like, p)
    np.testing.assert_allclose(fusion_apply(op, p1, fg, fl), fg)


def test_fusion_multi_selects_per_channel():
    """multi's vector gate picks global for some channels, local for others
    — the paper's argument for artificial non-IID wins."""
    C = 4
    lam = jnp.array([1.0, 0.0, 1.0, 0.0])
    fg = jnp.arange(C, dtype=jnp.float32)[None]
    fl = 10 + jnp.arange(C, dtype=jnp.float32)[None]
    out = fusion_apply("multi", {"lam": lam}, fg, fl)
    np.testing.assert_allclose(out[0], [0.0, 11.0, 2.0, 13.0])


def test_fusion_aggregate_conv_is_weighted_mean():
    C = 4
    f1 = fusion_init("conv", C, jax.random.PRNGKey(1))
    f2 = fusion_init("conv", C, jax.random.PRNGKey(2))
    stacked = jax.tree.map(lambda a, b: jnp.stack([a, b]), f1, f2)
    w = jnp.array([0.25, 0.75])
    out = fusion_aggregate("conv", f1, stacked, w, ema_beta=0.5)
    np.testing.assert_allclose(out["w"], 0.25 * f1["w"] + 0.75 * f2["w"],
                               rtol=1e-6)


@pytest.mark.parametrize("op", ["multi", "single"])
def test_fusion_aggregate_gates_use_ema(op):
    """Paper §3.3: multi/single gates are EMA-smoothed at aggregation."""
    C = 4
    old = fusion_init(op, C, jax.random.PRNGKey(0))       # lam = 0.5
    client = jax.tree.map(jnp.ones_like, old)             # client gate = 1
    stacked = jax.tree.map(lambda x: x[None], client)
    out = fusion_aggregate(op, old, stacked, jnp.array([1.0]), ema_beta=0.8)
    np.testing.assert_allclose(out["lam"], 0.8 * 0.5 + 0.2 * 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Two-stream losses (paper §3.1)
# ---------------------------------------------------------------------------

def test_fedmmd_loss_adds_positive_regularizer_after_drift():
    bundle = _cnn_bundle()
    fl_avg = FLConfig(algorithm="fedavg")
    fl_mmd = FLConfig(algorithm="fedmmd", mmd_lambda=1.0)
    params = bundle.init(jax.random.PRNGKey(0))
    drifted = jax.tree.map(lambda x: x + 0.3, params)
    batch = _cnn_batch(jax.random.PRNGKey(1))
    l_avg, _ = make_local_loss(bundle, fl_avg)({"model": drifted}, params, batch)
    l_mmd, aux = make_local_loss(bundle, fl_mmd)({"model": drifted}, params, batch)
    assert float(l_mmd) > float(l_avg)
    assert float(aux["mmd"]) > 0


def test_fedmmd_equals_fedavg_when_streams_identical():
    """MMD(theta_G(X), theta_L(X)) == 0 when theta_L == theta_G."""
    bundle = _cnn_bundle()
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _cnn_batch(jax.random.PRNGKey(1))
    l_avg, _ = make_local_loss(bundle, FLConfig(algorithm="fedavg"))(
        {"model": params}, params, batch)
    l_mmd, aux = make_local_loss(bundle, FLConfig(algorithm="fedmmd"))(
        {"model": params}, params, batch)
    np.testing.assert_allclose(float(l_mmd), float(l_avg), atol=1e-5)
    assert abs(float(aux["mmd"])) < 1e-6


def test_fedl2_penalizes_parameter_distance():
    bundle = _cnn_bundle()
    fl = FLConfig(algorithm="fedl2", l2_lambda=1.0)
    params = bundle.init(jax.random.PRNGKey(0))
    drifted = jax.tree.map(lambda x: x + 0.1, params)
    batch = _cnn_batch(jax.random.PRNGKey(1))
    loss_fn = make_local_loss(bundle, fl)
    _, aux0 = loss_fn({"model": params}, params, batch)
    _, aux1 = loss_fn({"model": drifted}, params, batch)
    assert float(aux0["l2"]) < 1e-6
    assert float(aux1["l2"]) > 0.01


def test_frozen_global_gets_no_gradient():
    """Paper Fig. 1: the global stream is FIXED; only trainable moves."""
    bundle = _cnn_bundle()
    fl = FLConfig(algorithm="fedmmd", mmd_lambda=1.0)
    params = bundle.init(jax.random.PRNGKey(0))
    drifted = jax.tree.map(lambda x: x + 0.2, params)
    batch = _cnn_batch(jax.random.PRNGKey(1))
    loss_fn = make_local_loss(bundle, fl)
    g_global = jax.grad(lambda gp: loss_fn({"model": drifted}, gp, batch)[0])(
        params)
    assert max(float(jnp.abs(g).max()) for g in jax.tree.leaves(g_global)) == 0


def test_fedfusion_local_step_trains_fusion_module():
    bundle = _cnn_bundle()
    fl = FLConfig(algorithm="fedfusion", fusion_op="conv", local_steps=3,
                  lr=0.1)
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    trainer = make_local_trainer(bundle, fl)
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_cnn_batch(jax.random.PRNGKey(i)) for i in range(3)])
    trainable, loss = trainer(state["model"], state["fusion"], batches,
                              jnp.float32(0.1))
    dw = float(jnp.abs(trainable["fusion"]["w"] - state["fusion"]["w"]).max())
    assert dw > 1e-6
    assert np.isfinite(float(loss))


# ---------------------------------------------------------------------------
# Round semantics (paper Alg. 1 / Alg. 2)
# ---------------------------------------------------------------------------

def _round_batches(key, n_clients=4, steps=2, n=4):
    ks = jax.random.split(key, n_clients * steps)
    per = [_cnn_batch(k, n) for k in ks]
    return jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape((n_clients, steps) + xs[0].shape),
        *per)


def test_parallel_and_sequential_rounds_agree():
    """The two mesh-execution modes are the SAME algorithm."""
    bundle = _cnn_bundle()
    fl = FLConfig(algorithm="fedavg", local_steps=2, lr=0.05)
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batches = _round_batches(jax.random.PRNGKey(1))
    nex = jnp.array([1.0, 2.0, 3.0, 4.0])
    out_p, _ = make_round_fn(bundle, fl, "client_parallel")(
        state, batches, nex, jnp.float32(0.05))
    out_s, _ = make_round_fn(bundle, fl, "client_sequential")(
        state, batches, nex, jnp.float32(0.05))
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5),
        out_p["model"], out_s["model"])


def test_single_client_round_equals_local_training():
    """With one client of weight 1, the round IS that client's local run."""
    bundle = _cnn_bundle()
    fl = FLConfig(algorithm="fedavg", local_steps=2, lr=0.05)
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batches = _round_batches(jax.random.PRNGKey(1), n_clients=1)
    new_state, _ = make_round_fn(bundle, fl, "client_parallel")(
        state, batches, jnp.ones(1), jnp.float32(0.05))
    trainer = make_local_trainer(bundle, fl)
    want, _ = trainer(state["model"], None,
                      jax.tree.map(lambda x: x[0], batches), jnp.float32(0.05))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-6),
                 new_state["model"], want["model"])


def test_weighted_mean_respects_n_t():
    """Server aggregation is the n_t-weighted average (Alg. 2 line 7)."""
    t1 = {"w": jnp.ones((2, 2))}
    t2 = {"w": 3 * jnp.ones((2, 2))}
    stacked = jax.tree.map(lambda a, b: jnp.stack([a, b]), t1, t2)
    w = normalize_weights(jnp.array([300.0, 100.0]))
    out = weighted_mean(stacked, w)
    np.testing.assert_allclose(out["w"], 1.5)  # 0.75*1 + 0.25*3


def test_identical_clients_fixed_point():
    """If every client computes the same update, averaging preserves it."""
    bundle = _cnn_bundle()
    fl = FLConfig(algorithm="fedavg", local_steps=1, lr=0.05)
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    one = _cnn_batch(jax.random.PRNGKey(1))
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None, None], (3, 1) + x.shape), one)
    new_state, _ = make_round_fn(bundle, fl, "client_parallel")(
        state, batches, jnp.ones(3), jnp.float32(0.05))
    trainer = make_local_trainer(bundle, fl)
    want, _ = trainer(state["model"], None,
                      jax.tree.map(lambda x: x[None], one), jnp.float32(0.05))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-6),
                 new_state["model"], want["model"])


@pytest.mark.parametrize("algo", ["fedfusion", "fedmmd"])
def test_cached_global_features_identical(algo):
    """Paper §3.3: E_g's features can be recorded once per round.  With
    E local epochs the cached path must be bit-identical to recompute."""
    bundle = _cnn_bundle()
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_cnn_batch(jax.random.PRNGKey(i)) for i in range(3)])
    outs = {}
    for cache in (True, False):
        fl = FLConfig(algorithm=algo, fusion_op="conv", local_steps=3,
                      local_epochs=2, cache_global_features=cache, lr=0.05)
        state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
        trainer = make_local_trainer(bundle, fl)
        outs[cache] = trainer(state["model"], state.get("fusion"), batches,
                              jnp.float32(0.05))
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-6),
                 outs[True][0], outs[False][0])


def test_multi_epoch_training_progresses():
    bundle = _cnn_bundle()
    fl1 = FLConfig(algorithm="fedavg", local_steps=2, local_epochs=1, lr=0.05)
    fl3 = FLConfig(algorithm="fedavg", local_steps=2, local_epochs=3, lr=0.05)
    state = init_global_state(bundle, fl1, jax.random.PRNGKey(0))
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[_cnn_batch(jax.random.PRNGKey(i)) for i in range(2)])
    t1, _ = make_local_trainer(bundle, fl1)(state["model"], None, batches,
                                            jnp.float32(0.05))
    t3, _ = make_local_trainer(bundle, fl3)(state["model"], None, batches,
                                            jnp.float32(0.05))
    # 3 epochs move farther from the init than 1
    d1 = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
        jax.tree.leaves(t1["model"]), jax.tree.leaves(state["model"])))
    d3 = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
        jax.tree.leaves(t3["model"]), jax.tree.leaves(state["model"])))
    assert d3 > d1
