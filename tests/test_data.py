"""Data pipeline: synthetic generators + the paper's three partitions."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.data.federated import FederatedDataset
from repro.data.partition import (artificial_noniid_partition,
                                  class_split_partition, iid_partition,
                                  permuted_partition, source_partition)
from repro.data.synth import class_images, token_stream


def _small():
    return class_images(30, n_classes=10, shape=(12, 12, 1), seed=0)


def test_class_images_shapes_and_labels():
    x, y = _small()
    assert x.shape == (300, 12, 12, 1)
    assert y.shape == (300,)
    assert set(np.unique(y)) == set(range(10))


def test_class_images_classes_are_separable():
    """Class templates differ: within-class distance << between-class."""
    x, y = class_images(50, n_classes=4, shape=(12, 12, 1), seed=0, noise=0.1)
    means = np.stack([x[y == c].mean(0).ravel() for c in range(4)])
    d = np.linalg.norm(means[:, None] - means[None], axis=-1)
    off = d[~np.eye(4, dtype=bool)]
    assert off.min() > 0.5  # templates are distinct


@pytest.mark.parametrize("fn,kw", [
    (iid_partition, {}),
    (artificial_noniid_partition, {"shards_per_client": 2}),
    (permuted_partition, {}),
])
def test_partitions_cover_all_examples_disjointly(fn, kw):
    x, y = _small()
    parts = fn(x, y, 5, **kw)
    total = sum(len(p["x"]) for p in parts)
    assert total == len(x)


def test_artificial_noniid_limits_classes_per_client():
    """2 shards of label-sorted data -> each client sees <= ~2-3 classes."""
    x, y = class_images(100, n_classes=10, shape=(8, 8, 1), seed=0)
    parts = artificial_noniid_partition(x, y, 10, shards_per_client=2, seed=0)
    for p in parts:
        assert len(np.unique(p["y"])) <= 3
    # while IID clients see (almost) all classes
    parts_iid = iid_partition(x, y, 10, seed=0)
    assert np.mean([len(np.unique(p["y"])) for p in parts_iid]) > 8


def test_class_split_partition_disjoint_classes():
    x, y = _small()
    parts = class_split_partition(x, y, 2, n_classes=10)
    c0 = set(np.unique(parts[0]["y"]))
    c1 = set(np.unique(parts[1]["y"]))
    assert c0 == {0, 1, 2, 3, 4} and c1 == {5, 6, 7, 8, 9}


def test_permuted_partition_applies_fixed_permutation():
    """Same client = same permutation; different clients differ (user-
    specific non-IID: same classes, different input distributions)."""
    x, y = _small()
    parts = permuted_partition(x, y, 3, seed=0)
    perms = [p["perm"] for p in parts]
    assert not np.array_equal(perms[0], perms[1])
    # each client's label distribution still covers most classes
    for p in parts:
        assert len(np.unique(p["y"])) >= 8


@settings(max_examples=10, deadline=None)
@given(n_clients=st.integers(2, 10), spc=st.integers(1, 4))
def test_artificial_partition_property(n_clients, spc):
    x, y = class_images(20, n_classes=5, shape=(6, 6, 1), seed=1)
    parts = artificial_noniid_partition(x, y, n_clients,
                                        shards_per_client=spc, seed=1)
    assert len(parts) == n_clients
    assert sum(len(p["x"]) for p in parts) == len(x)


def test_token_stream_vocab_and_structure():
    toks, src = token_stream(20, 32, vocab=1000, n_sources=4, seed=0)
    assert toks.shape == (20, 33)
    assert toks.max() < 1000 and toks.min() >= 0
    assert set(np.unique(src)) <= set(range(4))


def test_token_stream_has_learnable_bigram():
    """Even positions continue the previous token's phrase — a perfect
    bigram predictor exists, so training loss can actually decrease."""
    toks, src = token_stream(50, 64, vocab=512, n_sources=1, seed=0)
    # find the shift: t1 = (t0 + shift) % vocab_eff at odd positions
    diffs = (toks[:, 1::2].astype(np.int64)
             - toks[:, 0:-1:2].astype(np.int64)) % 512
    assert len(np.unique(diffs)) == 1


def test_source_partition_groups_sources():
    toks, src = token_stream(60, 16, vocab=256, n_sources=6, seed=0)
    parts = source_partition(toks, src, 3, sources_per_client=2, seed=0)
    assert len(parts) == 3
    for p in parts:
        assert len(p["tokens"]) > 0


def test_federated_dataset_round_batch_shapes():
    x, y = _small()
    ds = FederatedDataset(iid_partition(x, y, 4), {"x": x[:50], "y": y[:50]})
    cids = ds.sample_clients(3)
    batches, sizes = ds.round_batch(cids, local_steps=2, batch=8)
    assert batches["x"].shape == (3, 2, 8, 12, 12, 1)
    assert batches["y"].shape == (3, 2, 8)
    assert sizes.shape == (3,)
    assert all(s == 75 for s in sizes)  # 300/4


def test_federated_dataset_lm_batches_shift_labels():
    toks, src = token_stream(40, 16, vocab=128, n_sources=4, seed=0)
    ds = FederatedDataset(source_partition(toks, src, 4), {"tokens": toks})
    batches, _ = ds.round_batch([0, 1], local_steps=1, batch=4)
    assert batches["tokens"].shape == (2, 1, 4, 16)
    assert batches["labels"].shape == (2, 1, 4, 16)
    np.testing.assert_array_equal(batches["labels"][..., :-1],
                                  batches["tokens"][..., 1:])


def test_sample_clients_unique_and_guarded():
    """EF state is scattered back by cid (``table.at[cids].set``): a
    duplicated cid would silently drop one client's residual, so the
    sampler must (a) never produce duplicates and (b) assert if a broken
    rng ever does."""
    x, y = class_images(6, n_classes=4, shape=(6, 6, 1), seed=0)
    data = FederatedDataset(iid_partition(x, y, 6), {"x": x, "y": y}, seed=0)
    for _ in range(50):
        cids = data.sample_clients(4)
        assert len(np.unique(cids)) == len(cids)
    # oversampling raises instead of silently clamping (the old min()
    # behavior was exactly the silent-partial-participation failure the
    # participation policies make explicit)
    with pytest.raises(ValueError, match="cannot sample"):
        data.sample_clients(100)

    class DupRng:
        def choice(self, n, size, replace):
            return np.zeros(size, np.int64)   # a buggy rng: all duplicates

    data._rng = DupRng()
    with pytest.raises(ValueError, match="duplicate"):
        data.sample_clients(3)


def test_round_chunk_matches_per_round_stream():
    """round_chunk(K) consumes the rng stream exactly like K iterations of
    sample_clients + round_batch — the bitwise contract the superstep
    engine's prefetcher relies on."""
    x, y = class_images(6, n_classes=4, shape=(6, 6, 1), seed=0)
    a = FederatedDataset(iid_partition(x, y, 4), {"x": x, "y": y}, seed=5)
    b = FederatedDataset(iid_partition(x, y, 4), {"x": x, "y": y}, seed=5)
    cids, batches, sizes = a.round_chunk(3, 2, 2, 4)
    for k in range(3):
        want_cids = b.sample_clients(2)
        want_b, want_s = b.round_batch(want_cids, 2, 4)
        np.testing.assert_array_equal(cids[k], want_cids)
        np.testing.assert_array_equal(sizes[k], want_s)
        for key in want_b:
            np.testing.assert_array_equal(batches[key][k], want_b[key])
