"""Cohort-paged error-feedback store (the O(C·n) scaling tentpole).

The contract pinned here: ``ef_store="host"`` — chunk-local EF pages
gathered from a host store, patched on device across the chunk overlap
window, written back asynchronously — is BITWISE the dense device table,
per mode × codec, single-device and sharded, across checkpoint-resume in
either direction, and under chaos + partial participation (a masked
client's residual survives the page round-trip untouched).  Alongside it,
the fellow-traveller scaling pins: Floyd O(C) client sampling, the cached
``client_sizes`` / :class:`TemplateClients` lazy federation, the
device-only downlink mirror copy, and the fused one-psum jaxpr assert
with paging on.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import (ChaosConfig, FederatedDataset,
                                  TemplateClients, _FLOYD_THRESHOLD)
from repro.data.partition import iid_partition
from repro.data.synth import class_images
from repro.engine.efstore import (HostEFStore, _patch_map, plan_chunk_static)
from repro.engine.pipeline import WritebackLane
from repro.fl.server import run_federated
from repro.models.registry import make_bundle

_BUNDLE = None


def _bundle():
    global _BUNDLE
    if _BUNDLE is None:
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                                  input_shape=(8, 8, 1), conv_channels=(4,),
                                  fc_units=(8,), dropout=0.0)
        _BUNDLE = make_bundle(cfg)
    return _BUNDLE


def _data(seed=3, n=4, chaos=None):
    x, y = class_images(16, n_classes=4, shape=(8, 8, 1), seed=0)
    return FederatedDataset(iid_partition(x, y, n),
                            {"x": x[:16], "y": y[:16]}, seed=seed,
                            chaos=chaos)


FL_CASES = {
    "plain": dict(),
    "topk": dict(uplink_codec="topk", topk_frac=0.1),
    "quant+downtopk": dict(uplink_codec="int8", downlink_codec="topk",
                           topk_frac=0.1),
    "fusion-topk": dict(algorithm="fedfusion", fusion_op="conv",
                        uplink_codec="topk", topk_frac=0.1),
}


def _fl_for(case, **kw):
    base = dict(clients_per_round=2, local_steps=2, local_batch=4, lr=0.05)
    base.update(FL_CASES[case])
    base.update(kw)
    return FLConfig(algorithm=base.pop("algorithm", "fedavg"), **base)


def _assert_same(ref, eng):
    for a, b in zip(jax.tree.leaves(ref.global_state),
                    jax.tree.leaves(eng.global_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm.history == eng.comm.history
    assert ref.comm.bytes_up == eng.comm.bytes_up
    assert ref.comm.bytes_down == eng.comm.bytes_down


# ---------------------------------------------------------------------------
# HostEFStore unit contract


def _template():
    return {"w": np.zeros((3, 2), np.float32), "b": np.zeros((4,), np.float32)}


def test_host_store_gather_update_roundtrip():
    store = HostEFStore(_template())
    assert store.n_rows == 0
    assert store.row_nbytes() == (3 * 2 + 4) * 4

    rng = np.random.default_rng(0)
    # buffers ride in flattened-leaf order: "b" [*, 4], then "w" [*, 3, 2]
    b = rng.normal(size=(2, 4)).astype(np.float32)
    w = rng.normal(size=(2, 3, 2)).astype(np.float32)
    store.update([7, 1000], [b, w], [0, 1])
    assert store.n_rows == 2
    # update copies — mutating the source buffer must not reach the store
    w0 = w[0].copy()
    w[0] = -1.0

    bufs = [np.zeros((3, 4), np.float32), np.zeros((3, 3, 2), np.float32)]
    store.gather([1000, 7, 5], bufs, [0, 2, 1])
    np.testing.assert_array_equal(bufs[1][0], w[1])
    np.testing.assert_array_equal(bufs[1][2], w0)
    np.testing.assert_array_equal(bufs[1][1], 0.0)  # miss stays zero
    np.testing.assert_array_equal(bufs[0][0], b[1])
    assert store.hits == 2 and store.misses == 1
    assert store.writeback_rows == 2


def test_host_store_dense_roundtrip():
    store = HostEFStore(_template())
    w = np.arange(6, dtype=np.float32).reshape(1, 3, 2)
    b = np.zeros((1, 4), np.float32)
    store.update([3], [b, w], [0])
    dense = store.to_dense(6)
    assert dense["w"].shape == (6, 3, 2)
    np.testing.assert_array_equal(dense["w"][3], w[0])
    assert not dense["w"][[0, 1, 2, 4, 5]].any()

    back = HostEFStore(_template())
    back.from_dense(dense)
    assert back.n_rows == 1           # zero rows dropped: absent == zero
    np.testing.assert_array_equal(back.to_dense(6)["w"], dense["w"])
    # a row that is zero in one leaf but not the other must survive
    dense["b"][5, 0] = 2.0
    back.from_dense(dense)
    assert back.n_rows == 2


# ---------------------------------------------------------------------------
# PagePlan invariants


def test_plan_unsharded_injective_and_stable():
    cids = np.array([[9, 2], [2, 40], [7, 9]])
    plan = plan_chunk_static(cids)
    assert plan.page_rows == plan.p_loc == 6   # K*C slots
    assert plan.vcids.shape == cids.shape
    assert plan.vcids.dtype == np.int32
    # same client -> same slot across rounds; distinct clients distinct
    flat_c, flat_v = cids.reshape(-1), plan.vcids.reshape(-1)
    assert len({(c, v) for c, v in zip(flat_c, flat_v)}) == len(set(flat_c))
    assert len(set(flat_v[np.unique(flat_c, return_index=True)[1]])) == \
        len(set(flat_c))
    assert flat_v.max() < plan.page_rows


@pytest.mark.parametrize("n_shards", [2, 4])
def test_plan_sharded_owner_and_scratch_rows(n_shards):
    rng = np.random.default_rng(1)
    cids = rng.choice(1000, size=(4, 3), replace=False)
    plan = plan_chunk_static(cids, n_shards)
    p_loc = 4 * 3
    assert plan.p_loc == p_loc
    assert plan.page_rows == (p_loc + 1) * n_shards
    for cid, slot, row in zip(plan.uniq, plan.slots, plan.rows):
        owner = cid % n_shards                      # chunk-stable owner map
        assert row == owner * (p_loc + 1) + slot
        assert slot < p_loc                         # never the scratch row
    # virtual ids encode (owner, slot) in the superstep's ownership math:
    # vcid // p_loc == owner shard, vcid % p_loc == block-local slot
    flat_c, flat_v = cids.reshape(-1), plan.vcids.reshape(-1)
    for c, v in zip(flat_c, flat_v):
        assert v // p_loc == c % n_shards
    assert len(set(flat_v)) == len(set(flat_c))


def test_plan_owner_stable_across_chunks():
    """The device patch copies rows within a shard block — legal only
    because a client's owner shard never changes between chunks."""
    a = plan_chunk_static(np.array([[11, 5], [8, 11]]), 2, index=0)
    b = plan_chunk_static(np.array([[11, 30], [7, 8]]), 2, index=1)
    for cid in set(a.uniq) & set(b.uniq):
        oa = a.rows[list(a.uniq).index(cid)] // (a.p_loc + 1)
        ob = b.rows[list(b.uniq).index(cid)] // (b.p_loc + 1)
        assert oa == ob


def test_patch_map_selects_previous_chunk_rows():
    prev = plan_chunk_static(np.array([[4, 9], [9, 2]]), index=0)
    cur = plan_chunk_static(np.array([[9, 6], [4, 6]]), index=1)
    use, src = _patch_map(prev, cur)
    assert use.shape == (cur.page_rows,)
    hit = {cid: (u, s) for cid, u, s in
           zip(cur.uniq.tolist(), use[cur.rows], src[cur.rows])}
    prev_slot = dict(zip(prev.uniq.tolist(), prev.slots.tolist()))
    assert hit[9][0] and hit[9][1] == prev_slot[9]
    assert hit[4][0] and hit[4][1] == prev_slot[4]
    assert not hit[6][0]                            # fresh client: staged row
    assert int(use.sum()) == 2


# ---------------------------------------------------------------------------
# WritebackLane


def test_writeback_lane_orders_flush_close():
    lane = WritebackLane(name="t-lane")
    seen = []
    for i in range(5):
        lane.submit(lambda i=i: seen.append(i))
    assert lane.wait_done(3)
    lane.flush()
    assert seen == [0, 1, 2, 3, 4]                  # submission order
    lane.submit(lambda: seen.append(5))
    lane.close()                                    # drains before joining
    assert seen[-1] == 5
    lane.close()                                    # idempotent


def test_writeback_lane_error_surfaces_and_never_deadlocks():
    lane = WritebackLane(name="t-err")
    lane.submit(lambda: (_ for _ in ()).throw(RuntimeError("disk on fire")))
    with pytest.raises(RuntimeError, match="disk on fire"):
        lane.flush()
    lane.close()


# ---------------------------------------------------------------------------
# engine: paged == dense, bitwise


@pytest.mark.parametrize("case", sorted(FL_CASES))
@pytest.mark.parametrize("chunk", [1, 4])
def test_paged_matches_dense_bitwise(case, chunk):
    """Acceptance: ef_store="host" equals ef_store="device" bit for bit —
    final model AND full CommLog history — per codec case, K=1 (no scan)
    and K=4 (scan carry)."""
    bundle = _bundle()
    dense = run_federated(bundle, _fl_for(case), _data(), rounds=6, seed=1,
                          eval_every=2, superstep_rounds=chunk,
                          ef_store="device")
    paged = run_federated(bundle, _fl_for(case), _data(), rounds=6, seed=1,
                          eval_every=2, superstep_rounds=chunk,
                          ef_store="host")
    _assert_same(dense, paged)
    if case == "plain":
        assert paged.stats["ef_store"] is None      # no EF at all
    elif case == "quant+downtopk":
        # int8 uplink carries no residual state: nothing to page, the
        # engine keeps the (empty) dense tree whatever ef_store says
        assert paged.stats["ef_store"] == "device"
    else:
        assert paged.stats["ef_store"] == "host"
        assert dense.stats["ef_store"] == "device"


def test_paged_page_bytes_track_cohort_not_federation():
    """The O(C·n) pin: the staged EF page is sized by (chunk rounds ×
    cohort), so its byte count is IDENTICAL at 4 and 64 clients."""
    bundle = _bundle()
    fl = _fl_for("topk")
    sizes = {}
    for n in (4, 64):
        res = run_federated(bundle, fl, _data(n=n), rounds=4, seed=1,
                            eval_every=4, superstep_rounds=2,
                            ef_store="host")
        sizes[n] = res.stats["ef_page_bytes"]
        assert res.stats["ef_store_rows"] <= n
    assert sizes[4] == sizes[64] > 0


def test_ef_store_auto_flips_on_projected_bytes(monkeypatch):
    """"auto" picks the dense table while it fits and pages beyond the
    budget — same run, same bits either way."""
    import repro.engine.engine as eng
    bundle = _bundle()
    fl = _fl_for("topk")
    small = run_federated(bundle, fl, _data(), rounds=2, seed=1,
                          superstep_rounds=2, ef_store="auto")
    assert small.stats["ef_store"] == "device"
    monkeypatch.setattr(eng, "_EF_STORE_AUTO_BYTES", 0)
    big = run_federated(bundle, fl, _data(), rounds=2, seed=1,
                        superstep_rounds=2, ef_store="auto")
    assert big.stats["ef_store"] == "host"
    _assert_same(small, big)


def test_ef_store_rejects_unknown_value():
    with pytest.raises(ValueError, match="ef_store"):
        run_federated(_bundle(), _fl_for("topk"), _data(), rounds=1,
                      ef_store="hbm")


@pytest.mark.parametrize("first,second", [("device", "host"),
                                          ("host", "device"),
                                          ("host", "host")])
def test_paged_checkpoint_resume_cross_store(tmp_path, first, second):
    """ef.npz is store-agnostic: a checkpoint written under either backing
    resumes under either, landing bitwise on the dense->dense two-phase
    oracle (models AND the resumed history)."""
    bundle = _bundle()
    fl = _fl_for("topk")

    def two_phase(d, ef_first, ef_second):
        run_federated(bundle, fl, _data(), rounds=4, seed=1, eval_every=4,
                      superstep_rounds=3, checkpoint_dir=d,
                      checkpoint_every=2, ef_store=ef_first)
        return run_federated(bundle, fl, _data(), rounds=8, seed=1,
                             eval_every=4, superstep_rounds=3,
                             checkpoint_dir=d, checkpoint_every=2,
                             ef_store=ef_second)

    gold = two_phase(str(tmp_path / "gold"), "device", "device")
    res = two_phase(str(tmp_path / "ck"), first, second)
    _assert_same(gold, res)
    assert res.comm.rounds == 4                     # only rounds 5..8 ran


def test_paged_ef_npz_equals_dense_ef_npz(tmp_path):
    """The checkpointed EF table itself (not just the downstream run) is
    bitwise store-independent."""
    bundle = _bundle()
    fl = _fl_for("topk")
    for store in ("device", "host"):
        run_federated(bundle, fl, _data(), rounds=4, seed=1, eval_every=4,
                      superstep_rounds=2, checkpoint_dir=str(tmp_path / store),
                      checkpoint_every=4, ef_store=store)
    a = np.load(tmp_path / "device" / "ef.npz")
    b = np.load(tmp_path / "host" / "ef.npz")
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k])


def test_paged_chaos_participation_bitwise():
    """Chaos + deadline participation under paging: masked clients' EF
    rows ride the page out and back unmodified, so the run equals the
    dense one bit for bit (PR 7's EF-rollback contract survives paging)."""
    chaos = ChaosConfig(speed_sigma=1.0, jitter=0.2, dropout=0.3,
                        truncation=0.3, seed=7)
    bundle = _bundle()
    fl = _fl_for("topk", clients_per_round=4, participation="deadline",
                 over_provision=1.5)
    kw = dict(rounds=6, seed=1, eval_every=2, superstep_rounds=2)
    dense = run_federated(bundle, fl, _data(n=8, chaos=chaos),
                          ef_store="device", **kw)
    paged = run_federated(bundle, fl, _data(n=8, chaos=chaos),
                          ef_store="host", **kw)
    _assert_same(dense, paged)


def test_paged_auto_chunk_calibration_identical():
    """superstep_rounds="auto" calibrates on throwaway zero pages; the
    paged result stays bitwise-equal to a fixed-K paged run."""
    bundle = _bundle()
    fl = _fl_for("topk")
    fixed = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                          eval_every=4, superstep_rounds=4, ef_store="host")
    auto = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                         eval_every=4, superstep_rounds="auto",
                         ef_store="host")
    _assert_same(fixed, auto)


# ---------------------------------------------------------------------------
# engine: downlink mirror stays on device (no host round-trip copy)


def test_device_copy_mirror_is_device_native_and_unaliased():
    from repro.engine.engine import _device_copy
    src = {"w": jnp.arange(8, dtype=jnp.float32)}
    cpy = _device_copy(src)
    assert isinstance(cpy["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(cpy["w"]), np.asarray(src["w"]))
    # a jit-output buffer, safe to donate independently of the source
    assert cpy["w"].unsafe_buffer_pointer() != src["w"].unsafe_buffer_pointer()


# ---------------------------------------------------------------------------
# sharded: forced-2-device subprocess grid (paged == dense on a mesh)


_SHARDED_SCRIPT = textwrap.dedent("""
    import jax
    assert jax.device_count() == 2, jax.devices()
    from test_efstore import _assert_same, _bundle, _data, _fl_for
    from repro.fl.server import run_federated
    from repro.launch.mesh import make_engine_mesh

    mesh = make_engine_mesh()
    for case in ("topk", "quant+downtopk", "fusion-topk"):
        fl = _fl_for(case, clients_per_round=4)
        kw = dict(rounds=4, seed=1, eval_every=2, superstep_rounds=2,
                  mesh=mesh)
        dense = run_federated(_bundle(), fl, _data(n=8), ef_store="device",
                              **kw)
        paged = run_federated(_bundle(), fl, _data(n=8), ef_store="host",
                              **kw)
        _assert_same(dense, paged)
        print(f"case {case}: OK")

    # paged mode lifts the N-divides-over-shards constraint...
    fl = _fl_for("topk", clients_per_round=4)
    run_federated(_bundle(), fl, _data(n=7), rounds=2, seed=1,
                  superstep_rounds=2, mesh=mesh, ef_store="host")
    # ...which the dense table still enforces
    try:
        run_federated(_bundle(), fl, _data(n=7), rounds=2, seed=1,
                      superstep_rounds=2, mesh=mesh, ef_store="device")
        raise SystemExit("dense odd-N should have raised")
    except ValueError:
        pass
    print("EFSTORE-SHARDED-OK")
""")


def _forced_host_env(n_devices):
    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(here, "..", "src")
    env = dict(os.environ)
    kept = [t for t in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in t]
    env["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n_devices}"])
    env["REPRO_ALLOW_FORCED_DEVICES"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [src, here] + env.get("PYTHONPATH", "").split(os.pathsep))
    return env


def test_sharded_paged_matches_dense_forced_host():
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         capture_output=True, text=True,
                         env=_forced_host_env(2), timeout=1200)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "EFSTORE-SHARDED-OK" in out.stdout


# ---------------------------------------------------------------------------
# fused collective: still exactly ONE psum per round with paging on


_ONE_PSUM_PAGED_SCRIPT = textwrap.dedent("""
    import jax
    import jax.numpy as jnp
    assert jax.device_count() == 2, jax.devices()
    from test_efstore import _bundle, _fl_for
    from repro.analysis import count_collectives, round_body
    from repro.compress import make_codec
    from repro.core.rounds import init_global_state
    from repro.engine.sharded import client_sharding, make_sharded_superstep
    from repro.launch.mesh import make_engine_mesh

    mesh = make_engine_mesh()
    shard = client_sharding(mesh)
    fl = _fl_for("topk", clients_per_round=4)
    bundle = _bundle()
    uplink = make_codec(fl.uplink_codec, topk_frac=fl.topk_frac)
    downlink = make_codec(fl.downlink_codec)
    state = jax.eval_shape(lambda k: init_global_state(bundle, fl, k),
                           jax.random.PRNGKey(0))
    uplink.bind(state["model"])
    downlink.bind(state["model"])
    K, C, S, B = 4, fl.clients_per_round, fl.local_steps, fl.local_batch
    # the PAGED table: per-shard [K*C + 1] slot blocks (scratch row incl.)
    ef = [jax.ShapeDtypeStruct(
              ((K * C + 1) * shard.n_shards,) + z.shape, z.dtype)
          for z in jax.eval_shape(uplink.init_state)]
    args = (state, ef, state["model"],
            {"x": jax.ShapeDtypeStruct((K, C, S, B, 8, 8, 1), jnp.float32),
             "y": jax.ShapeDtypeStruct((K, C, S, B), jnp.int32)},
            jax.ShapeDtypeStruct((K, C), jnp.float32),
            jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, C), jnp.int32),   # virtual cids
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

    fn = make_sharded_superstep(bundle, fl, "client_parallel", K, mesh,
                                uplink=uplink, downlink=downlink,
                                fused_collective=True)
    jaxpr = jax.make_jaxpr(fn)(*args)
    body = round_body(jaxpr)
    per_round = count_collectives(body)
    total = count_collectives(jaxpr)
    assert per_round == 1, f"paged fused round body has {per_round} psums"
    assert total == 2, f"paged fused superstep has {total} psums"
    print("ONE-PSUM-PAGED-OK")
""")


def test_fused_superstep_one_psum_with_paging():
    """Acceptance: the fused sharded superstep traced on PAGE-shaped EF
    args (``[(K*C+1)*S, ...]`` + virtual cids) still counts exactly one
    psum in the round body and one chunk prologue — paging changes array
    sizes, never the collective structure."""
    out = subprocess.run([sys.executable, "-c", _ONE_PSUM_PAGED_SCRIPT],
                         capture_output=True, text=True,
                         env=_forced_host_env(2), timeout=600)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "ONE-PSUM-PAGED-OK" in out.stdout


# ---------------------------------------------------------------------------
# O(C) sampling (Floyd) + lazy federations


def test_floyd_sampling_distinct_in_range_replayable():
    n = _FLOYD_THRESHOLD + 37
    t = {"x": np.zeros((6, 2, 2, 1), np.float32),
         "y": np.zeros((6,), np.int64)}
    data = FederatedDataset(TemplateClients(t, n), {"x": t["x"], "y": t["y"]},
                            seed=11)
    a = data.sample_clients(64)
    assert len(np.unique(a)) == 64
    assert a.min() >= 0 and a.max() < n
    # same seed -> same draw (the skip_round_sampling replay contract)
    data2 = FederatedDataset(TemplateClients(t, n),
                             {"x": t["x"], "y": t["y"]}, seed=11)
    np.testing.assert_array_equal(a, data2.sample_clients(64))
    assert not np.array_equal(a, data.sample_clients(64))  # stream advances


def test_floyd_skip_round_sampling_replays():
    n = _FLOYD_THRESHOLD + 5
    t = {"x": np.zeros((8, 2, 2, 1), np.float32),
         "y": np.zeros((8,), np.int64)}
    data = FederatedDataset(TemplateClients(t, n), {"x": t["x"], "y": t["y"]},
                            seed=4)
    chunks = [data.round_chunk(2, 3, 2, 4) for _ in range(2)]
    data.skip_round_sampling(2, 3, 2, 4)       # re-seeds + replays chunk 0
    cids, _, _ = data.round_chunk(2, 3, 2, 4)
    np.testing.assert_array_equal(cids, chunks[1][0])


def test_small_federations_keep_choice_stream():
    """At or below the threshold the original permutation ``choice``
    stream is untouched — the bitwise reference pins depend on it."""
    x, y = class_images(12, n_classes=4, shape=(8, 8, 1), seed=0)
    data = FederatedDataset(iid_partition(x, y, 4), {"x": x, "y": y}, seed=3)
    expect = np.random.default_rng(3).choice(4, size=2, replace=False)
    np.testing.assert_array_equal(data.sample_clients(2), expect)


def test_sampling_cost_flat_in_federation_size():
    """The micro-bench guard: sampling a fixed cohort from a 64x larger
    federation must not cost ~64x (Floyd is O(cohort); the permutation
    path would scale with N)."""
    t = {"x": np.zeros((6, 2, 2, 1), np.float32),
         "y": np.zeros((6,), np.int64)}

    def cost(n):
        data = FederatedDataset(TemplateClients(t, n),
                                {"x": t["x"], "y": t["y"]}, seed=0)
        data.sample_clients(32)                 # warm caches
        t0 = time.perf_counter()
        for _ in range(50):
            data.sample_clients(32)
        return time.perf_counter() - t0

    small, big = cost(1 << 14), cost(1 << 20)
    assert big < small * 8 + 0.05, (small, big)


def test_template_clients_and_cached_sizes():
    t = {"x": np.ones((5, 2, 2, 1), np.float32),
         "y": np.zeros((5,), np.int64)}
    clients = TemplateClients(t, 1000)
    assert len(clients) == 1000
    assert clients[999] is clients[0]
    with pytest.raises(IndexError):
        clients[1000]
    data = FederatedDataset(clients, {"x": t["x"], "y": t["y"]}, seed=0)
    sizes = data.client_sizes()
    assert sizes.shape == (1000,) and (sizes == 5.0).all()
    assert data.client_sizes() is sizes          # cached
    # list-backed datasets cache too
    x, y = class_images(12, n_classes=4, shape=(8, 8, 1), seed=0)
    d2 = FederatedDataset(iid_partition(x, y, 4), {"x": x, "y": y}, seed=0)
    assert d2.client_sizes() is d2.client_sizes()


def test_template_clients_round_batch():
    t = {"x": np.random.default_rng(0).normal(
             size=(6, 8, 8, 1)).astype(np.float32),
         "y": np.arange(6, dtype=np.int64) % 4}
    data = FederatedDataset(TemplateClients(t, 5000),
                            {"x": t["x"], "y": t["y"]}, seed=2)
    cids = data.sample_clients(4)
    batch, sizes = data.round_batch(cids, 2, 3)
    assert batch["x"].shape == (4, 2, 3, 8, 8, 1)
    assert (sizes == 6.0).all()
