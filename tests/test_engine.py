"""Engine equivalence: the superstep loop IS the reference loop.

The contract pinned here is the repo's strongest: for every execution mode
x codec combination, the chunked engine (``run_federated``) reproduces the
preserved pre-engine loop (``run_federated_reference``) *exactly* — final
global model bitwise-equal, CommLog history equal as Python objects
(bytes, local_loss and eval metrics included), and identical
checkpoint-resume behaviour.  K=1 bypasses ``lax.scan`` entirely; K=4
exercises the scan carry (global state + EF tree + mirror threading).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import CNN_CONFIGS
from repro.configs.base import FLConfig
from repro.data.federated import FederatedDataset
from repro.data.partition import iid_partition
from repro.data.synth import class_images
from repro.engine import chunk_schedule
from repro.fl.server import (_evaluate_eager, evaluate, run_federated,
                             run_federated_reference)
from repro.models.registry import make_bundle


_BUNDLE = None


def _bundle():
    global _BUNDLE
    if _BUNDLE is None:
        cfg = dataclasses.replace(CNN_CONFIGS["cnn_mnist"],
                                  input_shape=(8, 8, 1), conv_channels=(4,),
                                  fc_units=(8,), dropout=0.0)
        _BUNDLE = make_bundle(cfg)
    return _BUNDLE


def _data(seed=3):
    x, y = class_images(12, n_classes=4, shape=(8, 8, 1), seed=0)
    return FederatedDataset(iid_partition(x, y, 4),
                            {"x": x[:16], "y": y[:16]}, seed=seed)


def _assert_same(ref, eng):
    for a, b in zip(jax.tree.leaves(ref.global_state),
                    jax.tree.leaves(eng.global_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ref.comm.history == eng.comm.history
    assert ref.comm.bytes_up == eng.comm.bytes_up
    assert ref.comm.bytes_down == eng.comm.bytes_down


FL_CASES = {
    "plain": dict(),
    "topk": dict(uplink_codec="topk", topk_frac=0.1),
    "quant+downtopk": dict(uplink_codec="int8", downlink_codec="topk",
                           topk_frac=0.1),
    "fusion-topk": dict(algorithm="fedfusion", fusion_op="conv",
                        uplink_codec="topk", topk_frac=0.1),
}


_REF_CACHE = {}


def _fl_for(case):
    kw = dict(FL_CASES[case])
    algo = kw.pop("algorithm", "fedavg")
    return FLConfig(algorithm=algo, clients_per_round=2, local_steps=2,
                    local_batch=4, lr=0.05, **kw)


def _reference(bundle, mode, case):
    if (mode, case) not in _REF_CACHE:
        _REF_CACHE[mode, case] = run_federated_reference(
            bundle, _fl_for(case), _data(), rounds=6, seed=1, eval_every=2,
            mode=mode)
    return _REF_CACHE[mode, case]


@pytest.mark.parametrize("mode", ["client_parallel", "client_sequential"])
@pytest.mark.parametrize("case", sorted(FL_CASES))
@pytest.mark.parametrize("chunk", [1, 4])
def test_engine_reproduces_reference(mode, case, chunk):
    """Chunked superstep == seed loop: model bitwise, history exactly."""
    bundle = _bundle()
    ref = _reference(bundle, mode, case)
    eng = run_federated(bundle, _fl_for(case), _data(), rounds=6, seed=1,
                        eval_every=2, mode=mode, superstep_rounds=chunk)
    _assert_same(ref, eng)


def test_engine_eval_every_round_in_scan():
    """eval_every=1 folds evaluation into the scan body; the per-round
    acc/loss trajectory still matches the reference exactly."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=4, lr=0.05)
    ref = run_federated_reference(bundle, fl, _data(), rounds=5, seed=1,
                                  eval_every=1)
    eng = run_federated(bundle, fl, _data(), rounds=5, seed=1, eval_every=1,
                        superstep_rounds=4)
    _assert_same(ref, eng)
    assert all("acc" in h for h in eng.comm.history)


@pytest.mark.parametrize("codec", ["identity", "topk"])
def test_engine_checkpoint_resume_matches_reference(tmp_path, codec):
    """Interrupt at round 4, resume to 8 — both loops land on the same
    state, and the engine restores the device-side EF tree from ef.npz."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=2,
                  local_batch=4, lr=0.05, uplink_codec=codec, topk_frac=0.1)
    dr = _data()
    run_federated_reference(bundle, fl, dr, rounds=4, seed=1, eval_every=4,
                            checkpoint_dir=str(tmp_path / "ref"),
                            checkpoint_every=2)
    ref = run_federated_reference(bundle, fl, dr, rounds=8, seed=1,
                                  eval_every=4,
                                  checkpoint_dir=str(tmp_path / "ref"),
                                  checkpoint_every=2)
    de = _data()
    run_federated(bundle, fl, de, rounds=4, seed=1, eval_every=4,
                  checkpoint_dir=str(tmp_path / "eng"), checkpoint_every=2,
                  superstep_rounds=3)
    eng = run_federated(bundle, fl, de, rounds=8, seed=1, eval_every=4,
                        checkpoint_dir=str(tmp_path / "eng"),
                        checkpoint_every=2, superstep_rounds=3)
    _assert_same(ref, eng)
    assert ref.comm.rounds == eng.comm.rounds == 4  # only rounds 5..8 ran


def test_engine_callback_gets_per_round_state():
    """A callback forces one-round chunks and sees the same (round,
    metrics) sequence as the reference loop."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)

    def make_cb(store):
        def cb(r, state, metrics):
            store[r] = dict(metrics)
        return cb

    ref_seen, eng_seen = {}, {}
    run_federated_reference(bundle, fl, _data(), rounds=3, seed=1,
                            eval_every=1, callback=make_cb(ref_seen))
    run_federated(bundle, fl, _data(), rounds=3, seed=1, eval_every=1,
                  callback=make_cb(eng_seen), superstep_rounds=4)
    assert ref_seen == eng_seen
    assert sorted(ref_seen) == [0, 1, 2]


def test_engine_prefetch_off_identical():
    """prefetch=False (synchronous staging) changes nothing numerically."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg", clients_per_round=2, local_steps=1,
                  local_batch=4, lr=0.05)
    a = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                      superstep_rounds=2, prefetch=True)
    b = run_federated(bundle, fl, _data(), rounds=4, seed=1,
                      superstep_rounds=2, prefetch=False)
    _assert_same(a, b)


def test_chunk_schedule_boundaries():
    """Chunks never cross eval or checkpoint boundaries."""
    sched = chunk_schedule(0, 20, 8, eval_every=5, ckpt_every=4)
    assert sched[0] == (0, 4)
    flat = [b for _, b in sched]
    assert all(b % 5 == 0 or b % 4 == 0 or b == 20 for b in flat)
    assert sched[-1][1] == 20
    # contiguous, in order
    assert all(sched[i][1] == sched[i + 1][0] for i in range(len(sched) - 1))
    # per-round mode (callback) degenerates to K=1
    assert chunk_schedule(2, 5, 8, per_round=True) == [(2, 3), (3, 4),
                                                       (4, 5)]
    # eval folded into the scan imposes no boundary
    assert chunk_schedule(0, 16, 8, eval_every=None) == [(0, 8), (8, 16)]


def test_jitted_evaluate_matches_eager():
    """The pad-and-mask jitted evaluator equals the uncompiled original."""
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg")
    from repro.core.rounds import init_global_state
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batch = _data().test_batch()
    fast = evaluate(bundle, fl, state, batch)
    slow = _evaluate_eager(bundle, fl, state, batch)
    assert fast.keys() == slow.keys()
    for k in fast:
        np.testing.assert_allclose(fast[k], slow[k], rtol=1e-5, atol=1e-6)


def test_jitted_evaluate_respects_max_examples():
    bundle = _bundle()
    fl = FLConfig(algorithm="fedavg")
    from repro.core.rounds import init_global_state
    state = init_global_state(bundle, fl, jax.random.PRNGKey(0))
    batch = _data().test_batch()
    fast = evaluate(bundle, fl, state, batch, max_examples=8)
    slow = _evaluate_eager(bundle, fl, state, batch, max_examples=8)
    for k in fast:
        np.testing.assert_allclose(fast[k], slow[k], rtol=1e-5, atol=1e-6)


def test_masked_metrics_ignore_padding():
    """Masked accuracy/CE on a padded batch == plain metrics unpadded."""
    import jax.numpy as jnp
    from repro.core import (accuracy, cross_entropy, masked_accuracy,
                            masked_cross_entropy)
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (6, 5))
    labels = jax.random.randint(key, (6,), 0, 5)
    pad_logits = jnp.concatenate([logits, 100 * jnp.ones((2, 5))])
    pad_labels = jnp.concatenate([labels, jnp.zeros((2,), labels.dtype)])
    mask = jnp.arange(8) < 6
    np.testing.assert_allclose(
        float(masked_accuracy(pad_logits, pad_labels, mask)),
        float(accuracy(logits, labels)), rtol=1e-6)
    np.testing.assert_allclose(
        float(masked_cross_entropy(pad_logits, pad_labels, mask)),
        float(cross_entropy(logits, labels)), rtol=1e-5)
